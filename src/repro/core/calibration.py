"""Calibrated state-conditional cost coefficients (measure → fit →
profile → score/probe).

FATE's gains hinge on state-conditional cost estimation (paper §3.5),
but the proxy constants the scheduler plans against — per-model
``switch_cost``/``prefill_coef``/``decode_coef`` profiles, the global
transfer and prefix-saving scales — were hand-set.  This module closes
the loop between measured wall times (the instrumented
:mod:`repro.serving.engine` trace) and the planner's cost model:

1. **Measure** — every executed stage yields a
   :class:`StageObservation`: model, query count, tokens in/out,
   residency-switch count, warm-prefix hit fraction, cross-device
   transfer volume, and the measured wall seconds.
2. **Fit** — :func:`fit_profile` solves a per-model-family
   least-squares problem over those features (the duration model is
   linear in the coefficients once the prefix term is folded into a
   combined column; see :func:`_design_matrix`), recovering
   base/prefill/decode/switch/transfer coefficients and the prefix
   saving fraction.
3. **Profile** — the result is a versioned, JSON-serializable
   :class:`CalibrationProfile`.  Loading it replaces the hand-set
   constants everywhere they are consumed: ``model_profiles()`` feeds
   ``ExecutionState.profiles`` (read by ``CostModel.switch_cost``,
   ``Scorer.future_tail``/``_model_vec``, and the admission floors in
   :mod:`repro.core.admission`), ``cost_params()`` feeds ``CostModel``
   / ``FrontierPlanner`` / the executors, and the serving engine
   derives its emulated switch sleeps from the SAME object
   (:meth:`CalibrationProfile.assert_consistent` enforces agreement at
   profile-load time).  Any FIXED profile preserves the engine's bit
   parity: matrix vs scalar scoring and delta vs full rebuilds stay
   bit-identical because a profile only changes constants, never term
   order (``tests/test_calibration.py``).
4. **Probe correction** — :class:`ProbeCorrector` replaces the
   hand-set admission ``probe_margin`` with an online
   predicted-vs-observed latency correction: an EWMA of the
   observed/predicted ratio per model family, updated on every serving
   completion and fed back into every admission probe and deferral
   re-probe (:mod:`repro.core.admission`).

``benchmarks/sched_bench.py --calibrate`` gates the loop end to end;
the workflow is documented in ``docs/COSTMODEL.md``.
"""
from __future__ import annotations

import dataclasses
import json
import math
from pathlib import Path
from typing import Iterable, Mapping, Optional, Sequence

import numpy as np

from repro.core.costs import CostParams
from repro.core.workflow import DEFAULT_PROFILES, ModelProfile

#: Schema version written into every serialized profile; bumped on any
#: incompatible change to the coefficient set or its semantics.
PROFILE_VERSION = 1

#: β reference the hand-set proxy clusters use (seconds per 1k tokens
#: moved between distinct devices, ``Cluster.transfer_coef``).  Fitted
#: per-family transfer coefficients are expressed relative to it when a
#: profile is lowered onto global ``CostParams.transfer_scale``.
REFERENCE_BETA = 0.06


@dataclasses.dataclass(frozen=True)
class StageObservation:
    """One measured stage execution — the calibration unit of evidence.

    Features are per-stage aggregates in the engine's measurement
    frame: ``queries`` queries of ``prompt_tokens`` prompt and
    ``output_tokens`` generated tokens each ran under model ``model``
    (family ``family``), causing ``switches`` residency switches, with
    a warm shared prefix covering ``prefix_fraction`` of the queries
    and ``transfer_ktokens`` thousand tokens moved across devices,
    taking ``wall_s`` measured seconds end to end on a device of
    relative ``speed``.
    """
    model: str
    family: str
    queries: int
    prompt_tokens: float
    output_tokens: float
    switches: int
    prefix_fraction: float
    transfer_ktokens: float
    wall_s: float
    speed: float = 1.0


@dataclasses.dataclass(frozen=True)
class FamilyCoefficients:
    """Fitted (or hand-set) duration coefficients for one model family.

    All values are in PROXY seconds (the unit the scheduler plans in):

    * ``base`` — per-query constant overhead;
    * ``prefill`` — seconds per 1k prompt tokens per query;
    * ``decode`` — seconds per 1k generated tokens per query;
    * ``switch`` — model weight-load (residency switch) seconds;
    * ``transfer`` — seconds per 1k tokens moved across devices;
    * ``prefix_saving`` — fraction of the prefill term saved per
      fully-warm shared-prefix query.

    The stage-duration model these parametrize is spelled out in
    :func:`predict_wall` and ``docs/COSTMODEL.md``.
    """
    base: float
    prefill: float
    decode: float
    switch: float
    transfer: float
    prefix_saving: float

    def as_dict(self) -> dict:
        """Flat float dict (JSON serialization order)."""
        return dataclasses.asdict(self)


def predict_wall(c: FamilyCoefficients, obs: StageObservation) -> float:
    """Predicted stage wall seconds (proxy units) under coefficients
    ``c`` — the generative duration model the fitter inverts:

    ``(q/speed)·(base + prefill·pk + decode·ok)
    + switches·switch + transfer_ktokens·transfer
    − prefix_fraction·(q/speed)·pk·prefill·prefix_saving``

    with ``pk``/``ok`` the prompt/output sizes in thousands of tokens.
    """
    q = obs.queries / max(obs.speed, 1e-9)
    pk = obs.prompt_tokens / 1000.0
    ok = obs.output_tokens / 1000.0
    wall = q * (c.base + c.prefill * pk + c.decode * ok)
    wall += obs.switches * c.switch
    wall += obs.transfer_ktokens * c.transfer
    wall -= obs.prefix_fraction * q * pk * c.prefill * c.prefix_saving
    return wall


def _family_means(defaults: Mapping[str, ModelProfile]
                  ) -> dict[str, tuple[float, float, float]]:
    """Per-family hand-set (switch, prefill, decode) means — the
    reference magnitudes fitted family coefficients are expressed
    against when lowered onto per-model profiles (within-family ratios
    between model sizes are preserved)."""
    groups: dict[str, list[ModelProfile]] = {}
    for prof in defaults.values():
        groups.setdefault(prof.family, []).append(prof)
    out = {}
    for fam, profs in groups.items():
        out[fam] = (
            sum(p.switch_cost for p in profs) / len(profs),
            sum(p.prefill_coef for p in profs) / len(profs),
            sum(p.decode_coef for p in profs) / len(profs),
        )
    return out


@dataclasses.dataclass(frozen=True)
class CalibrationProfile:
    """Versioned per-model-family cost coefficients — the single source
    of truth both the planner and the serving engine load.

    ``families`` maps family name → :class:`FamilyCoefficients` in
    proxy seconds.  ``fit_stats`` carries provenance per family
    (observation count, RMSE, which coefficients fell back to hand-set
    defaults because their feature column never varied).  Consumption:

    * :meth:`model_profiles` → the ``profiles`` dict of
      ``ExecutionState`` (switch costs for ``CostModel``/``Scorer``/
      admission floors);
    * :meth:`cost_params` → the :class:`~repro.core.costs.CostParams`
      of ``CostModel``/``FrontierPlanner``/executors (transfer and
      prefix-saving scales);
    * the serving engine's emulated switch sleeps
      (:class:`repro.serving.engine.ServingEngine`), with
      :meth:`assert_consistent` guaranteeing engine and planner read
      identical constants.

    The class is frozen: a loaded profile is immutable configuration,
    so every consumer sees the same constants for the whole run.
    """
    families: Mapping[str, FamilyCoefficients]
    version: int = PROFILE_VERSION
    source: str = "hand-set"
    fit_stats: Mapping[str, Mapping] = dataclasses.field(
        default_factory=dict)

    # -- construction ----------------------------------------------------
    @classmethod
    def hand_set(cls, defaults: Optional[Mapping[str, ModelProfile]] = None,
                 params: Optional[CostParams] = None) -> "CalibrationProfile":
        """The identity profile: hand-set constants repackaged.

        Loading it reproduces the uncalibrated system exactly
        (``model_profiles()`` returns the defaults unchanged,
        ``cost_params()`` returns the given params unchanged) — the
        baseline every fitted profile is compared against.
        """
        defaults = defaults or DEFAULT_PROFILES
        params = params or CostParams()
        fams = {}
        for fam, (sw, pf, dc) in _family_means(defaults).items():
            fams[fam] = FamilyCoefficients(
                base=0.0, prefill=pf, decode=dc, switch=sw,
                transfer=REFERENCE_BETA * params.transfer_scale,
                prefix_saving=params.prefix_saving)
        return cls(families=fams, source="hand-set")

    def perturbed(self, *, switch_mul: float = 1.0,
                  prefill_mul: float = 1.0, decode_mul: float = 1.0,
                  transfer_mul: float = 1.0,
                  prefix_saving: Optional[float] = None,
                  base: Optional[float] = None,
                  source: str = "synthetic-truth") -> "CalibrationProfile":
        """Uniformly-perturbed copy — the synthetic ground truth of the
        fit round-trip harness (``sched_bench --calibrate``,
        ``tests/test_calibration.py``): generate a trace from the
        perturbed profile, fit, and the multipliers must be recovered.
        """
        fams = {}
        for f, c in self.families.items():
            fams[f] = FamilyCoefficients(
                base=c.base if base is None else base,
                prefill=c.prefill * prefill_mul,
                decode=c.decode * decode_mul,
                switch=c.switch * switch_mul,
                transfer=c.transfer * transfer_mul,
                prefix_saving=(c.prefix_saving if prefix_saving is None
                               else prefix_saving))
        return CalibrationProfile(families=fams, source=source)

    # -- consumption -----------------------------------------------------
    def model_profiles(self, defaults: Optional[Mapping[str, ModelProfile]]
                       = None) -> dict[str, ModelProfile]:
        """Per-model profiles with this profile's family coefficients
        applied.

        Each model's hand-set switch/prefill/decode values are rescaled
        by ``family_fit / family_hand_set_mean``, preserving the
        within-family ratios between model sizes while calibrating the
        family-level magnitude.  Models of families absent from the
        profile pass through unchanged.  Feed the result to
        ``fresh_state(cluster, profiles=...)``.
        """
        defaults = defaults or DEFAULT_PROFILES
        means = _family_means(defaults)
        out: dict[str, ModelProfile] = {}
        for name, prof in defaults.items():
            fam = self.families.get(prof.family)
            if fam is None:
                out[name] = prof
                continue
            sw0, pf0, dc0 = means[prof.family]
            out[name] = dataclasses.replace(
                prof,
                switch_cost=prof.switch_cost * _ratio(fam.switch, sw0),
                prefill_coef=prof.prefill_coef * _ratio(fam.prefill, pf0),
                decode_coef=prof.decode_coef * _ratio(fam.decode, dc0))
        return out

    def cost_params(self, base: Optional[CostParams] = None
                    ) -> CostParams:
        """Global :class:`CostParams` with this profile's
        observation-weighted transfer scale and prefix saving lowered
        onto them.

        ``CostParams`` is global while the profile is per-family, so
        the per-family transfer and prefix-saving fits are collapsed to
        a mean weighted by each family's observation count (uniform
        when no fit stats are recorded — e.g. the hand-set profile).
        """
        base = base or CostParams()
        if not self.families:
            return base
        w_tr, w_ps, w_tot = 0.0, 0.0, 0.0
        for fam, c in self.families.items():
            w = float(self.fit_stats.get(fam, {}).get("n_obs", 1.0))
            w_tr += w * c.transfer
            w_ps += w * c.prefix_saving
            w_tot += w
        return dataclasses.replace(
            base,
            transfer_scale=(w_tr / w_tot) / REFERENCE_BETA,
            prefix_saving=w_ps / w_tot)

    def assert_consistent(self, profiles: Mapping[str, ModelProfile],
                          rtol: float = 1e-9) -> None:
        """Raise ``ValueError`` unless ``profiles`` (typically
        ``ExecutionState.profiles``, i.e. what the planner prices)
        matches this profile's :meth:`model_profiles` output.

        Called by the serving engine at profile-load time so
        engine-emulated switch durations and planner switch costs can
        never silently diverge again (the pre-calibration TODO this
        subsystem retires).
        """
        expect = self.model_profiles()
        for name, prof in profiles.items():
            exp = expect.get(name)
            if exp is None:
                continue
            for field in ("switch_cost", "prefill_coef", "decode_coef"):
                a, b = getattr(prof, field), getattr(exp, field)
                if not math.isclose(a, b, rel_tol=rtol, abs_tol=1e-12):
                    raise ValueError(
                        f"calibration mismatch: {name}.{field} is {a} "
                        f"in the execution state but the loaded profile "
                        f"({self.source}) expects {b} — engine and "
                        f"planner must load the same CalibrationProfile")

    def predict(self, obs: StageObservation) -> float:
        """Predicted wall seconds for one observation under this
        profile's coefficients for the observation's family."""
        c = self.families.get(obs.family)
        if c is None:
            raise KeyError(f"no coefficients for family {obs.family!r}")
        return predict_wall(c, obs)

    # -- serialization ---------------------------------------------------
    def to_json(self) -> str:
        """Serialize to the versioned JSON document CI archives next to
        ``BENCH_sched.json``."""
        return json.dumps({
            "version": self.version,
            "source": self.source,
            "families": {f: c.as_dict()
                         for f, c in sorted(self.families.items())},
            "fit_stats": {f: dict(s)
                          for f, s in sorted(self.fit_stats.items())},
        }, indent=2) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "CalibrationProfile":
        """Inverse of :meth:`to_json`; rejects unknown schema versions."""
        doc = json.loads(text)
        version = int(doc.get("version", -1))
        if version != PROFILE_VERSION:
            raise ValueError(
                f"unsupported CalibrationProfile version {version} "
                f"(expected {PROFILE_VERSION})")
        fams = {f: FamilyCoefficients(**c)
                for f, c in doc.get("families", {}).items()}
        return cls(families=fams, version=version,
                   source=doc.get("source", "unknown"),
                   fit_stats=doc.get("fit_stats", {}))

    def save(self, path) -> Path:
        """Write :meth:`to_json` to ``path``; returns the path."""
        path = Path(path)
        path.write_text(self.to_json())
        return path

    @classmethod
    def load(cls, path) -> "CalibrationProfile":
        """Read a profile previously written by :meth:`save`."""
        return cls.from_json(Path(path).read_text())


def _ratio(fit: float, ref: float) -> float:
    """Safe ``fit / ref`` rescale factor (1.0 when the reference is 0)."""
    return fit / ref if ref > 0 else 1.0


# ---------------------------------------------------------------------------
# least-squares fitting
# ---------------------------------------------------------------------------

#: Design-matrix column order; index i's coefficient lands in the
#: matching :class:`FamilyCoefficients` slot (the last column carries
#: the combined ``prefill·prefix_saving`` product — see
#: :func:`_design_matrix`).
_COLUMNS = ("base", "prefill", "decode", "switch", "transfer",
            "prefix_combined")


def _design_matrix(group: Sequence[StageObservation]
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Feature matrix ``X`` and target ``y`` for one family's
    observations.

    The duration model (:func:`predict_wall`) is bilinear in
    ``(prefill, prefix_saving)``; substituting the combined coefficient
    ``c5 = prefill · prefix_saving`` makes it linear — the fitter
    solves for ``c5`` and divides by the fitted prefill afterwards.
    """
    X = np.empty((len(group), len(_COLUMNS)))
    y = np.empty(len(group))
    for i, o in enumerate(group):
        q = o.queries / max(o.speed, 1e-9)
        pk = o.prompt_tokens / 1000.0
        ok = o.output_tokens / 1000.0
        X[i] = (q, q * pk, q * ok, o.switches, o.transfer_ktokens,
                -o.prefix_fraction * q * pk)
        y[i] = o.wall_s
    return X, y


def fit_profile(observations: Iterable[StageObservation], *,
                time_scale: float = 1.0,
                defaults: Optional[Mapping[str, ModelProfile]] = None,
                base_params: Optional[CostParams] = None,
                source: str = "fit:engine-trace") -> CalibrationProfile:
    """Fit a :class:`CalibrationProfile` from measured stage traces.

    Groups observations by model family and solves one least-squares
    problem per group over the :func:`_design_matrix` features.
    ``time_scale`` is the measurement-frame scale (wall seconds per
    proxy second — tiny test models run orders of magnitude faster than
    the 7–14B profiles the proxy costs describe); fitted coefficients
    are divided by it so the profile is always in proxy units.

    Robustness: feature columns that cannot be identified from the
    trace are dropped from the solve, and their coefficients fall back
    to the hand-set defaults, recorded per family in
    ``fit_stats[family]["defaulted"]`` (with the collinear subset also
    under ``"collinear"``) so provenance is never silent.  Two cases:

    * **no variation** — e.g. a trace that never moved tokens across
      devices cannot identify ``transfer``;
    * **collinearity** — e.g. an engine run with FIXED prompt/output
      lengths makes the base/prefill/decode columns exactly
      proportional (all three scale with ``q``); a plain least-squares
      solve would split the combined per-query rate across them
      arbitrarily and silently distort the planner's prefill-vs-decode
      pricing.  Columns are admitted greedily in
      :data:`_COLUMNS` order only while they increase the (normalized)
      design-matrix rank, so a degenerate trace keeps the hand-set
      values for the dropped coefficients instead of absorbing an
      arbitrary split.  Identifying prefill and decode separately
      requires a trace that VARIES prompt and generation lengths.

    Fitted coefficients are clipped at zero (every physical
    coefficient is nonnegative) and ``prefix_saving`` to ``[0, 1]``.
    """
    defaults = defaults or DEFAULT_PROFILES
    hand = CalibrationProfile.hand_set(defaults, base_params)
    groups: dict[str, list[StageObservation]] = {}
    for o in observations:
        groups.setdefault(o.family, []).append(o)
    fams: dict[str, FamilyCoefficients] = {}
    stats: dict[str, dict] = {}
    for fam, group in sorted(groups.items()):
        X, y = _design_matrix(group)
        y = y / time_scale
        live, collinear = _identifiable_columns(X)
        coef = np.zeros(X.shape[1])
        if live:
            sol, *_ = np.linalg.lstsq(X[:, live], y, rcond=None)
            coef[live] = np.maximum(0.0, sol)
        fallback = hand.families.get(
            fam, FamilyCoefficients(0.0, 0.0, 0.0, 0.0,
                                    REFERENCE_BETA, 0.9))
        defaulted = []
        vals = dict(zip(_COLUMNS, coef))
        for j, name in enumerate(_COLUMNS):
            if j in live:
                continue
            defaulted.append(name)
            if name == "prefix_combined":
                vals[name] = fallback.prefill * fallback.prefix_saving
            else:
                vals[name] = getattr(fallback, name)
        prefill = vals["prefill"]
        saving = (min(1.0, vals["prefix_combined"] / prefill)
                  if prefill > 1e-12 else fallback.prefix_saving)
        fams[fam] = FamilyCoefficients(
            base=vals["base"], prefill=prefill, decode=vals["decode"],
            switch=vals["switch"], transfer=vals["transfer"],
            prefix_saving=saving)
        resid = X @ np.array([vals[c] for c in _COLUMNS]) - y
        stats[fam] = {
            "n_obs": len(group),
            "rmse": float(np.sqrt(np.mean(resid ** 2))),
            "defaulted": defaulted,
            "collinear": [_COLUMNS[j] for j in collinear],
        }
    return CalibrationProfile(families=fams, source=source,
                              fit_stats=stats)


def _identifiable_columns(X: np.ndarray) -> tuple[list[int], list[int]]:
    """Split design-matrix columns into (identifiable, collinear).

    Zero columns (no variation at all) are neither.  Remaining columns
    are admitted greedily in order while they increase the rank of the
    norm-scaled submatrix; a column linearly dependent on the admitted
    set is classed collinear (its coefficient cannot be separated from
    theirs and must fall back to the hand-set default).
    """
    live: list[int] = []
    collinear: list[int] = []
    for j in range(X.shape[1]):
        col = X[:, j]
        norm = float(np.linalg.norm(col))
        if norm <= 1e-12:
            continue
        trial = live + [j]
        sub = X[:, trial]
        sub = sub / np.linalg.norm(sub, axis=0)
        if np.linalg.matrix_rank(sub, tol=1e-9) == len(trial):
            live.append(j)
        else:
            collinear.append(j)
    return live, collinear


def synthetic_trace(profile: CalibrationProfile, n: int, *,
                    seed: int = 0, noise: float = 0.0,
                    time_scale: float = 1.0) -> list[StageObservation]:
    """Generate a synthetic measured trace whose wall times follow
    ``profile`` exactly (up to multiplicative ``noise``).

    The fit round-trip harness: features are drawn uniformly over
    realistic ranges per family, wall seconds come from
    :func:`predict_wall` scaled into the measurement frame by
    ``time_scale``, and :func:`fit_profile` must recover the generating
    coefficients (``tests/test_calibration.py``).  Switch events are
    Bernoulli-sparse (like a steady-state serving trace, where most
    stage executions find their model resident) — noise is
    multiplicative on the TOTAL wall time, so a trace where every
    observation pays a multi-second switch would drown the millisecond
    token coefficients in switch-term noise.  Deterministic in
    ``seed``.
    """
    rng = np.random.default_rng(seed)
    fams = sorted(profile.families)
    out: list[StageObservation] = []
    for i in range(n):
        fam = fams[i % len(fams)]
        obs = StageObservation(
            model=f"{fam}-synthetic", family=fam,
            queries=int(rng.integers(1, 17)),
            prompt_tokens=float(rng.uniform(64, 2048)),
            output_tokens=float(rng.uniform(16, 512)),
            switches=int(rng.random() < 0.25),
            # warm prefixes are bimodal in practice — a stage either
            # misses (cold group) or hits on most of its queries; the
            # cold half also decorrelates the prefill and prefix
            # columns, conditioning the least-squares problem
            prefix_fraction=(0.0 if rng.random() < 0.5
                             else float(rng.uniform(0.5, 1.0))),
            transfer_ktokens=float(rng.uniform(0.0, 8.0)),
            wall_s=0.0,
            speed=float(rng.choice([0.7, 1.0])))
        wall = profile.predict(obs) * time_scale
        if noise:
            wall *= 1.0 + noise * float(rng.standard_normal())
        out.append(dataclasses.replace(obs, wall_s=max(wall, 0.0)))
    return out


def coefficient_errors(fitted: CalibrationProfile,
                       truth: CalibrationProfile) -> dict[str, float]:
    """Per-``family.coefficient`` relative errors of a fit against the
    generating truth (coefficients the fit marked as defaulted are
    skipped — they were never identifiable from the trace)."""
    out: dict[str, float] = {}
    for fam, true_c in truth.families.items():
        fit_c = fitted.families.get(fam)
        if fit_c is None:
            continue
        defaulted = set(fitted.fit_stats.get(fam, {})
                        .get("defaulted", ()))
        for name in ("base", "prefill", "decode", "switch", "transfer",
                     "prefix_saving"):
            if name in defaulted or (name == "prefix_saving"
                                     and "prefix_combined" in defaulted):
                continue
            t = getattr(true_c, name)
            f = getattr(fit_c, name)
            denom = abs(t) if abs(t) > 1e-9 else 1.0
            out[f"{fam}.{name}"] = abs(f - t) / denom
    return out


# ---------------------------------------------------------------------------
# online probe-error correction
# ---------------------------------------------------------------------------


class ProbeCorrector:
    """Online predicted-vs-observed latency correction (EWMA per model
    family) — the learned replacement for the hand-set admission
    ``probe_margin``.

    The admission probe predicts a workflow's completion latency; the
    serving executor later observes the real one.  This tracker keeps,
    per model family, an exponentially-weighted moving average of the
    ``observed / predicted`` ratio and serves it as the live probe
    margin: ``margin(family)`` starts at the hand-set ``prior`` (so an
    un-warmed corrector reproduces the static controller exactly) and
    converges toward the family's true ratio as completions arrive,
    tracking drift with time constant ``1/alpha`` observations.
    Ratios and margins are clipped to ``[min_margin, max_margin]`` so a
    single pathological observation (a near-zero prediction, a stalled
    workflow) cannot poison the estimate.
    """

    def __init__(self, prior: float = 1.5, alpha: float = 0.4,
                 min_margin: float = 0.25, max_margin: float = 16.0):
        self.prior = prior
        self.alpha = alpha
        self.min_margin = min_margin
        self.max_margin = max_margin
        self.margins: dict[str, float] = {}
        self.n_obs: dict[str, int] = {}

    def margin(self, family: str) -> float:
        """Current multiplicative probe margin for ``family`` (the
        prior until the first observation arrives)."""
        return self.margins.get(family, self.prior)

    def observe(self, family: str, predicted: float,
                observed: float) -> float:
        """Fold one completed workflow's ``(predicted, observed)``
        latency pair into the family's EWMA; returns the updated
        margin.  Non-positive predictions are ignored (nothing to form
        a ratio against)."""
        if predicted <= 1e-9 or observed < 0.0:
            return self.margin(family)
        ratio = min(self.max_margin,
                    max(self.min_margin, observed / predicted))
        cur = self.margins.get(family)
        if cur is None:
            new = ratio
        else:
            new = (1.0 - self.alpha) * cur + self.alpha * ratio
        self.margins[family] = new
        self.n_obs[family] = self.n_obs.get(family, 0) + 1
        return new

    def to_dict(self) -> dict:
        """Plain-JSON capture of the corrector: knobs plus the
        per-family EWMA margins and observation counts.  Inverse of
        :meth:`from_dict` — the scheduler snapshot embeds this so a
        restored control plane keeps its learned probe margins."""
        return {"prior": self.prior, "alpha": self.alpha,
                "min_margin": self.min_margin,
                "max_margin": self.max_margin,
                "margins": dict(self.margins),
                "n_obs": dict(self.n_obs)}

    @classmethod
    def from_dict(cls, doc: dict) -> "ProbeCorrector":
        """Rebuild a corrector from :meth:`to_dict` output."""
        c = cls(prior=doc["prior"], alpha=doc["alpha"],
                min_margin=doc["min_margin"],
                max_margin=doc["max_margin"])
        c.margins = dict(doc.get("margins") or {})
        c.n_obs = {k: int(n)
                   for k, n in (doc.get("n_obs") or {}).items()}
        return c
