"""Execution state s_t = (ρ_t, κ_t, ℓ_t, τ_t) — the object FATE preserves.

  ρ_t : model residency per device (which model is live in HBM)
  κ_t : reusable prefix-related metadata per device (prefix groups with
        warm cache state, plus the model they were built under)
  ℓ_t : device location(s) of completed stage outputs
  τ_t : next-available time per device

The state also carries bookkeeping used by the runtime (completed set,
running set, committed-but-not-finished set — Appendix A.1 notes these
implementation-level sets are suppressed in the main-text formulation).

Dirty-set protocol
------------------
Incremental wave rescoring (``Scorer.rescore_matrix``) reuses the
previous wave's frontier score tables and recomputes only the entries
whose state inputs changed.  Every mutation of per-device state must
go through the mutator methods (``set_free_at``, ``set_resident``,
``warm_prefix``), which record the touched device in a dirty-device
set.  A single-consumer caller (the planner, between its own
commit-and-advance waves on one overlay) calls
:meth:`ExecutionState.drain_dirty` to claim-and-clear the set and
passes it to the rescorer, which then patches only those devices'
warm-prefix columns.  When no claimed set is available — the first
wave of a session, or any caller that cannot guarantee it is the sole
consumer — the rescorer verifies warm state against fully re-gathered
per-signature snapshots instead, so a lost or stolen mark can never
produce stale scores.  Residency, wait times, and sibling counts are
always snapshot-diffed (clock advancement shrinks every busy device's
wait without touching the device, so marks alone could not cover
them).  ``PlanningOverlay`` starts each planning session with an
empty dirty set: its drains see exactly the devices its own estimated
placements touched.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Optional

from repro.core.devices import Cluster
from repro.core.workflow import ModelProfile, Stage, Workflow


@dataclasses.dataclass
class PrefixEntry:
    """One warm shared-prefix slot on a device: group, model, warmth."""

    group: str
    model: str
    warm_queries: int = 0          # number of queries whose prefix is warm
    last_used: float = 0.0


@dataclasses.dataclass
class ExecutionState:
    """Mutable cluster-wide execution state ``s_t``: residency (ρ),
    prefix caches (κ), output locations (ℓ), device free times (τ),
    the fault domain, and mechanism counters."""

    cluster: Cluster
    profiles: dict[str, ModelProfile]
    # ρ_t: device -> resident model alias (None = empty)
    residency: dict[int, Optional[str]] = dataclasses.field(
        default_factory=dict)
    # κ_t: device -> {group: PrefixEntry}
    prefix: dict[int, dict[str, PrefixEntry]] = dataclasses.field(
        default_factory=dict)
    # ℓ_t: (wid, sid) -> tuple of device ids holding the completed output
    # (shard execution can leave outputs on several devices)
    output_loc: dict[tuple[str, str], tuple[int, ...]] = dataclasses.field(
        default_factory=dict)
    # τ_t: device -> next free time
    free_at: dict[int, float] = dataclasses.field(default_factory=dict)
    now: float = 0.0
    # bookkeeping
    completed: set = dataclasses.field(default_factory=set)
    running: set = dataclasses.field(default_factory=set)
    committed: set = dataclasses.field(default_factory=set)
    # mechanism counters (Appendix C.2 proxies)
    cross_device_edges: int = 0
    prefix_hits_est: float = 0.0
    same_model_continuations: int = 0
    total_tasks: int = 0
    model_switches: int = 0
    # fault domain: devices currently out of the live set (crashed or
    # quarantined), and a monotone epoch bumped on every membership
    # change so per-cluster caches (admission floors, deadlines) know
    # to invalidate.  Empty set / epoch 0 on every fault-free run.
    down: set = dataclasses.field(default_factory=set)
    fault_epoch: int = 0

    def __post_init__(self) -> None:
        for d in self.cluster.ids():
            self.residency.setdefault(d, None)
            self.prefix.setdefault(d, {})
            self.free_at.setdefault(d, 0.0)
        self._dirty_devices: set[int] = set()

    # -- dirty-set protocol (see module docstring) -----------------------
    def touch_device(self, device: int) -> None:
        """Mark ``device`` dirty for the delta-rescoring consumer."""
        self._dirty_devices.add(device)

    def drain_dirty(self) -> set[int]:
        """Claim-and-clear the set of devices mutated since last drain."""
        out = self._dirty_devices
        self._dirty_devices = set()
        return out

    # -- ρ --------------------------------------------------------------
    def resident_model(self, device: int) -> Optional[str]:
        """Model alias currently resident on ``device`` (None = empty)."""
        return self.residency.get(device)

    def is_resident(self, model: str, device: int) -> bool:
        """Whether ``model`` is the resident model on ``device``."""
        return self.residency.get(device) == model

    def set_resident(self, device: int, model: str) -> None:
        """Load ``model`` onto ``device``, counting the switch and
        dropping prefix entries invalidated by the swap."""
        if self.residency.get(device) != model:
            self.model_switches += 1
            # switching a model invalidates that device's prefix cache
            self.prefix[device] = {
                g: e for g, e in self.prefix[device].items()
                if e.model == model}
        self.residency[device] = model
        self.touch_device(device)

    # -- κ --------------------------------------------------------------
    def prefix_overlap(self, stage: Stage, device: int,
                       num_queries: int) -> float:
        """Overlap(grp(v), d, s_t): fraction of the stage's queries whose
        shared prefix is warm on the device (0..1)."""
        if not stage.cache_reuse or stage.prefix_group is None:
            return 0.0
        e = self.prefix.get(device, {}).get(stage.prefix_group)
        if e is None or e.model != stage.model:
            return 0.0
        return (min(1.0, e.warm_queries / max(num_queries, 1))
                * stage.shared_fraction)

    def warm_prefix(self, device: int, group: Optional[str], model: str,
                    queries: int, now: float) -> None:
        """Record that ``queries`` of prefix ``group`` are warm on
        ``device`` under ``model`` (monotone in query count)."""
        if group is None:
            return
        slot = self.prefix[device].setdefault(
            group, PrefixEntry(group, model))
        if slot.model != model:
            slot.model = model
            slot.warm_queries = 0
        slot.warm_queries = max(slot.warm_queries, queries)
        slot.last_used = now
        self.touch_device(device)

    def revoke_prefix(self, device: int, group: Optional[str],
                      model: str) -> None:
        """Forfeit the warm-prefix entry for ``group`` on ``device``
        (only if it is held under ``model``): the κ credit-back of a
        killed stage attempt, whose :meth:`warm_prefix` recorded cache
        state that never materialized.  Conservative — a prior
        attempt's genuinely-warm entry for the same group is forfeited
        with it, which only under-estimates future benefit.  Marks the
        device dirty like every other state mutator."""
        if group is None:
            return
        e = self.prefix.get(device, {}).get(group)
        if e is not None and e.model == model:
            del self.prefix[device][group]
            self.touch_device(device)

    # -- ℓ --------------------------------------------------------------
    def parent_locations(self, wid: str, stage: Stage) -> dict[str, tuple]:
        """Map each parent stage id to the devices holding its output."""
        return {p: self.output_loc.get((wid, p), ()) for p in stage.parents}

    def parent_on_device(self, wid: str, stage: Stage, device: int) -> int:
        """Number of parents whose output is local to ``device``."""
        k = 0
        for p in stage.parents:
            if device in self.output_loc.get((wid, p), ()):
                k += 1
        return k

    # -- τ --------------------------------------------------------------
    def set_free_at(self, device: int, t: float) -> None:
        """Set device ``d``'s next-free time τ_d and mark it dirty."""
        self.free_at[device] = t
        self.touch_device(device)

    def device_free(self, device: int) -> float:
        """Next-free time τ_d for ``device`` (0.0 if never used)."""
        return self.free_at.get(device, 0.0)

    def wait_time(self, device: int, t: Optional[float] = None) -> float:
        """Queueing delay on ``device`` at time ``t`` (default: now)."""
        t = self.now if t is None else t
        return max(0.0, self.device_free(device) - t)

    def backlog_seconds(self) -> float:
        """Total committed busy time still queued across the cluster:
        ``Σ_d max(0, τ_d − now)``.  The admission controller's analytic
        probe divides this by the device count to estimate how long a
        new arrival waits before its first stage can start."""
        return sum(self.wait_time(d) for d in self.cluster.ids())

    def residency_groups(self) -> dict[Optional[str], list[int]]:
        """Device ids grouped by currently-resident model.

        Devices with no resident model (cold, or wiped by a fail-stop
        crash) land under the ``None`` key.  Group membership follows
        the cluster's canonical id order, so for a fixed residency map
        the grouping is deterministic.  The hierarchical frontier
        partitioner uses this to build affinity-aware device pools:
        keeping same-model devices in one pool preserves the colocation
        and prefix-cache bonuses that the planner score rewards.
        """
        out: dict[Optional[str], list[int]] = {}
        for d in self.cluster.ids():
            out.setdefault(self.residency.get(d), []).append(d)
        return out

    # -- fault domain -----------------------------------------------------
    def live_ids(self) -> list[int]:
        """Device ids currently in the live set (cluster minus down)."""
        if not self.down:
            return self.cluster.ids()
        return [d for d in self.cluster.ids() if d not in self.down]

    @property
    def n_live(self) -> int:
        """Number of live devices (``cluster.n`` minus downed)."""
        return self.cluster.n - len(self.down)

    def mark_down(self, device: int, *, wipe: bool = True) -> None:
        """Evict ``device`` from the live set (crash or quarantine).

        ``wipe=True`` (fail-stop crash) destroys the device's residency
        ρ, warm-prefix table κ, and queued busy time τ — HBM contents do
        not survive a crash.  ``wipe=False`` (quarantine) keeps state
        warm; the device merely stops receiving new work.  Either way
        the device is marked dirty so delta rescoring repairs its
        columns, and the fault epoch is bumped so dependent caches
        invalidate.
        """
        self.down.add(device)
        self.fault_epoch += 1
        if wipe:
            self.residency[device] = None
            self.prefix[device] = {}
            self.free_at[device] = self.now
        self.touch_device(device)

    def mark_up(self, device: int) -> None:
        """Return ``device`` to the live set (recovery)."""
        self.down.discard(device)
        self.fault_epoch += 1
        self.touch_device(device)

    # -- planning views --------------------------------------------------
    def overlay(self) -> "PlanningOverlay":
        """Copy-on-write view for commit-and-advance planning."""
        return PlanningOverlay(self)

    # -- serialization ---------------------------------------------------
    def to_dict(self) -> dict:
        """Plain-JSON document of the full state (ρ/κ/ℓ/τ, clock,
        bookkeeping sets, counters, fault domain, dirty set).

        Dict iteration orders are preserved verbatim — the restored
        state must replay bit-identically, so insertion order (which
        downstream float accumulation can observe) is part of the
        contract.  The cluster and profiles are NOT embedded; the
        owning snapshot carries them (:meth:`from_dict` takes both).
        """
        def _key(k):
            return list(k) if isinstance(k, tuple) else k
        return {
            "now": self.now,
            "residency": {str(d): m for d, m in self.residency.items()},
            "prefix": {str(d): {g: dataclasses.asdict(e)
                                for g, e in tbl.items()}
                       for d, tbl in self.prefix.items()},
            "output_loc": [[wid, sid, list(devs)]
                           for (wid, sid), devs
                           in self.output_loc.items()],
            "free_at": {str(d): t for d, t in self.free_at.items()},
            "completed": [_key(k) for k in sorted(self.completed)],
            "running": [_key(k) for k in sorted(self.running)],
            "committed": [_key(k) for k in sorted(self.committed)],
            "cross_device_edges": self.cross_device_edges,
            "prefix_hits_est": self.prefix_hits_est,
            "same_model_continuations": self.same_model_continuations,
            "total_tasks": self.total_tasks,
            "model_switches": self.model_switches,
            "down": sorted(self.down),
            "fault_epoch": self.fault_epoch,
            "dirty": sorted(self._dirty_devices),
        }

    @classmethod
    def from_dict(cls, doc, cluster: Cluster,
                  profiles: dict) -> "ExecutionState":
        """Rebuild a state from :meth:`to_dict` output over the given
        cluster and model-profile table."""
        def _key(k):
            return tuple(k) if isinstance(k, list) else k
        st = cls(cluster=cluster, profiles=dict(profiles))
        st.now = doc["now"]
        st.residency = {int(d): m
                        for d, m in doc["residency"].items()}
        st.prefix = {int(d): {g: PrefixEntry(**e)
                              for g, e in tbl.items()}
                     for d, tbl in doc["prefix"].items()}
        st.output_loc = {(wid, sid): tuple(devs)
                         for wid, sid, devs in doc["output_loc"]}
        st.free_at = {int(d): t for d, t in doc["free_at"].items()}
        st.completed = {_key(k) for k in doc["completed"]}
        st.running = {_key(k) for k in doc["running"]}
        st.committed = {_key(k) for k in doc["committed"]}
        st.cross_device_edges = doc["cross_device_edges"]
        st.prefix_hits_est = doc["prefix_hits_est"]
        st.same_model_continuations = doc["same_model_continuations"]
        st.total_tasks = doc["total_tasks"]
        st.model_switches = doc["model_switches"]
        st.down = set(doc["down"])
        st.fault_epoch = doc["fault_epoch"]
        st._dirty_devices = set(doc.get("dirty", ()))
        return st


class _LayeredSet:
    """Set overlay: additions land in a private layer, lookups fall
    through to the (unmodified) base set."""
    __slots__ = ("_base", "_added")

    def __init__(self, base: set):
        self._base = base
        self._added: set = set()

    def add(self, x) -> None:
        if x not in self._base:
            self._added.add(x)

    def __contains__(self, x) -> bool:
        return x in self._added or x in self._base

    def __len__(self) -> int:
        return len(self._base) + len(self._added)

    def __iter__(self):
        yield from self._base
        yield from self._added


class PlanningOverlay(ExecutionState):
    """Copy-on-write overlay over an :class:`ExecutionState`.

    The frontier planner simulates placement effects between waves
    (Algorithm 2's commit-and-advance) on a scratch state.  The seed
    implementation deep-copied the nested per-device prefix tables on
    every ``plan()`` call; the overlay copies only the flat top-level
    dicts (C-speed, device-count sized) and shares the inner prefix
    dicts with the base until a device is first written, at which point
    that device's table (and its entries, mutated in place by
    ``warm_prefix``) is copied.
    """

    def __init__(self, base: ExecutionState):
        # deliberately NOT calling the dataclass __init__: every field
        # is re-bound to an overlay view of the base state.
        self.cluster = base.cluster
        self.profiles = base.profiles
        self.residency = dict(base.residency)
        self.prefix = dict(base.prefix)        # inner dicts shared (COW)
        self.output_loc = dict(base.output_loc)
        self.free_at = dict(base.free_at)
        self.now = base.now
        self.completed = _LayeredSet(base.completed)
        self.running = _LayeredSet(base.running)
        self.committed = _LayeredSet(base.committed)
        self.cross_device_edges = base.cross_device_edges
        self.prefix_hits_est = base.prefix_hits_est
        self.same_model_continuations = base.same_model_continuations
        self.total_tasks = base.total_tasks
        self.model_switches = base.model_switches
        self.down = set(base.down)
        self.fault_epoch = base.fault_epoch
        self._base = base
        self._prefix_own: set[int] = set()
        # fresh, overlay-local dirty set: it records ONLY this planning
        # session's estimated placements, so the planner can trust it
        # for intra-session wave patching (single consumer by
        # construction).  Base-state mutations are NOT claimed — the
        # session's first rescore verifies warm state against full
        # re-gathered snapshots instead (see Scorer.rescore_matrix), so
        # constructing an overlay never perturbs other consumers.
        self._dirty_devices: set[int] = set()

    def _own_prefix(self, device: int) -> None:
        if device not in self._prefix_own:
            src = self._base.prefix.get(device, {})
            self.prefix[device] = {g: copy.copy(e) for g, e in src.items()}
            self._prefix_own.add(device)

    def warm_prefix(self, device: int, group: Optional[str], model: str,
                    queries: int, now: float) -> None:
        """Copy-on-write wrapper: own the device's prefix map, then
        apply the base-class warm-prefix update to the overlay only."""
        if group is None:
            return
        self._own_prefix(device)
        super().warm_prefix(device, group, model, queries, now)

    def set_resident(self, device: int, model: str) -> None:
        """Copy-on-write wrapper around residency switching, so the
        prefix-invalidation side effect stays overlay-local."""
        self._own_prefix(device)
        super().set_resident(device, model)

    def revoke_prefix(self, device: int, group: Optional[str],
                      model: str) -> None:
        """Copy-on-write wrapper: the forfeit stays overlay-local."""
        if group is None:
            return
        self._own_prefix(device)
        super().revoke_prefix(device, group, model)
