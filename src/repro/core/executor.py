"""Commit-and-advance workflow executor (paper Algorithm 2).

A discrete-event runtime over the proxy cost model (the paper's own
evaluation substrate, Appendix C.1): policies commit Placements into a
committed action pool; the executor issues dependency-ready actions as
their devices free, updates the execution state (ρ, κ, ℓ, τ) on
completion, and invokes the policy again when the pool has no feasible
ready action.

Per-query completion times are tracked through shard partitions so P95
query latency is measurable (queries in different shards of the sink
stage finish at different times).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional, Protocol

from repro.core.costs import CostModel, CostParams
from repro.core.planner import Placement
from repro.core.state import ExecutionState
from repro.core.workflow import ModelProfile, Stage, Workflow


class Policy(Protocol):
    name: str

    def plan(self, wf: Workflow, state: ExecutionState,
             ready: list[str]) -> list[Placement]:
        ...


@dataclasses.dataclass
class StageRun:
    placement: Placement
    start: float
    finish: float                       # max over shards
    shard_finish: tuple[float, ...]
    switched: tuple[bool, ...]


@dataclasses.dataclass
class RunResult:
    wid: str
    makespan: float
    query_completion: list[float]       # per query
    stage_runs: dict[str, StageRun]
    # mechanism proxies (Appendix C.2), per workflow
    cross_device_edges: int
    prefix_hits_est: float
    same_model_continuations: float
    total_tasks: int
    model_switches: int

    @property
    def p95(self) -> float:
        xs = sorted(self.query_completion)
        if not xs:
            return self.makespan
        idx = max(0, min(len(xs) - 1, int(round(0.95 * (len(xs) - 1)))))
        return xs[idx]


class WorkflowExecutor:
    def __init__(self, state: ExecutionState,
                 cost_params: Optional[CostParams] = None):
        self.state = state
        self.cm = CostModel(state, cost_params)

    # ------------------------------------------------------------------
    def run(self, wf: Workflow, policy: Policy) -> RunResult:
        state = self.state
        cm = self.cm
        wf.validate()
        n_stages = len(wf.stages)
        committed: list[Placement] = []
        issued: set[str] = set()
        completed: set[str] = set()
        finish_heap: list[tuple[float, str]] = []
        runs: dict[str, StageRun] = {}
        query_done: dict[int, float] = {}
        edge_cross = 0
        prefix_hits = 0.0
        same_model = 0.0
        switches_before = state.model_switches

        def ready_uncommitted() -> list[str]:
            in_pool = {p.sid for p in committed}
            return [sid for sid in wf.topo_order
                    if sid not in completed and sid not in issued
                    and sid not in in_pool
                    and all(p in completed
                            for p in wf.stages[sid].parents)]

        def issuable(p: Placement) -> bool:
            st = wf.stages[p.sid]
            if any(par not in completed for par in st.parents):
                return False
            return all(state.device_free(d) <= state.now + 1e-12
                       for d in p.devices)

        def issue(p: Placement) -> None:
            nonlocal edge_cross, prefix_hits, same_model
            st = wf.stages[p.sid]
            primary = p.devices[0]
            # mechanism proxies (measured at issue, before state update)
            for par in st.parents:
                locs = state.output_loc.get((wf.wid, par), ())
                if locs and primary not in locs:
                    edge_cross += 1
            ov = state.prefix_overlap(st, primary, wf.num_queries)
            prefix_hits += ov
            res_frac = sum(
                1 for d in p.devices if state.is_resident(st.model, d)
            ) / len(p.devices)
            same_model += res_frac

            shard_fin = []
            switched = []
            for d, nq in zip(p.devices, p.shard_sizes):
                was_resident = state.is_resident(st.model, d)
                t0 = max(state.now, state.device_free(d))
                dur = cm.base_cost(st, d, nq)
                dur += cm.switch_cost(st, d)
                dur += cm.transfer_cost(wf, st, d, nq)
                dur -= cm.prefix_benefit(st, d, nq)
                dur -= cm.locality_benefit(wf, st, d, nq)
                if len(p.devices) > 1:
                    dur += (cm.base_cost(st, d, wf.num_queries)
                            * cm.p.shard_overhead)
                dur = max(dur, 1e-6)
                fin = t0 + dur
                state.free_at[d] = fin
                state.set_resident(d, st.model)
                if st.keep_cache:
                    state.warm_prefix(d, st.prefix_group, st.model, nq,
                                      fin)
                shard_fin.append(fin)
                switched.append(not was_resident)
            fin_all = max(shard_fin)
            runs[p.sid] = StageRun(p, state.now, fin_all,
                                   tuple(shard_fin), tuple(switched))
            issued.add(p.sid)
            heapq.heappush(finish_heap, (fin_all, p.sid))

        # main loop -----------------------------------------------------
        guard = 0
        while len(completed) < n_stages:
            guard += 1
            if guard > 40 * n_stages + 1000:
                raise RuntimeError(
                    f"{wf.wid}: executor stalled ({policy.name})")
            # 1. issue every committed action that can start now
            progress = True
            while progress:
                progress = False
                for p in list(committed):
                    if p.sid in issued or p.sid in completed:
                        committed.remove(p)
                        continue
                    if issuable(p):
                        committed.remove(p)
                        issue(p)
                        progress = True
            # 2. plan if the pool has no feasible ready action
            ready = ready_uncommitted()
            pool_feasible = any(
                all(par in completed for par in wf.stages[p.sid].parents)
                for p in committed)
            if ready and not pool_feasible:
                new = policy.plan(wf, state, ready)
                if not new:
                    # liveness fallback: greedily place the single best
                    # ready stage by state-corrected cost
                    sid = ready[0]
                    st = wf.stages[sid]
                    devs = (list(st.eligible) if st.eligible
                            else state.cluster.ids())
                    best = min(devs, key=lambda d: (
                        cm.effective_cost(wf, st, d, wf.num_queries)
                        + state.wait_time(d)))
                    new = [Placement(wf.wid, sid, (best,),
                                     (wf.num_queries,))]
                committed.extend(new)
                continue
            # 3. advance time to the next completion
            if finish_heap:
                t, sid = heapq.heappop(finish_heap)
                state.now = max(state.now, t)
                completed.add(sid)
                state.completed.add((wf.wid, sid))
                st = wf.stages[sid]
                run = runs[sid]
                state.output_loc[(wf.wid, sid)] = run.placement.devices
                # per-query completion at sink stages
                if not st.children:
                    qid = 0
                    for dfin, nq in zip(run.shard_finish,
                                        run.placement.shard_sizes):
                        for _ in range(nq):
                            query_done[qid] = max(
                                query_done.get(qid, 0.0), dfin)
                            qid += 1
            elif not committed and not ready_uncommitted():
                raise RuntimeError(f"{wf.wid}: deadlock ({policy.name})")

        makespan = max((r.finish for r in runs.values()), default=0.0)
        qdone = [query_done.get(i, makespan)
                 for i in range(wf.num_queries)]
        return RunResult(
            wid=wf.wid, makespan=makespan, query_completion=qdone,
            stage_runs=runs, cross_device_edges=edge_cross,
            prefix_hits_est=prefix_hits,
            same_model_continuations=same_model,
            total_tasks=n_stages,
            model_switches=state.model_switches - switches_before)


def fresh_state(cluster, profiles=None) -> ExecutionState:
    from repro.core.workflow import DEFAULT_PROFILES
    return ExecutionState(cluster=cluster,
                          profiles=dict(profiles or DEFAULT_PROFILES))
