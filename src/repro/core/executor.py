"""Commit-and-advance workflow executor (paper Algorithm 2).

A discrete-event runtime over the proxy cost model (the paper's own
evaluation substrate, Appendix C.1): policies commit Placements into a
committed action pool; the executor issues dependency-ready actions as
their devices free, updates the execution state (ρ, κ, ℓ, τ) on
completion, and invokes the policy again when the pool has no feasible
ready action.

Per-query completion times are tracked through shard partitions so P95
query latency is measurable (queries in different shards of the sink
stage finish at different times).

Two runtimes share the issue/completion machinery:

* :class:`WorkflowExecutor` — the paper's single-workflow batch
  setting: one DAG owns the cluster until it drains.
* :class:`ServingExecutor` — the serving setting: workflows arrive
  over time (e.g. from a Poisson trace), a :class:`SharedFrontier`
  merges the ready sets of every in-flight DAG, and the policy replans
  the merged frontier on every completion, so cross-workflow contention
  for residency/prefix state is decided by one placement problem.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional, Protocol, Sequence

from repro.core.admission import AdmissionController, SLOConfig
from repro.core.costs import CostModel, CostParams
from repro.core.planner import Placement
from repro.core.state import ExecutionState
from repro.core.workflow import ModelProfile, Stage, StageKey, Workflow


class Policy(Protocol):
    """Scheduling policy interface: map a ready frontier to placements.

    Policies may additionally implement ``plan_shared(workflows,
    state, ready)`` (merged multi-workflow planning) and
    ``forget_workflow(wid)`` (cache release on retirement); the serving
    runtime dispatches on their presence.
    """

    name: str

    def plan(self, wf: Workflow, state: ExecutionState,
             ready: list[str]) -> list[Placement]:
        """Return committed placements for (a subset of) ``ready``."""
        ...


def nearest_rank_p95(xs: Sequence[float],
                     default: float = float("nan")) -> float:
    """Nearest-rank 95th percentile of ``xs`` (``default`` if empty).

    The single percentile convention shared by batch results, serving
    stats, and the benchmark metrics — keep them in sync by calling
    this, not by re-deriving the index.
    """
    s = sorted(xs)
    if not s:
        return default
    idx = max(0, min(len(s) - 1, int(round(0.95 * (len(s) - 1)))))
    return s[idx]


@dataclasses.dataclass
class StageRun:
    """One issued stage execution: its placement and timing record."""
    placement: Placement
    start: float
    finish: float                       # max over shards
    shard_finish: tuple[float, ...]
    switched: tuple[bool, ...]


@dataclasses.dataclass
class RunResult:
    """Outcome of one single-workflow batch run (paper Table 1 row)."""
    wid: str
    makespan: float
    query_completion: list[float]       # per query
    stage_runs: dict[str, StageRun]
    # mechanism proxies (Appendix C.2), per workflow
    cross_device_edges: int
    prefix_hits_est: float
    same_model_continuations: float
    total_tasks: int
    model_switches: int

    @property
    def p95(self) -> float:
        """95th-percentile per-query completion time (nearest-rank)."""
        return nearest_rank_p95(self.query_completion,
                                default=self.makespan)


def _greedy_fallback(state: ExecutionState, cm: CostModel, wf: Workflow,
                     sid: str) -> Placement:
    """Liveness fallback shared by both runtimes: place one ready stage
    on the device minimizing state-corrected cost plus queueing."""
    st = wf.stages[sid]
    devs = list(st.eligible) if st.eligible else state.cluster.ids()
    best = min(devs, key=lambda d: (
        cm.effective_cost(wf, st, d, wf.num_queries)
        + state.wait_time(d)))
    return Placement(wf.wid, sid, (best,), (wf.num_queries,))


def _issue_shards(state: ExecutionState, cm: CostModel, wf: Workflow,
                  st: Stage, p: Placement
                  ) -> tuple[list[float], list[bool]]:
    """Start one placement's shards: per-device state-corrected duration
    (base + switch + transfer − prefix − locality, plus coordination
    overhead when sharded), applied to (ρ, κ, τ) through the dirty-set
    mutators.  The single duration model shared by both runtimes."""
    shard_fin: list[float] = []
    switched: list[bool] = []
    for d, nq in zip(p.devices, p.shard_sizes):
        was_resident = state.is_resident(st.model, d)
        t0 = max(state.now, state.device_free(d))
        dur = cm.base_cost(st, d, nq)
        dur += cm.switch_cost(st, d)
        dur += cm.transfer_cost(wf, st, d, nq)
        dur -= cm.prefix_benefit(st, d, nq)
        dur -= cm.locality_benefit(wf, st, d, nq)
        if len(p.devices) > 1:
            dur += (cm.base_cost(st, d, wf.num_queries)
                    * cm.p.shard_overhead)
        dur = max(dur, 1e-6)
        fin = t0 + dur
        state.set_free_at(d, fin)
        state.set_resident(d, st.model)
        if st.keep_cache:
            state.warm_prefix(d, st.prefix_group, st.model, nq, fin)
        shard_fin.append(fin)
        switched.append(not was_resident)
    return shard_fin, switched


class WorkflowExecutor:
    """Single-workflow batch runtime: one DAG owns the cluster.

    Implements Algorithm 2's commit-and-advance loop over the proxy
    cost model; see the module docstring for the issue/completion
    machinery shared with :class:`ServingExecutor`.
    """

    def __init__(self, state: ExecutionState,
                 cost_params: Optional[CostParams] = None,
                 world_profiles: Optional[dict] = None):
        self.state = state
        # world_profiles: ground-truth per-model constants the emulated
        # hardware follows when they diverge from what the scheduler
        # believes (state.profiles) — the calibration benchmark's
        # mis-belief harness; None means world == belief
        self.cm = CostModel(state, cost_params, profiles=world_profiles)

    # ------------------------------------------------------------------
    def run(self, wf: Workflow, policy: Policy) -> RunResult:
        """Execute ``wf`` to completion under ``policy``.

        Invariants (property-tested in ``tests/test_executor.py``):
        every stage runs exactly once, dependencies are respected, and
        per-device busy intervals never overlap.  Raises
        ``RuntimeError`` on a stalled policy (liveness guard).
        """
        state = self.state
        cm = self.cm
        wf.validate()
        n_stages = len(wf.stages)
        committed: list[Placement] = []
        issued: set[str] = set()
        completed: set[str] = set()
        finish_heap: list[tuple[float, str]] = []
        runs: dict[str, StageRun] = {}
        query_done: dict[int, float] = {}
        edge_cross = 0
        prefix_hits = 0.0
        same_model = 0.0
        switches_before = state.model_switches

        def ready_uncommitted() -> list[str]:
            in_pool = {p.sid for p in committed}
            return [sid for sid in wf.topo_order
                    if sid not in completed and sid not in issued
                    and sid not in in_pool
                    and all(p in completed
                            for p in wf.stages[sid].parents)]

        def issuable(p: Placement) -> bool:
            st = wf.stages[p.sid]
            if any(par not in completed for par in st.parents):
                return False
            return all(state.device_free(d) <= state.now + 1e-12
                       for d in p.devices)

        def issue(p: Placement) -> None:
            nonlocal edge_cross, prefix_hits, same_model
            st = wf.stages[p.sid]
            primary = p.devices[0]
            # mechanism proxies (measured at issue, before state update)
            for par in st.parents:
                locs = state.output_loc.get((wf.wid, par), ())
                if locs and primary not in locs:
                    edge_cross += 1
            ov = state.prefix_overlap(st, primary, wf.num_queries)
            prefix_hits += ov
            res_frac = sum(
                1 for d in p.devices if state.is_resident(st.model, d)
            ) / len(p.devices)
            same_model += res_frac

            shard_fin, switched = _issue_shards(state, cm, wf, st, p)
            fin_all = max(shard_fin)
            runs[p.sid] = StageRun(p, state.now, fin_all,
                                   tuple(shard_fin), tuple(switched))
            issued.add(p.sid)
            heapq.heappush(finish_heap, (fin_all, p.sid))

        # main loop -----------------------------------------------------
        guard = 0
        while len(completed) < n_stages:
            guard += 1
            if guard > 40 * n_stages + 1000:
                raise RuntimeError(
                    f"{wf.wid}: executor stalled ({policy.name})")
            # 1. issue every committed action that can start now
            progress = True
            while progress:
                progress = False
                for p in list(committed):
                    if p.sid in issued or p.sid in completed:
                        committed.remove(p)
                        continue
                    if issuable(p):
                        committed.remove(p)
                        issue(p)
                        progress = True
            # 2. plan if the pool has no feasible ready action
            ready = ready_uncommitted()
            pool_feasible = any(
                all(par in completed for par in wf.stages[p.sid].parents)
                for p in committed)
            if ready and not pool_feasible:
                new = policy.plan(wf, state, ready)
                if not new:
                    # liveness fallback: greedily place the single best
                    # ready stage by state-corrected cost
                    new = [_greedy_fallback(state, cm, wf, ready[0])]
                committed.extend(new)
                continue
            # 3. advance time to the next completion
            if finish_heap:
                t, sid = heapq.heappop(finish_heap)
                state.now = max(state.now, t)
                completed.add(sid)
                state.completed.add((wf.wid, sid))
                st = wf.stages[sid]
                run = runs[sid]
                state.output_loc[(wf.wid, sid)] = run.placement.devices
                # per-query completion at sink stages
                if not st.children:
                    qid = 0
                    for dfin, nq in zip(run.shard_finish,
                                        run.placement.shard_sizes):
                        for _ in range(nq):
                            query_done[qid] = max(
                                query_done.get(qid, 0.0), dfin)
                            qid += 1
            elif not committed and not ready_uncommitted():
                raise RuntimeError(f"{wf.wid}: deadlock ({policy.name})")

        makespan = max((r.finish for r in runs.values()), default=0.0)
        qdone = [query_done.get(i, makespan)
                 for i in range(wf.num_queries)]
        return RunResult(
            wid=wf.wid, makespan=makespan, query_completion=qdone,
            stage_runs=runs, cross_device_edges=edge_cross,
            prefix_hits_est=prefix_hits,
            same_model_continuations=same_model,
            total_tasks=n_stages,
            model_switches=state.model_switches - switches_before)


def fresh_state(cluster, profiles=None) -> ExecutionState:
    """Empty execution state over ``cluster`` (cold devices, t=0),
    with the paper's default model profiles unless overridden."""
    from repro.core.workflow import DEFAULT_PROFILES
    return ExecutionState(cluster=cluster,
                          profiles=dict(profiles or DEFAULT_PROFILES))


# ---------------------------------------------------------------------------
# multi-workflow serving
# ---------------------------------------------------------------------------


class SharedFrontier:
    """Merged ready frontier across in-flight workflow DAGs.

    Tracks, per admitted workflow, which stages have completed and
    exposes one ``(wid, sid)``-keyed ready list spanning every active
    DAG — the planning unit of the serving setting.  Workflows are
    iterated in admission order and stages in topological order, so the
    merged list is deterministic; the planner (not this container)
    decides how cross-workflow contention is resolved.  A workflow is
    retired automatically once its last stage completes.
    """

    def __init__(self) -> None:
        self.workflows: dict[str, Workflow] = {}
        self.completed: dict[str, set[str]] = {}
        self._order: list[str] = []

    def admit(self, wf: Workflow) -> None:
        """Add an in-flight workflow; its sources become ready."""
        if wf.wid in self.workflows:
            raise ValueError(f"duplicate workflow id {wf.wid}")
        wf.validate()
        self.workflows[wf.wid] = wf
        self.completed[wf.wid] = set()
        self._order.append(wf.wid)

    def complete(self, wid: str, sid: str) -> bool:
        """Record a stage completion; True if the workflow finished."""
        done = self.completed[wid]
        done.add(sid)
        if len(done) == len(self.workflows[wid].stages):
            self.retire(wid)
            return True
        return False

    def retire(self, wid: str) -> None:
        """Drop a workflow (finished or evicted) from the frontier."""
        self.workflows.pop(wid, None)
        self.completed.pop(wid, None)
        self._order.remove(wid)

    def ready(self, exclude: set[StageKey]) -> list[StageKey]:
        """Merged dependency-ready, not-yet-claimed stage keys."""
        out: list[StageKey] = []
        for wid in self._order:
            wf = self.workflows[wid]
            done = self.completed[wid]
            for sid in wf.topo_order:
                if sid in done or (wid, sid) in exclude:
                    continue
                if all(p in done for p in wf.stages[sid].parents):
                    out.append((wid, sid))
        return out

    def __len__(self) -> int:
        return len(self.workflows)


@dataclasses.dataclass
class WorkflowServeStats:
    """Per-workflow serving outcome (times are absolute sim seconds).

    ``arrival`` is the ORIGINAL trace arrival even for workflows that
    the control plane deferred, so latency (and SLO attainment)
    includes time spent in the admission backlog.  ``deadline`` is set
    only when the executor runs with an :class:`SLOConfig`.
    """
    wid: str
    arrival: float
    finish: float
    query_completion: list[float]      # absolute per-query finish times
    n_stages: int
    deadline: Optional[float] = None   # absolute SLO deadline, if any

    @property
    def makespan(self) -> float:
        """End-to-end latency: completion minus original arrival."""
        return self.finish - self.arrival

    @property
    def latencies(self) -> list[float]:
        """Per-query latencies relative to the original arrival."""
        return [t - self.arrival for t in self.query_completion]

    @property
    def p95(self) -> float:
        """95th-percentile per-query latency (nearest-rank)."""
        return nearest_rank_p95(self.latencies, default=self.makespan)

    @property
    def slo_met(self) -> bool:
        """True when the workflow finished within its deadline (always
        True when no SLO was configured)."""
        return self.deadline is None or self.finish <= self.deadline + 1e-9


@dataclasses.dataclass
class ServingResult:
    """Outcome of one serving trace under one policy.

    ``rejected`` lists workflows the admission controller shed (never
    executed); ``deferrals``/``preemptions`` count control-plane
    interventions.  All three stay empty/zero without an SLO config.
    """
    stats: dict[str, WorkflowServeStats]
    horizon: float                     # first arrival -> last completion
    max_in_flight: int
    replans: int
    model_switches: int
    rejected: list[str] = dataclasses.field(default_factory=list)
    deferrals: int = 0
    preemptions: int = 0

    @property
    def n_offered(self) -> int:
        """Workflows offered by the trace: completed + rejected."""
        return len(self.stats) + len(self.rejected)

    @property
    def slo_attainment(self) -> float:
        """Fraction of OFFERED workflows that completed within their
        deadline (rejected arrivals count against attainment)."""
        if self.n_offered == 0:
            return float("nan")
        met = sum(1 for s in self.stats.values() if s.slo_met)
        return met / self.n_offered

    @property
    def goodput_wps(self) -> float:
        """Completed workflows per second over the busy horizon."""
        return len(self.stats) / self.horizon if self.horizon > 0 else 0.0

    @property
    def goodput_slo_wps(self) -> float:
        """SLO-met workflows per second over the busy horizon — the
        serving objective the control plane optimizes."""
        if self.horizon <= 0:
            return 0.0
        met = sum(1 for s in self.stats.values() if s.slo_met)
        return met / self.horizon

    @property
    def goodput_qps(self) -> float:
        """Completed queries per second over the busy horizon."""
        n_q = sum(len(s.query_completion) for s in self.stats.values())
        return n_q / self.horizon if self.horizon > 0 else 0.0


class ServingExecutor:
    """Event-driven multi-workflow runtime over the proxy cost model.

    Admits workflows from an arrival trace, keeps a
    :class:`SharedFrontier` of every in-flight DAG, and replans on
    every completion event: unissued commitments are revoked and the
    merged frontier is re-solved against the freshest execution state
    (the serving analogue of Algorithm 2's replan trigger).  Policies
    that implement ``plan_shared(workflows, state, ready)`` plan the
    merged frontier in one problem; others fall back to per-workflow
    ``plan`` calls over their slice of the frontier.

    With an :class:`SLOConfig`, the SLO-aware control plane is active:
    every arrival passes through an
    :class:`~repro.core.admission.AdmissionController` future-state
    probe and is admitted, deferred into a bounded backlog, or
    rejected; the backlog is re-probed oldest-feasible-first on every
    completion batch; and SLO-tight admissions preempt — revoke — the
    committed-but-unissued placement pool so the urgent workflow
    competes in a fresh merged solve immediately.  Revocation never
    touches execution state (only ``issue()`` mutates it), so delta
    rescoring stays bit-identical to full rebuilds across preemptions
    (``tests/test_preemption.py``).
    """

    def __init__(self, state: ExecutionState,
                 cost_params: Optional[CostParams] = None,
                 replan_on_completion: bool = True,
                 slo: Optional[SLOConfig] = None,
                 world_profiles: Optional[dict] = None,
                 probe_corrector=None):
        self.state = state
        # world != belief harness; see WorkflowExecutor.__init__
        self.cm = CostModel(state, cost_params, profiles=world_profiles)
        self.replan_on_completion = replan_on_completion
        self.slo = slo
        # long-lived ProbeCorrector shared across run() calls: each run
        # builds a fresh AdmissionController around it, so the learned
        # per-family probe margins survive trace boundaries (a
        # calibration run warm-starts production traffic) while still
        # updating online on every completion
        self.probe_corrector = probe_corrector
        # the last run()'s controller, exposed for tests/introspection
        self.admission: Optional[AdmissionController] = None
        # per-(wid, sid) StageRun records of the most recent run()
        self.last_runs: dict[StageKey, StageRun] = {}

    # -- policy dispatch -------------------------------------------------
    def _plan(self, policy, frontier: SharedFrontier,
              ready: list[StageKey]) -> list[Placement]:
        if hasattr(policy, "plan_shared"):
            return policy.plan_shared(frontier.workflows, self.state,
                                      ready)
        out: list[Placement] = []
        by_wid: dict[str, list[str]] = {}
        for wid, sid in ready:
            by_wid.setdefault(wid, []).append(sid)
        for wid, sids in by_wid.items():
            out.extend(policy.plan(frontier.workflows[wid], self.state,
                                   sids))
        return out

    # -- main loop -------------------------------------------------------
    def run(self, trace: Sequence[tuple[float, Workflow]],
            policy) -> ServingResult:
        """Serve one arrival trace to completion under ``policy``.

        ``trace`` is a list of ``(arrival_time, workflow)`` sorted by
        time with unique workflow ids.  Returns the per-workflow stats
        plus control-plane counters; per-stage :class:`StageRun`
        records of this run are left on :attr:`last_runs`.
        """
        state = self.state
        cm = self.cm
        frontier = SharedFrontier()
        adm = (AdmissionController(self.slo,
                                   corrector=self.probe_corrector)
               if self.slo is not None else None)
        self.admission = adm
        heap: list[tuple[float, int, str, object]] = []
        seq = 0
        n_total_stages = 0
        for t, wf in trace:
            heapq.heappush(heap, (t, seq, "arrive", wf))
            seq += 1
            n_total_stages += len(wf.stages)
        committed: list[Placement] = []
        issued: set[StageKey] = set()
        runs: dict[StageKey, StageRun] = {}
        wf_finish: dict[str, float] = {}     # running max stage finish
        arrivals: dict[str, float] = {}
        deadlines: dict[str, float] = {}
        workflows_all: dict[str, Workflow] = {}
        stats: dict[str, WorkflowServeStats] = {}
        query_done: dict[str, dict[int, float]] = {}
        first_arrival = trace[0][0] if trace else 0.0
        last_finish = first_arrival
        max_in_flight = 0
        replans = 0
        preemptions = 0
        switches_before = state.model_switches

        def issuable(p: Placement) -> bool:
            done = frontier.completed.get(p.wid)
            if done is None:
                return False
            st_ = frontier.workflows[p.wid].stages[p.sid]
            if any(par not in done for par in st_.parents):
                return False
            return all(state.device_free(d) <= state.now + 1e-12
                       for d in p.devices)

        def issue(p: Placement) -> None:
            wf = frontier.workflows[p.wid]
            st = wf.stages[p.sid]
            shard_fin, switched = _issue_shards(state, cm, wf, st, p)
            fin_all = max(shard_fin)
            key = (p.wid, p.sid)
            runs[key] = StageRun(p, state.now, fin_all,
                                 tuple(shard_fin), tuple(switched))
            wf_finish[p.wid] = max(wf_finish.get(p.wid, 0.0), fin_all)
            issued.add(key)
            nonlocal seq
            heapq.heappush(heap, (fin_all, seq, "finish", key))
            seq += 1

        def admit(wf: Workflow, arrival: float,
                  deadline: Optional[float] = None) -> None:
            nonlocal max_in_flight
            frontier.admit(wf)
            workflows_all[wf.wid] = wf
            arrivals[wf.wid] = arrival
            if deadline is not None:
                deadlines[wf.wid] = deadline
            max_in_flight = max(max_in_flight, len(frontier))

        def claimed_keys() -> set[StageKey]:
            return issued | {(p.wid, p.sid) for p in committed}

        def preempt_commitments() -> None:
            """Revoke committed-but-unissued placements for an
            SLO-tight admission.  No execution state was mutated for
            them (only ``issue()`` writes ρ/κ/τ), so the planner's
            delta-rescoring caches need no repair — the revoked rows
            simply reappear in the next merged solve, warm-started on
            their previous devices via the solution hint."""
            nonlocal preemptions
            if committed:
                committed.clear()
                preemptions += 1

        def finish(key: StageKey) -> None:
            nonlocal last_finish
            wid, sid = key
            wf = frontier.workflows[wid]
            st = wf.stages[sid]
            run = runs[key]
            state.output_loc[(wid, sid)] = run.placement.devices
            state.completed.add((wid, sid))
            if not st.children:          # sink: per-query completion
                qd = query_done.setdefault(wid, {})
                qid = 0
                for dfin, nq in zip(run.shard_finish,
                                    run.placement.shard_sizes):
                    for _ in range(nq):
                        qd[qid] = max(qd.get(qid, 0.0), dfin)
                        qid += 1
            issued.discard(key)
            if frontier.complete(wid, sid):
                wf_all = workflows_all[wid]
                qd = query_done.get(wid, {})
                fin_t = wf_finish.get(wid, state.now)
                qdone = [qd.get(i, fin_t)
                         for i in range(wf_all.num_queries)]
                stats[wid] = WorkflowServeStats(
                    wid=wid, arrival=arrivals[wid], finish=fin_t,
                    query_completion=qdone, n_stages=len(wf_all.stages),
                    deadline=deadlines.get(wid))
                last_finish = max(last_finish, fin_t)
                if hasattr(policy, "forget_workflow"):
                    policy.forget_workflow(wid)
                if adm is not None:
                    # close the probe loop (predicted vs observed
                    # latency -> EWMA margin corrector) before the
                    # controller drops its per-workflow records
                    adm.record_completion(wid, fin_t)
                    adm.forget(wid)

        def issue_all() -> None:
            progress = True
            while progress:
                progress = False
                for p in list(committed):
                    key = (p.wid, p.sid)
                    if key in issued or p.wid not in frontier.workflows \
                            or p.sid in frontier.completed[p.wid]:
                        committed.remove(p)
                        continue
                    if issuable(p):
                        committed.remove(p)
                        issue(p)
                        progress = True

        guard = 0
        guard_limit = 60 * max(n_total_stages, 1) + 1000
        while True:
            guard += 1
            if guard > guard_limit:
                raise RuntimeError(
                    f"serving executor stalled ({policy.name})")
            # 1. issue everything issuable at the current time
            issue_all()
            # 2. plan when claimed actions cannot cover the frontier
            claimed = issued | {(p.wid, p.sid) for p in committed}
            ready = frontier.ready(claimed)
            pool_feasible = any(
                all(par in frontier.completed[p.wid]
                    for par in frontier.workflows[p.wid]
                    .stages[p.sid].parents)
                for p in committed if p.wid in frontier.workflows)
            if ready and not pool_feasible:
                new = self._plan(policy, frontier, ready)
                replans += 1
                if not new and not issued:
                    # liveness fallback: greedily place the single best
                    # ready stage by state-corrected cost
                    wid, sid = ready[0]
                    new = [_greedy_fallback(
                        state, cm, frontier.workflows[wid], sid)]
                if new:
                    committed.extend(new)
                    issue_all()        # start the fresh plan NOW, before
                    continue           # the clock advances to next event
            # 3. advance the clock to the next event batch
            if not heap:
                if adm is not None and adm.backlog:
                    # no further events will trigger re-admission:
                    # drain the backlog (shed expired entries, force
                    # the oldest reachable one in) and keep planning
                    for arr, wfp, dec in adm.readmit(
                            state, frontier, policy, claimed_keys(),
                            force=True):
                        admit(wfp, arr, dec.deadline)
                        if dec.preempt:
                            preempt_commitments()
                    continue
                if committed or len(frontier):
                    raise RuntimeError(
                        f"serving executor deadlock ({policy.name})")
                break
            t = heap[0][0]
            state.now = max(state.now, t)
            completed_any = False
            while heap and heap[0][0] <= t + 1e-12:
                _, _, kind, payload = heapq.heappop(heap)
                if kind == "arrive":
                    wf = payload
                    if wf.wid in workflows_all:
                        # stats/arrivals are keyed by wid for the whole
                        # trace, so a reused wid (even after the first
                        # instance retired) would silently clobber them
                        raise ValueError(
                            f"duplicate workflow id in trace: {wf.wid}")
                    if adm is None:
                        admit(wf, state.now)
                        continue
                    dec = adm.on_arrival(wf, state, frontier, policy,
                                         claimed_keys())
                    if dec.action == "admit":
                        admit(wf, state.now, dec.deadline)
                        if dec.preempt:
                            # SLO-tight arrival: revoke unissued
                            # commitments so it competes immediately
                            preempt_commitments()
                    # defer/reject: bookkept inside the controller
                else:
                    finish(payload)
                    completed_any = True
            if completed_any and adm is not None:
                # re-admission sweep: freed capacity may now fit the
                # oldest deferred arrivals (one per sweep so each
                # admission's frontier update feeds the next probe)
                while True:
                    batch = adm.readmit(state, frontier, policy,
                                        claimed_keys())
                    if not batch:
                        break
                    for arr, wfp, dec in batch:
                        admit(wfp, arr, dec.deadline)
                        if dec.preempt:
                            preempt_commitments()
            if completed_any and self.replan_on_completion and committed:
                # revoke unissued commitments: the completed stage
                # changed ρ/κ/ℓ/τ, so the merged frontier is re-solved
                committed.clear()
        horizon = max(last_finish - first_arrival, 0.0)
        self.last_runs = runs
        return ServingResult(
            stats=stats, horizon=horizon, max_in_flight=max_in_flight,
            replans=replans,
            model_switches=state.model_switches - switches_before,
            rejected=list(adm.rejected) if adm is not None else [],
            deferrals=adm.n_deferrals if adm is not None else 0,
            preemptions=preemptions)
