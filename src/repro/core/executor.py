"""Back-compat executor adapters over the event-driven scheduler core.

The commit-and-advance runtime (paper Algorithm 2) lives in
:mod:`repro.core.scheduler` — one event loop, one issue/completion
machinery, one typed event stream.  This module keeps the historical
entry points as thin adapters over it:

* :class:`WorkflowExecutor` — the paper's single-workflow batch
  setting: one DAG owns the cluster until it drains (the scheduler
  core's ``batch=True`` semantics: per-workflow ``plan()`` dispatch,
  unconditional greedy fallback, persistent commit pool, one
  completion per clock advance);
* :class:`ServingExecutor` — the serving setting: workflows arrive
  over time (e.g. from a Poisson trace), a
  :class:`~repro.core.scheduler.SharedFrontier` merges the ready sets
  of every in-flight DAG, and the policy replans the merged frontier
  on every completion.

Both adapters produce bit-identical placements to the pre-refactor
monolithic loops (``tests/test_scheduler_api.py``).  The former
residents of this module (:class:`Policy`, :class:`SharedFrontier`,
:class:`StageRun`, :class:`RunResult`, :class:`WorkflowServeStats`,
:class:`ServingResult`, ``nearest_rank_p95``, ``fresh_state``) are
re-exported from their new homes so existing imports keep working.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.core.admission import SLOConfig
from repro.core.costs import CostModel, CostParams
from repro.core.planner import Placement                        # noqa: F401
from repro.core.policies.base import Policy                     # noqa: F401
from repro.core.scheduler import (RunResult, Scheduler,         # noqa: F401
                                  SchedulerConfig, ServingResult,
                                  SharedFrontier, StageRun,
                                  WorkflowServeStats,
                                  _greedy_fallback, _issue_shards,
                                  fresh_state, nearest_rank_p95)
from repro.core.state import ExecutionState
from repro.core.workflow import StageKey, Workflow

__all__ = [
    "Policy", "RunResult", "ServingExecutor", "ServingResult",
    "SharedFrontier", "StageRun", "WorkflowExecutor",
    "WorkflowServeStats", "fresh_state", "nearest_rank_p95",
]


class WorkflowExecutor:
    """Single-workflow batch runtime: one DAG owns the cluster.

    A thin adapter: each :meth:`run` builds a batch-mode
    :class:`~repro.core.scheduler.Scheduler` around this executor's
    execution state, submits the workflow, drains it, and returns the
    single-workflow :class:`RunResult` view.  The last scheduler (with
    its event stream) is kept on :attr:`scheduler`.
    """

    def __init__(self, state: ExecutionState,
                 cost_params: Optional[CostParams] = None,
                 world_profiles: Optional[dict] = None):
        self.state = state
        self.cost_params = cost_params
        # world_profiles: ground-truth per-model constants the emulated
        # hardware follows when they diverge from what the scheduler
        # believes (state.profiles) — the calibration benchmark's
        # mis-belief harness; None means world == belief
        self.world_profiles = world_profiles
        self.cm = CostModel(state, cost_params, profiles=world_profiles)
        self.scheduler: Optional[Scheduler] = None

    def run(self, wf: Workflow, policy: Policy) -> RunResult:
        """Execute ``wf`` to completion under ``policy``.

        Invariants (property-tested in ``tests/test_executor.py``):
        every stage runs exactly once, dependencies are respected, and
        per-device busy intervals never overlap.  Raises
        ``RuntimeError`` on a stalled policy (liveness guard).
        """
        wf.validate()
        sched = Scheduler(
            config=SchedulerConfig(cost=self.cost_params),
            state=self.state, policy=policy,
            world_profiles=self.world_profiles, batch=True)
        self.scheduler = sched
        self.cm = sched.cm      # the model actually pricing this run
        sched.submit(wf, at=self.state.now)
        sched.drain()
        return sched.batch_result(wf.wid)


class ServingExecutor:
    """Event-driven multi-workflow runtime over the proxy cost model.

    A thin adapter: each :meth:`run` builds a
    :class:`~repro.core.scheduler.Scheduler` around this executor's
    execution state, submits the whole arrival trace, and drains it.
    With an :class:`SLOConfig`, the SLO-aware control plane
    (admission / deferral / preemption, see
    :mod:`repro.core.admission`) is active inside the core.  The
    long-lived ``probe_corrector`` is shared across :meth:`run` calls
    so learned per-family probe margins survive trace boundaries (a
    calibration run warm-starts production traffic) while still
    updating online on every completion.
    """

    def __init__(self, state: ExecutionState,
                 cost_params: Optional[CostParams] = None,
                 replan_on_completion: bool = True,
                 slo: Optional[SLOConfig] = None,
                 world_profiles: Optional[dict] = None,
                 probe_corrector=None):
        self.state = state
        # world != belief harness; see WorkflowExecutor.__init__
        self.cm = CostModel(state, cost_params, profiles=world_profiles)
        self.cost_params = cost_params
        self.replan_on_completion = replan_on_completion
        self.slo = slo
        self.world_profiles = world_profiles
        self.probe_corrector = probe_corrector
        # the last run()'s controller/scheduler, for tests/introspection
        self.admission = None
        self.scheduler: Optional[Scheduler] = None
        # per-(wid, sid) StageRun records of the most recent run()
        self.last_runs: dict[StageKey, StageRun] = {}

    def run(self, trace: Sequence[tuple[float, Workflow]],
            policy) -> ServingResult:
        """Serve one arrival trace to completion under ``policy``.

        ``trace`` is a list of ``(arrival_time, workflow)`` sorted by
        time with unique workflow ids.  Returns the per-workflow stats
        plus control-plane counters; per-stage :class:`StageRun`
        records of this run are left on :attr:`last_runs`.
        """
        sched = Scheduler(
            config=SchedulerConfig(
                cost=self.cost_params, slo=self.slo,
                replan_on_completion=self.replan_on_completion),
            state=self.state, policy=policy,
            world_profiles=self.world_profiles,
            probe_corrector=self.probe_corrector)
        self.scheduler = sched
        self.cm = sched.cm      # the model actually pricing this run
        for t, wf in trace:
            sched.submit(wf, at=t)
        res = sched.drain()
        self.admission = sched.admission
        self.last_runs = sched.runs
        return res
