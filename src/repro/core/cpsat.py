"""Exact 0-1 solver for FATE's frontier placement ILP.

``ortools`` is not installable in the offline container, so this module
provides an exact branch-and-bound solver for the constraint class the
frontier planner emits (Appendix A.2):

  * binary variables
  * AddAtMostOne over variable groups (device capacity, slot uniqueness)
  * AddImplication(a, b): a -> b   (slot monotonicity)
  * Maximize(linear objective)

The interface mirrors CP-SAT (``BoolVar``/``AddAtMostOne``/
``AddImplication``/``Maximize``/``Solve`` returning ``OPTIMAL``), so the
real ortools solver can be swapped in unchanged.  DFS branch-and-bound
over variables in descending-weight order with an admissible bound (sum
of positive weights of free variables, tightened per at-most-one group)
proves optimality on every instance; frontier instances are ≤ 64 stages
× ≤ 2 slots × ≤ 8 devices and solve in well under a millisecond
(benchmarked in Table 12's analogue).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

OPTIMAL = "OPTIMAL"
INFEASIBLE = "INFEASIBLE"


@dataclasses.dataclass
class _Var:
    idx: int
    name: str


class CpModel:
    """Constraint model for the frontier ILP class (see module doc)."""

    def __init__(self) -> None:
        self._n = 0
        self._names: list[str] = []
        self._amo_groups: list[list[int]] = []     # at-most-one groups
        self._implications: list[tuple[int, int]] = []   # a -> b
        self._objective: dict[int, float] = {}
        self._fixed_false: set[int] = set()
        self._hints: dict[int, int] = {}           # solution hints

    def new_bool_var(self, name: str = "") -> _Var:
        v = _Var(self._n, name or f"x{self._n}")
        self._n += 1
        self._names.append(v.name)
        return v

    def add_at_most_one(self, vs: Sequence[_Var]) -> None:
        self._amo_groups.append([v.idx for v in vs])

    def add_implication(self, a: _Var, b: _Var) -> None:
        """a == 1 implies b == 1."""
        self._implications.append((a.idx, b.idx))

    def fix_false(self, v: _Var) -> None:
        self._fixed_false.add(v.idx)

    def add_hint(self, v: _Var, value: int = 1) -> None:
        """CP-SAT-style solution hint (``AddHint`` analogue).

        Hints with value 1 are tried first by the solver's warm-start
        pass, so a previous wave's assignment seeds the incumbent
        before the DFS.  Hints are advisory: infeasible or dominated
        hints are silently dropped and the returned optimum is
        unaffected.
        """
        self._hints[v.idx] = int(value)

    def maximize(self, terms: Sequence[tuple[_Var, float]]) -> None:
        """Set the linear objective to maximize."""
        self._objective = {v.idx: float(w) for v, w in terms}


@dataclasses.dataclass
class SolveResult:
    status: str
    objective: float
    values: dict[int, int]
    wall_time: float
    nodes: int
    proven_gap: float = 0.0


class CpSolver:
    """DFS branch-and-bound with group-aware admissible bound.

    A greedy warm-start incumbent (descending-weight feasible
    assignment — the best-per-row pick for frontier instances) is
    installed before the DFS so the bound prunes from the first node;
    the incumbent is feasible, so optimality is unaffected.  Scratch
    arrays are kept on the solver instance and reused across solves.
    """

    def __init__(self, time_limit: float = 5.0, warm_start: bool = True):
        self.time_limit = time_limit
        self.warm_start = warm_start
        self._assign_buf: list[int] = []
        self._group_buf: list[bool] = []

    def _scratch(self, n_vars: int, n_groups: int
                 ) -> tuple[list[int], list[bool]]:
        """Reusable assign/group-used arrays (resized, then re-filled)."""
        if len(self._assign_buf) < n_vars:
            self._assign_buf.extend([-1] * (n_vars - len(self._assign_buf)))
        if len(self._group_buf) < n_groups:
            self._group_buf.extend(
                [False] * (n_groups - len(self._group_buf)))
        for i in range(n_vars):
            self._assign_buf[i] = -1
        for g in range(n_groups):
            self._group_buf[g] = False
        return self._assign_buf, self._group_buf

    def solve(self, model: CpModel) -> SolveResult:
        t0 = time.perf_counter()
        n = model._n
        w = [model._objective.get(i, 0.0) for i in range(n)]
        # variable -> groups; variable -> implications (a->b: b required)
        groups_of: list[list[int]] = [[] for _ in range(n)]
        for gi, g in enumerate(model._amo_groups):
            for v in g:
                groups_of[v].append(gi)
        needs: list[list[int]] = [[] for _ in range(n)]   # a -> required b
        blocked_by: list[list[int]] = [[] for _ in range(n)]  # b=0 -> a=0
        for a, b in model._implications:
            needs[a].append(b)
            blocked_by[b].append(a)

        # branch order: descending weight (set-to-1 first)
        order = sorted(range(n), key=lambda i: -w[i])
        pos = {v: k for k, v in enumerate(order)}

        # admissible suffix bounds over positions [k:):
        #   suffix    — plain sum of positive weights
        #   gdev/gslot — group-capped: each at-most-one group contributes
        #   at most its best remaining member (designating each var to
        #   its first / second group resp.); min of all three is used.
        suffix = [0.0] * (len(order) + 1)
        for k in range(len(order) - 1, -1, -1):
            suffix[k] = suffix[k + 1] + max(0.0, w[order[k]])

        def group_capped(designate: int) -> list[float]:
            out = [0.0] * (len(order) + 1)
            gmax: dict[int, float] = {}
            total = 0.0
            for k in range(len(order) - 1, -1, -1):
                v = order[k]
                wp = max(0.0, w[v])
                gs = groups_of[v]
                if len(gs) > designate:
                    g = gs[designate]
                    old = gmax.get(g, 0.0)
                    if wp > old:
                        total += wp - old
                        gmax[g] = wp
                else:
                    total += wp
                out[k] = total
            return out

        gdev = group_capped(0)
        gslot = group_capped(1)
        bound_at = [min(a, b, c) for a, b, c in zip(suffix, gdev, gslot)]

        best_val = -1.0
        best_assign: dict[int, int] = {}
        assign, group_used = self._scratch(n, len(model._amo_groups))
        nodes = 0
        deadline = t0 + self.time_limit

        for i in model._fixed_false:
            assign[i] = 0

        def feasible_one(v: int) -> bool:
            if assign[v] == 0:
                return False
            for g in groups_of[v]:
                if group_used[g]:
                    return False
            for b in needs[v]:
                if assign[b] == 0:
                    return False
            return True

        def set_one(v: int) -> Optional[list]:
            """Set v=1 with propagation; returns undo log or None.
            Maintains ``value`` for every assignment it makes."""
            nonlocal value
            undo: list = []
            for g in groups_of[v]:
                group_used[g] = True
                undo.append(("g", g))
            assign[v] = 1
            value += w[v]
            undo.append(("v", v))
            # propagate: all needs must become 1 (chain)
            stack = list(needs[v])
            while stack:
                b = stack.pop()
                if assign[b] == 1:
                    continue
                if assign[b] == 0:
                    _undo(undo)
                    return None
                for g in groups_of[b]:
                    if group_used[g]:
                        _undo(undo)
                        return None
                for g in groups_of[b]:
                    group_used[g] = True
                    undo.append(("g", g))
                assign[b] = 1
                value += w[b]
                undo.append(("v", b))
                stack.extend(needs[b])
            return undo

        def set_zero(v: int) -> Optional[list]:
            undo: list = []
            stack = [v]
            while stack:
                x = stack.pop()
                if assign[x] == 0:
                    continue
                if assign[x] == 1:
                    _undo(undo)
                    return None
                assign[x] = 0
                undo.append(("v0", x))
                stack.extend(blocked_by[x])
            return undo

        value = 0.0

        def _undo(undo: list) -> None:
            nonlocal value
            for kind, x in reversed(undo):
                if kind == "g":
                    group_used[x] = False
                elif kind == "v":
                    assign[x] = -1
                    value -= w[x]
                else:
                    assign[x] = -1

        # greedy warm-start incumbent: walk variables in bound order —
        # solution-hinted variables first (a previous wave's assignment,
        # see CpModel.add_hint), then the rest — taking every
        # positive-weight feasible set-to-1 (with implied propagation).
        # Feasible by construction, so it seeds best_val without
        # cutting the optimum; the DFS then prunes against it from node
        # one instead of descending to a leaf first.
        if self.warm_start:
            hints = model._hints
            warm_order = order
            if hints:
                warm_order = ([v for v in order if hints.get(v) == 1]
                              + [v for v in order if hints.get(v) != 1])
            warm_undos: list[list] = []
            for v in warm_order:
                if assign[v] != -1 or w[v] <= 0 or not feasible_one(v):
                    continue
                u = set_one(v)
                if u is not None:
                    warm_undos.append(u)
            if value > best_val:
                # ε-below seeding (mirrors frontier_solver's hint
                # incumbent): the DFS still re-finds — in its own
                # deterministic order — any solution tying the greedy
                # value, so warm starts and hints only prune, they
                # never change which tied-optimal assignment is
                # returned.
                best_val = value - 1e-9
                best_assign = {i: (1 if assign[i] == 1 else 0)
                               for i in range(n)}
            for u in reversed(warm_undos):
                _undo(u)

        # iterative DFS: frames are (k, phase, undo_log); phase 0 = try
        # v=1 branch, phase 1 = try v=0 branch, phase 2 = done.
        stack: list[list] = [[0, 0, None]]
        while stack:
            frame = stack[-1]
            k, phase = frame[0], frame[1]
            if phase == 0:
                nodes += 1
                if (time.perf_counter() > deadline
                        or value + bound_at[k] <= best_val + 1e-12):
                    stack.pop()
                    if frame[2] is not None:
                        _undo(frame[2])
                    continue
                if k == len(order):
                    if value > best_val:
                        best_val = value
                        best_assign = {i: (1 if assign[i] == 1 else 0)
                                       for i in range(n)}
                    stack.pop()
                    if frame[2] is not None:
                        _undo(frame[2])
                    continue
                v = order[k]
                if assign[v] != -1:
                    frame[1] = 2
                    stack.append([k + 1, 0, None])
                    continue
                frame[1] = 1
                if w[v] > 0 and feasible_one(v):
                    undo = set_one(v)
                    if undo is not None:
                        stack.append([k + 1, 0, undo])
                        continue
                continue
            if phase == 1:
                v = order[k]
                frame[1] = 2
                undo = set_zero(v)
                if undo is not None:
                    stack.append([k + 1, 0, undo])
                continue
            # phase 2: unwind
            stack.pop()
            if frame[2] is not None:
                _undo(frame[2])
        wall = time.perf_counter() - t0
        status = OPTIMAL if wall <= self.time_limit else "FEASIBLE"
        if best_val < 0:
            # all-zeros is always feasible for this constraint class
            best_val = 0.0
            best_assign = {i: 0 for i in range(n)}
        return SolveResult(status=status, objective=best_val,
                           values=best_assign, wall_time=wall, nodes=nodes)


def solve_frontier(model: CpModel,
                   time_limit: float = 5.0) -> SolveResult:
    return CpSolver(time_limit).solve(model)
