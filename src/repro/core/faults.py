"""Deterministic, seeded fault injection for the scheduling runtime.

The serving loop in :mod:`repro.core.scheduler` historically assumed
every issued shard completes on a healthy device.  This module supplies
the declarative fault model that breaks that assumption on purpose —
reproducibly:

* :class:`FaultPlan` — a frozen, JSON-serializable description of a
  fault trace: scripted :class:`DeviceCrash` / recovery episodes,
  :class:`Slowdown` (straggler) windows, targeted transient
  :class:`ShardFailure` injections plus an optional seeded random
  failure rate, and the retry / quarantine / speculation knobs the
  scheduler obeys while recovering.  A plan rides inside
  ``SchedulerConfig`` (``faults=...``) so a chaos run is reproducible
  from its config JSON alone.
* :class:`FaultInjector` — the runtime oracle the scheduler consults at
  issue time.  All randomness flows through one ``random.Random(seed)``
  stream and scripted faults are pure functions of ``(wid, sid, t)``,
  so two runs of the same plan over the same trace produce bit-identical
  event streams (the ``sched_bench --chaos`` replay gate).
* :class:`DeviceHealth` — consecutive-transient-failure counter that
  trips a device into quarantine after ``quarantine_after`` strikes.
* :class:`TransientStageFailure` — the exception
  :meth:`repro.serving.engine.ServingEngine.run_stage` raises when an
  injected failure fires, so the live engine exercises the same retry
  contract as the simulator.

An EMPTY ``FaultPlan()`` arms the machinery but injects nothing: the
scheduler's fault paths are strictly additive, and the chaos gate
asserts that an empty plan reproduces the fault-free run bit-for-bit.

See ``docs/FAULTS.md`` for the fault taxonomy and recovery semantics.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Mapping, Optional, Sequence


class TransientStageFailure(RuntimeError):
    """Raised by the live engine when an injected shard failure fires.

    Carries no state beyond the message; callers retry the stage (up to
    ``FaultPlan.max_retries``) or surface the failure.
    """


@dataclasses.dataclass(frozen=True)
class DeviceCrash:
    """Scripted fail-stop crash of one device at time ``at``.

    The device loses residency, warm prefixes, and all in-flight shards
    the moment it crashes; committed-but-unissued placements on it are
    revoked.  If ``recover_at`` is set the device rejoins the live set
    (cold) at that time; otherwise it stays down for the whole run.
    """

    device: int
    at: float
    recover_at: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class Slowdown:
    """Straggler episode: device runs ``factor``× slower in a window.

    Any shard ISSUED on ``device`` with ``at <= t < until`` takes
    ``factor`` times its modeled duration.  The scheduler's cost model
    does not see the slowdown — that gap is what timeout-based straggler
    detection (``FaultPlan.straggler_threshold``) exists to catch.
    """

    device: int
    at: float
    until: float
    factor: float = 3.0


@dataclasses.dataclass(frozen=True)
class ShardFailure:
    """Targeted transient failure of one stage's first issue attempt.

    The attempt runs for ``at_fraction`` of its (actual) duration, then
    fails; the scheduler retries with exponential backoff.  Fires at
    most once per ``(wid, sid)``; retries of the same stage succeed.
    """

    wid: str
    sid: str
    at_fraction: float = 0.5


def _tuple_of(cls, docs) -> tuple:
    """Rehydrate a tuple of frozen fault dataclasses from dict rows."""
    return tuple(cls(**dict(d)) for d in (docs or ()))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Declarative, seeded fault trace plus recovery policy knobs.

    Scripted faults (``crashes`` / ``slowdowns`` / ``failures``) are
    deterministic; ``failure_rate`` adds seeded random transient
    failures (at most ``max_random_failures``, each failing at
    ``failure_point`` of the shard's duration).  Recovery knobs:
    ``max_retries`` bounded replays with ``retry_backoff *
    retry_backoff_mult**attempt`` backoff; ``straggler_threshold``
    (× believed duration, 0 disables) arms timeout-based straggler
    detection with optional speculative re-issue (``speculate``);
    ``quarantine_after`` consecutive transient failures on one device
    quarantine it for ``quarantine_s`` seconds.  The default
    ``FaultPlan()`` injects nothing.
    """

    seed: int = 0
    crashes: tuple = ()
    slowdowns: tuple = ()
    failures: tuple = ()
    failure_rate: float = 0.0
    max_random_failures: int = 0
    failure_point: float = 0.5
    max_retries: int = 3
    retry_backoff: float = 0.05
    retry_backoff_mult: float = 2.0
    straggler_threshold: float = 0.0
    speculate: bool = True
    quarantine_after: int = 3
    quarantine_s: float = 1.0

    def __post_init__(self):
        object.__setattr__(self, "crashes", tuple(self.crashes))
        object.__setattr__(self, "slowdowns", tuple(self.slowdowns))
        object.__setattr__(self, "failures", tuple(self.failures))

    @property
    def empty(self) -> bool:
        """True when the plan injects nothing and arms no detection."""
        return (not self.crashes and not self.slowdowns
                and not self.failures and self.failure_rate <= 0.0
                and self.straggler_threshold <= 0.0)

    def backoff(self, attempt: int) -> float:
        """Exponential backoff before re-issuing retry ``attempt``."""
        return self.retry_backoff * self.retry_backoff_mult ** max(
            attempt - 1, 0)

    def to_dict(self) -> dict:
        """Plain-JSON dict; inverse of :meth:`from_dict`."""
        doc = dataclasses.asdict(self)
        doc["crashes"] = [dataclasses.asdict(c) for c in self.crashes]
        doc["slowdowns"] = [dataclasses.asdict(s) for s in self.slowdowns]
        doc["failures"] = [dataclasses.asdict(f) for f in self.failures]
        return doc

    @classmethod
    def from_dict(cls, doc: Mapping) -> "FaultPlan":
        """Rehydrate a plan from :meth:`to_dict` output."""
        doc = dict(doc)
        doc["crashes"] = _tuple_of(DeviceCrash, doc.get("crashes"))
        doc["slowdowns"] = _tuple_of(Slowdown, doc.get("slowdowns"))
        doc["failures"] = _tuple_of(ShardFailure, doc.get("failures"))
        return cls(**doc)


class FaultInjector:
    """Runtime oracle for a :class:`FaultPlan`.

    The scheduler (or live engine) asks it, at each issue, whether the
    attempt fails and how much each device is slowed.  Scripted faults
    are pure lookups; random failures draw from one seeded stream in
    issue order, so identical runs consume identical draws.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._rng = random.Random(plan.seed)
        self._targeted = {(f.wid, f.sid): f.at_fraction
                          for f in plan.failures}
        self._fired: set = set()
        self.n_random = 0

    def failure_fraction(self, wid: str, sid: str,
                         devices: Sequence[int],
                         attempt: int) -> Optional[float]:
        """Fraction of the attempt's duration to run before failing.

        ``None`` means the attempt succeeds.  Targeted failures fire
        once on the stage's first attempt; random failures (if
        ``failure_rate > 0``) also only strike first attempts so
        bounded retry always converges.
        """
        if attempt > 0:
            return None
        key = (wid, sid)
        if key in self._targeted and key not in self._fired:
            self._fired.add(key)
            return self._targeted[key]
        if (self.plan.failure_rate > 0.0
                and self.n_random < self.plan.max_random_failures
                and self._rng.random() < self.plan.failure_rate):
            self.n_random += 1
            return self.plan.failure_point
        return None

    def slow_factor(self, device: int, t: float) -> float:
        """Slowdown multiplier for a shard issued on ``device`` at ``t``."""
        f = 1.0
        for ep in self.plan.slowdowns:
            if ep.device == device and ep.at <= t < ep.until:
                f = max(f, ep.factor)
        return f

    def slow_map(self, devices: Sequence[int], t: float
                 ) -> Optional[dict]:
        """Per-device slowdown factors, or ``None`` when all are 1.0."""
        if not self.plan.slowdowns:
            return None
        m = {d: self.slow_factor(d, t) for d in devices}
        return m if any(v != 1.0 for v in m.values()) else None

    # -- durability ------------------------------------------------------
    def state_dict(self) -> dict:
        """Plain-JSON capture of the injector's mutable cursor: the
        seeded RNG state, fired targeted failures, and the random-
        failure count.  With :meth:`load_state` this lets a restored
        scheduler consume the exact same fault draws the pre-crash
        run would have — the determinism the recovery gate asserts."""
        version, internal, gauss = self._rng.getstate()
        return {"rng": [version, list(internal), gauss],
                "fired": sorted(list(k) for k in self._fired),
                "n_random": self.n_random}

    def load_state(self, doc: Mapping) -> None:
        """Restore the cursor captured by :meth:`state_dict` (the
        plan itself rides in the owning ``SchedulerConfig``)."""
        version, internal, gauss = doc["rng"]
        self._rng.setstate((int(version),
                            tuple(int(x) for x in internal), gauss))
        self._fired = {tuple(k) for k in doc["fired"]}
        self.n_random = int(doc["n_random"])


class DeviceHealth:
    """Consecutive-transient-failure tracker driving quarantine.

    ``record_failure`` returns True when a device crosses
    ``quarantine_after`` consecutive strikes (and resets its counter);
    any successful completion on the device resets it.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.consecutive: dict[int, int] = {}

    def record_failure(self, device: int) -> bool:
        """Register a transient failure; True when quarantine trips."""
        n = self.consecutive.get(device, 0) + 1
        self.consecutive[device] = n
        if 0 < self.plan.quarantine_after <= n:
            self.consecutive[device] = 0
            return True
        return False

    def record_success(self, device: int) -> None:
        """A healthy completion clears the device's strike counter."""
        self.consecutive.pop(device, None)

    def reset(self, device: int) -> None:
        """Forget a device's strikes (e.g. on crash recovery)."""
        self.consecutive.pop(device, None)

    # -- durability ------------------------------------------------------
    def state_dict(self) -> dict:
        """Plain-JSON capture of the per-device strike counters."""
        return {"consecutive": {str(d): n
                                for d, n in self.consecutive.items()}}

    def load_state(self, doc: Mapping) -> None:
        """Restore the counters captured by :meth:`state_dict`."""
        self.consecutive = {int(d): int(n)
                            for d, n in doc["consecutive"].items()}
