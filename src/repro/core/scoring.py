"""State-aware scoring: runtime score S(v,d|s) and horizon-aware
planner score Ψ(v,k,d|s,H)  (paper §3.3–3.4, Appendix A.3).

    S(v,d|s) = −λ_q C_wait − λ_s C_switch − λ_tr C_transfer
               + λ_c B_colo + λ_p B_prefix + λ_r B_parallel
               (+ λ_m B_same_model — the "same-model bonus", ablated
                separately from switch cost per Appendix C.3)

    Ψ(v,k,d|s,H) = quality_base + S-terms (+ marginal shard gain for
                   k>0) + Σ_{u ∈ Desc_H(v)} γ^{dist(u)} · tail(u, v, d)

The tail folds downstream demand into current-frontier candidates
without expanding future stages into solver variables (paper §3.3):
  * same-model continuation — placing v on d keeps m(v) resident where
    descendant u (same model) could continue, weighted by how scarce
    m(v)-residency currently is;
  * prefix affinity — placing v on d warms grp(v) state that matching
    descendants can reuse;
  * child transfer pressure — direct children inherit v's output.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.costs import CostModel
from repro.core.state import ExecutionState
from repro.core.workflow import Stage, Workflow


@dataclasses.dataclass(frozen=True)
class ScoreParams:
    lam_wait: float = 1.0          # λ_q
    lam_switch: float = 1.0        # λ_s
    lam_transfer: float = 1.0      # λ_tr
    lam_colo: float = 0.6          # λ_c
    lam_prefix: float = 1.5        # λ_p
    lam_parallel: float = 0.9      # λ_r
    lam_same_model: float = 0.5    # λ_m (same-model bonus)
    horizon: int = 4               # H (levels; 1 = frontier only)
    gamma: float = 0.6             # level discount
    sibling_factor: float = 0.4    # frontier-sibling demand folding
    bonus_factor: float = 0.4      # same-model bonus scale (of switch)
    margin_factor: float = 0.1     # wave regret margin (of mean base)
    specialize_factor: float = 0.15  # model-specialized device preference
    # ablation switches (Appendix C.3)
    enable_future: bool = True
    enable_locality: bool = True
    enable_same_model: bool = True
    enable_prefix: bool = True
    enable_shard: bool = True

    def scaled(self, *, state_mul: float = 1.0, locality_mul: float = 1.0,
               prefix_mul: float = 1.0) -> "ScoreParams":
        """Table 10 sensitivity: scale term groups."""
        return dataclasses.replace(
            self,
            lam_switch=self.lam_switch * state_mul,
            lam_same_model=self.lam_same_model * state_mul,
            lam_colo=self.lam_colo * locality_mul,
            lam_transfer=self.lam_transfer * locality_mul,
            lam_prefix=self.lam_prefix * prefix_mul,
        )


def _preferred_devices(model: str, n_devices: int,
                       k: int = 2) -> tuple[int, ...]:
    """Stable per-model device affinity (hash-spread over the cluster)."""
    import hashlib
    h = int(hashlib.sha256(model.encode()).hexdigest()[:8], 16)
    return tuple((h + i * 3) % n_devices for i in range(k))


class Scorer:
    def __init__(self, state: ExecutionState, cost_model: CostModel,
                 params: Optional[ScoreParams] = None):
        self.state = state
        self.cm = cost_model
        self.p = params or ScoreParams()
        self._frontier_models: dict[str, int] = {}
        self._device_pressure_cost = 0.0

    def set_frontier(self, wf: Workflow, ready: Sequence[str]) -> None:
        """Record frontier model demand + device pressure."""
        self._frontier_models = {}
        for sid in ready:
            m = wf.stages[sid].model
            self._frontier_models[m] = self._frontier_models.get(m, 0) + 1
        n_dev = self.state.cluster.n
        mean_base = sum(
            self.cm.base_cost(wf.stages[sid], self.state.cluster.ids()[0],
                              wf.num_queries)
            for sid in ready) / max(len(ready), 1)
        # displacement only bites once primaries saturate the devices
        pressure = min(1.0, max(0.0, (len(ready) - 0.75 * n_dev)
                                / (0.5 * n_dev)))
        self._device_pressure_cost = mean_base * pressure

    # ------------------------------------------------------------------
    def runtime_score(self, wf: Workflow, stage: Stage,
                      device: int) -> float:
        """S(v, d | s_t)."""
        p = self.p
        q = wf.num_queries
        s = 0.0
        s -= p.lam_wait * self.state.wait_time(device)
        s -= p.lam_switch * self.cm.switch_cost(stage, device)
        if p.enable_locality:
            s -= p.lam_transfer * self.cm.transfer_cost(wf, stage, device, q)
            if stage.parents:
                colo = (self.state.parent_on_device(wf.wid, stage, device)
                        / len(stage.parents))
                s += p.lam_colo * colo * self.cm.base_cost(stage, device, q) \
                    * 0.25
        if p.enable_prefix:
            s += p.lam_prefix * self.cm.prefix_benefit(stage, device, q)
        if p.enable_same_model and self.state.is_resident(stage.model,
                                                          device):
            # small tie-breaker only: residency's real value is carried
            # by C_switch (immediate) and the horizon tail (future)
            prof = self.state.profiles[stage.model]
            s += p.lam_same_model * prof.switch_cost * p.bonus_factor
        return s

    # ------------------------------------------------------------------
    def _descendants_within(self, wf: Workflow, sid: str,
                            depth: int) -> list[tuple[str, int]]:
        out: list[tuple[str, int]] = []
        frontier = [(sid, 0)]
        seen = {sid}
        while frontier:
            cur, d = frontier.pop()
            if d >= depth:
                continue
            for ch in wf.stages[cur].children:
                if ch in seen:
                    continue
                seen.add(ch)
                out.append((ch, d + 1))
                frontier.append((ch, d + 1))
        return out

    def future_tail(self, wf: Workflow, stage: Stage, device: int) -> float:
        """Discounted downstream (and frontier-sibling) state-preservation
        value of placing v on d."""
        p = self.p
        if not p.enable_future or p.horizon <= 1:
            return 0.0
        q = wf.num_queries
        tail = 0.0
        resident_count = sum(
            1 for d2 in self.state.cluster.ids()
            if d2 != device and self.state.is_resident(stage.model, d2))
        scarcity = 1.0 / (1.0 + resident_count)
        # frontier-sibling demand: creating a NEW m(v) residency is worth
        # a share of the switch cost the queued same-model siblings would
        # otherwise pay (or wait out), with diminishing returns as more
        # devices already host the model.
        if not self.state.is_resident(stage.model, device):
            siblings = self._frontier_models.get(stage.model, 1) - 1
            if siblings > 0:
                prof = self.state.profiles[stage.model]
                tail += (p.sibling_factor * siblings
                         * prof.switch_cost * scarcity)
        for uid, dist in self._descendants_within(wf, stage.sid,
                                                  p.horizon - 1):
            u = wf.stages[uid]
            g = p.gamma ** dist
            if u.model == stage.model:
                prof = self.state.profiles[u.model]
                tail += (g * 0.5 * p.lam_switch * prof.switch_cost
                         * scarcity)
            if (p.enable_prefix and stage.prefix_group is not None
                    and u.prefix_group == stage.prefix_group
                    and u.cache_reuse and u.model == stage.model):
                base_u = self.cm.base_cost(u, device, q)
                tail += (g * p.lam_prefix * base_u * u.prefill_fraction
                         * self.cm.p.prefix_saving)
            if p.enable_locality and dist == 1:
                # direct child inherits v's output: colocating later saves
                # β·σ(v,u); reward keeping that option cheap on d
                sigma_k = stage.output_tokens * q * u.comm_weight / 1000.0
                tail += g * p.lam_transfer * \
                    self.state.cluster.transfer_coef * sigma_k * 0.5
        return tail

    def corrected_eft(self, wf: Workflow, stage: Stage,
                      device: int) -> float:
        """State-corrected stage duration on d (no wait): ĉ(v,d,s)."""
        bd = self.cm.breakdown(wf, stage, device, wf.num_queries)
        return max(1e-6, bd.total)

    # ------------------------------------------------------------------
    def planner_score(self, wf: Workflow, stage: Stage, slot: int,
                      device: int, quality_base: float,
                      solo_best: float = 0.0) -> float:
        """Ψ(v, k, d | s_t, H).

        Slot 0 scores are an estimated-finish-time value in seconds:
        −(wait + state-corrected cost) plus the discounted future tail,
        so immediate efficiency and future-state quality share one unit
        and the planner's wave competition approximates completion-time
        impact (§3.2's  −C_imm + γ·V_future  decomposition).
        """
        p = self.p
        q = wf.num_queries
        if slot == 0:
            bd = self.cm.breakdown(wf, stage, device, q)
            eft = p.lam_wait * self.state.wait_time(device)
            eft += bd.base
            eft += p.lam_switch * bd.switch
            if p.enable_locality:
                eft += p.lam_transfer * bd.transfer
                eft -= p.lam_colo * bd.locality_benefit
            if p.enable_prefix:
                eft -= p.lam_prefix * bd.prefix_benefit
            psi = quality_base - eft
            psi += self.future_tail(wf, stage, device)
            if p.enable_same_model and self.state.is_resident(
                    stage.model, device):
                prof = self.state.profiles[stage.model]
                psi += p.lam_same_model * prof.switch_cost \
                    * p.bonus_factor
            # model-specialized placement preference (deep heterogeneous
            # workflows, §4.1 implementation summary): a stable per-model
            # device affinity that damps residency churn across waves.
            if p.specialize_factor and p.enable_same_model:
                prof = self.state.profiles[stage.model]
                if device in _preferred_devices(
                        stage.model, self.state.cluster.n):
                    psi += p.specialize_factor * prof.switch_cost
            return psi
        # extra shard slot: marginal completion-time gain minus occupancy.
        # Under device pressure (more ready stages than devices) taking a
        # device for a shard defers another stage's primary — charge that
        # opportunity cost so bounded shard execution activates only when
        # devices would otherwise idle (paper: "enables bounded
        # multi-device shard execution when beneficial").
        if not p.enable_shard or slot >= stage.max_shards:
            return float("-inf")
        # completion with this extra shard = the slowest partition; the
        # candidate device contributes its own STATE-CORRECTED per-query
        # cost (a cold/unswitched device can make sharding a net loss
        # even when the primary runs warm).
        corrected_d = self.corrected_eft(wf, stage, device)
        solo = solo_best if solo_best > 0 else corrected_d
        completion_new = max(solo, corrected_d) / (slot + 1)
        overhead = solo * self.cm.p.shard_overhead
        gain = (solo / slot - completion_new - overhead) * p.lam_parallel
        gain -= p.lam_wait * self.state.wait_time(device)
        gain -= self._device_pressure_cost
        return gain
