"""State-aware scoring: runtime score S(v,d|s) and horizon-aware
planner score Ψ(v,k,d|s,H)  (paper §3.3–3.4, Appendix A.3).

    S(v,d|s) = −λ_q C_wait − λ_s C_switch − λ_tr C_transfer
               + λ_c B_colo + λ_p B_prefix + λ_r B_parallel
               (+ λ_m B_same_model — the "same-model bonus", ablated
                separately from switch cost per Appendix C.3)

    Ψ(v,k,d|s,H) = quality_base + S-terms (+ marginal shard gain for
                   k>0) + Σ_{u ∈ Desc_H(v)} γ^{dist(u)} · tail(u, v, d)

The tail folds downstream demand into current-frontier candidates
without expanding future stages into solver variables (paper §3.3):
  * same-model continuation — placing v on d keeps m(v) resident where
    descendant u (same model) could continue, weighted by how scarce
    m(v)-residency currently is;
  * prefix affinity — placing v on d warms grp(v) state that matching
    descendants can reuse;
  * child transfer pressure — direct children inherit v's output.

Batched engine layout
---------------------
``Scorer.score_matrix`` no longer loops numpy expressions per ready
stage: it fills per-component matrices (base / switch / transfer /
prefix / locality / tail / bonuses, each [R, D]) and assembles Ψ and
EFT with one 2-D pass in ``planner_score``'s exact accumulation order,
so entries stay bit-identical to the scalar path.  Rows are grouped by
model (residency mask, scarcity, switch vector, bonuses shared) and by
(prefix-group, model) signature (warm-query gathers and overlap math
shared), and the discounted future tail is materialized from a cached
per-stage *term plan* — the static [K, D] payload of every descendant
term in scalar DFS order plus a flag marking scarcity-scaled terms —
then folded with K sequential 2-D adds.

``Scorer.rescore_matrix`` is the incremental twin: given the previous
wave's :class:`FrontierScores` it recomputes only what state changes
invalidated — rows of models whose residency footprint or frontier
sibling count changed, newly-ready rows, prefix columns whose warm
state moved — and reuses every other cached component bit-identically.
See the dirty-set protocol in :mod:`repro.core.state`.

Every model-level constant the scorer folds (switch costs in the
switch/tail/bonus terms) is read from ``state.profiles``, and every
global scale from the cost model's ``CostParams`` — so a loaded
:class:`~repro.core.calibration.CalibrationProfile` recalibrates both
score paths identically, and parity (matrix vs scalar, delta vs full)
holds under ANY fixed profile (``tests/test_calibration.py``).
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import Optional, Sequence

import numpy as np

from repro.core.costs import CostModel, cluster_arrays
from repro.core.frontier_solver import NEG
from repro.core.state import ExecutionState
from repro.core.workflow import Stage, Workflow


@dataclasses.dataclass(frozen=True)
class ScoreParams:
    """Score-term weights λ and horizon knobs (paper Table 10 rows).

    ``sibling_factor`` scales the frontier-sibling demand term; note
    the sibling COUNT it multiplies is capped at cluster size inside
    the scorer (see :meth:`Scorer.future_tail`) — merged serving
    frontiers can queue dozens of same-model stages, and an unbounded
    linear term would drown every other signal and thrash residency.
    """

    lam_wait: float = 1.0          # λ_q
    lam_switch: float = 1.0        # λ_s
    lam_transfer: float = 1.0      # λ_tr
    lam_colo: float = 0.6          # λ_c
    lam_prefix: float = 1.5       # λ_p
    lam_parallel: float = 0.9      # λ_r
    lam_same_model: float = 0.5    # λ_m (same-model bonus)
    horizon: int = 4               # H (levels; 1 = frontier only)
    gamma: float = 0.6             # level discount
    sibling_factor: float = 0.4    # frontier-sibling demand folding
    bonus_factor: float = 0.4      # same-model bonus scale (of switch)
    margin_factor: float = 0.1     # wave regret margin (of mean base)
    specialize_factor: float = 0.15  # model-specialized device preference
    # ablation switches (Appendix C.3)
    enable_future: bool = True
    enable_locality: bool = True
    enable_same_model: bool = True
    enable_prefix: bool = True
    enable_shard: bool = True

    def scaled(self, *, state_mul: float = 1.0, locality_mul: float = 1.0,
               prefix_mul: float = 1.0) -> "ScoreParams":
        """Table 10 sensitivity: scale term groups."""
        return dataclasses.replace(
            self,
            lam_switch=self.lam_switch * state_mul,
            lam_same_model=self.lam_same_model * state_mul,
            lam_colo=self.lam_colo * locality_mul,
            lam_transfer=self.lam_transfer * locality_mul,
            lam_prefix=self.lam_prefix * prefix_mul,
        )


_AFFINITY_GENERATION = 0


def invalidate_affinity_cache() -> None:
    """Bump the generation key of the per-model device-affinity cache.

    ``_preferred_devices`` memoizes on ``(model, n_devices)`` — immutable
    facts for frozen clusters.  Code that redefines what those inputs
    mean (swapping the profile table, re-numbering devices in place)
    must call this so stale affinity tuples are never reused.
    """
    global _AFFINITY_GENERATION
    _AFFINITY_GENERATION += 1


@functools.lru_cache(maxsize=4096)
def _preferred_devices_keyed(model: str, n_devices: int, k: int,
                             generation: int) -> tuple[int, ...]:
    h = int(hashlib.sha256(model.encode()).hexdigest()[:8], 16)
    return tuple((h + i * 3) % n_devices for i in range(k))


def _preferred_devices(model: str, n_devices: int,
                       k: int = 2) -> tuple[int, ...]:
    """Stable per-model device affinity (hash-spread over the cluster).

    Memoized (the seed re-imported hashlib and re-hashed the model name
    for every candidate of every wave) and keyed on a generation counter
    so :func:`invalidate_affinity_cache` can force recomputation.
    """
    return _preferred_devices_keyed(model, n_devices, k,
                                    _AFFINITY_GENERATION)


@dataclasses.dataclass
class WaveComponents:
    """Per-wave component cache behind one :class:`FrontierScores`.

    Holds every additive term of Ψ/EFT as its own [R, D] matrix (in
    ``planner_score``'s accumulation order), the materialized tail term
    vectors [R, K, D] for cheap refolds, and the state snapshots
    (residency row, frontier model counts, topology generation) that
    the delta engine diffs to prove which rows/columns are still valid.
    """
    sids: list
    models: list
    sigs: list                      # (prefix_group, model) or None
    row_of: dict
    base: np.ndarray                # [R, D]
    switch: np.ndarray
    transfer: np.ndarray
    prefix: np.ndarray
    locality: np.ndarray
    tail: np.ndarray
    res_bonus: np.ndarray
    spec_bonus: np.ndarray
    elig: np.ndarray                # [R, D] bool
    tail_terms: np.ndarray          # [R, K, D] scar-folded term vectors
    shared_frac: np.ndarray         # [R]
    prefill_frac: np.ndarray        # [R]
    constrained: list
    max_slots: list
    n_terms: list
    # snapshots (validity certificates for the next delta wave)
    res_model: list
    counts: dict
    generation: int
    model_vecs: dict
    warm: dict = dataclasses.field(default_factory=dict)
    sig_groups: dict = dataclasses.field(default_factory=dict)
    # identity of the Workflow these tables were built from: a NEW
    # Workflow object reusing the same wid must never match (fresh
    # objects restart at generation 0, so the counter alone cannot
    # distinguish them)
    wf: object = None


@dataclasses.dataclass
class FrontierScores:
    """Full frontier × device score tables for one planning wave.

    ``raw[i, j]`` is the slot-0 planner score Ψ of ready stage i on
    device j (NEG where ineligible); ``eft`` the state-corrected stage
    durations (inf where ineligible); ``base`` the unmasked base costs
    (the wave margin is an all-pairs mean in the scalar path).  Shard
    slot weights are derived on demand from the cached EFT rows.
    ``comp`` carries the component cache that lets the next wave be
    delta-rescored instead of rebuilt.
    """
    ready: list[str]
    devices: list[int]
    raw: np.ndarray                # [R, D]
    eft: np.ndarray                # [R, D]
    base: np.ndarray               # [R, D]
    eligible: np.ndarray           # [R, D] bool
    max_slots: list[int]
    constrained: list[bool]        # row has an eligibility restriction
    wait: np.ndarray               # [D]
    pressure: float
    shard_overhead: float
    lam_parallel: float
    lam_wait: float
    comp: Optional[WaveComponents] = None
    built_full: bool = False           # full build vs delta rescore

    def shard_weights(self, i: int, slot: int,
                      solo_best: float) -> np.ndarray:
        """Ψ for shard slot ``slot`` ≥ 1 of ready stage ``i`` — the
        vectorized twin of the scalar ``planner_score`` shard branch."""
        eft = self.eft[i]
        completion_new = np.maximum(solo_best, eft) / (slot + 1)
        overhead = solo_best * self.shard_overhead
        gain = (solo_best / slot - completion_new - overhead) \
            * self.lam_parallel
        gain = gain - self.lam_wait * self.wait
        gain = gain - self.pressure
        if not self.constrained[i]:
            return gain
        return np.where(self.eligible[i], gain, NEG)

    def restrict(self, cols: Sequence[int]) -> "FrontierScores":
        """Column-sliced copy for a device-pool subproblem.

        ``cols`` are column *positions* into :attr:`devices` (not device
        ids).  Every per-device table is sliced to the pool's columns so
        downstream row building, shard-weight derivation (``solo_best``
        becomes pool-local, by design) and solving see only the pool's
        devices; rows, scalars and the eligibility flags carry over
        unchanged.  Fancy indexing copies, so the slice never aliases
        the cached full-axis tables, and slicing the full column set in
        order reproduces the originals bit-for-bit.  The component
        cache is deliberately dropped (``comp=None``) — it is keyed to
        the full device axis and must never seed a delta rescore from a
        pool-shaped table.
        """
        idx = np.asarray(list(cols), dtype=int)
        return dataclasses.replace(
            self,
            devices=[self.devices[j] for j in idx],
            raw=self.raw[:, idx],
            eft=self.eft[:, idx],
            base=self.base[:, idx],
            eligible=self.eligible[:, idx],
            wait=self.wait[idx],
            comp=None,
        )


class _WaveCtx:
    """Per-wave scratch: cluster vectors, state gathers, lazy caches."""
    __slots__ = ("ids", "pos", "n_dev", "speeds", "tscale", "wait",
                 "res_model", "counts", "zeros", "model_vecs",
                 "warm_cache")

    def __init__(self, state: ExecutionState, counts: dict):
        cluster = state.cluster
        self.ids = cluster.ids()
        self.pos = {d: j for j, d in enumerate(self.ids)}
        self.n_dev = len(self.ids)
        self.speeds, self.tscale = cluster_arrays(cluster)
        free = np.array([state.free_at.get(d, 0.0) for d in self.ids])
        self.wait = np.maximum(0.0, free - state.now)
        self.res_model = [state.residency.get(d) for d in self.ids]
        self.counts = counts
        self.zeros = np.zeros(self.n_dev)
        self.model_vecs: dict = {}
        self.warm_cache: dict = {}


class Scorer:
    """State-aware scoring engine: S, Ψ/EFT, and their batched twins.

    One scorer serves many planning sessions: per-workflow topology
    caches (base-cost rows, tail term plans) persist across calls,
    keyed by workflow identity + generation, and are dropped via
    :meth:`forget_workflow` when a served workflow retires.  The
    scalar entry points (:meth:`runtime_score`, :meth:`planner_score`,
    :meth:`corrected_eft`) are the bit-parity reference for the
    batched ones (:meth:`score_matrix`, :meth:`rescore_matrix`).
    Call :meth:`set_frontier` (or :meth:`set_frontier_shared`) before
    scoring a wave — sibling demand and device pressure are
    frontier-wide inputs.
    """

    def __init__(self, state: ExecutionState, cost_model: CostModel,
                 params: Optional[ScoreParams] = None):
        self.state = state
        self.cm = cost_model
        self.p = params or ScoreParams()
        self._frontier_models: dict[str, int] = {}
        self._device_pressure_cost = 0.0
        # per-wid cache shards: O(1) eviction on workflow retirement
        self._cost_vecs: dict[str, dict] = {}
        self._tail_plans: dict[str, dict] = {}
        # (workflow object, generation) the caches were derived from
        self._wf_seen: dict[str, tuple] = {}
        self._cluster = state.cluster

    def rebind(self, state: ExecutionState) -> None:
        """Point this scorer (and its cost model) at another state view
        — e.g. a fresh :class:`PlanningOverlay` — while keeping the
        per-workflow topology caches warm across planning sessions."""
        self.state = state
        self.cm.state = state

    def forget_workflow(self, wid: str) -> None:
        """Drop per-workflow caches (serving: workflow retired)."""
        self._cost_vecs.pop(wid, None)
        self._tail_plans.pop(wid, None)
        self._wf_seen.pop(wid, None)

    def _check_generation(self, wf: Workflow) -> None:
        """Drop caches whose provenance is gone: a different cluster
        (base-cost rows fold in device speeds, which the wid keys
        cannot see), a NEW workflow object reusing a wid, or a bumped
        topology generation."""
        if self.state.cluster is not self._cluster:
            self._cost_vecs.clear()
            self._tail_plans.clear()
            self._wf_seen.clear()
            self._cluster = self.state.cluster
        seen = self._wf_seen.get(wf.wid)
        if seen is not None and (seen[0] is not wf
                                 or seen[1] != wf.generation):
            self.forget_workflow(wf.wid)
        self._wf_seen[wf.wid] = (wf, wf.generation)

    def set_frontier(self, wf: Workflow, ready: Sequence[str]) -> None:
        """Record frontier model demand + device pressure."""
        self._check_generation(wf)
        counts: dict[str, int] = {}
        for sid in ready:
            m = wf.stages[sid].model
            counts[m] = counts.get(m, 0) + 1
        self._frontier_models = counts
        self._device_pressure_cost = self._pressure(
            [(wf, sid) for sid in ready])

    def set_frontier_shared(self, wf: Workflow, ready: Sequence[str],
                            counts: dict[str, int],
                            pressure: float) -> None:
        """Shared-frontier variant: model demand and device pressure are
        merged across every in-flight workflow by the caller (the
        multi-workflow planner), so cross-DAG siblings raise residency
        demand exactly like same-DAG siblings do."""
        self._check_generation(wf)
        self._frontier_models = dict(counts)
        self._device_pressure_cost = pressure

    def _pressure(self, entries: Sequence[tuple]) -> float:
        """Displacement pressure for a (possibly merged) frontier."""
        n_dev = self.state.cluster.n
        ids = self.state.cluster.ids()
        speeds, _ = cluster_arrays(self.state.cluster)
        total = 0.0
        for wf, sid in entries:
            total += self._base_row_sum(wf, wf.stages[sid], ids, speeds,
                                        wf.num_queries)
        mean_base = total / max(len(entries) * n_dev, 1)
        # displacement only bites once primaries saturate the devices
        pressure = min(1.0, max(0.0, (len(entries) - 0.75 * n_dev)
                                / (0.5 * n_dev)))
        return mean_base * pressure

    # ------------------------------------------------------------------
    def runtime_score(self, wf: Workflow, stage: Stage,
                      device: int) -> float:
        """S(v, d | s_t)."""
        p = self.p
        q = wf.num_queries
        s = 0.0
        s -= p.lam_wait * self.state.wait_time(device)
        s -= p.lam_switch * self.cm.switch_cost(stage, device)
        if p.enable_locality:
            s -= p.lam_transfer * self.cm.transfer_cost(wf, stage, device, q)
            if stage.parents:
                colo = (self.state.parent_on_device(wf.wid, stage, device)
                        / len(stage.parents))
                s += p.lam_colo * colo * self.cm.base_cost(stage, device, q) \
                    * 0.25
        if p.enable_prefix:
            s += p.lam_prefix * self.cm.prefix_benefit(stage, device, q)
        if p.enable_same_model and self.state.is_resident(stage.model,
                                                          device):
            # small tie-breaker only: residency's real value is carried
            # by C_switch (immediate) and the horizon tail (future)
            prof = self.state.profiles[stage.model]
            s += p.lam_same_model * prof.switch_cost * p.bonus_factor
        return s

    # ------------------------------------------------------------------
    def _descendants_within(self, wf: Workflow, sid: str,
                            depth: int) -> list[tuple[str, int]]:
        out: list[tuple[str, int]] = []
        frontier = [(sid, 0)]
        seen = {sid}
        while frontier:
            cur, d = frontier.pop()
            if d >= depth:
                continue
            for ch in wf.stages[cur].children:
                if ch in seen:
                    continue
                seen.add(ch)
                out.append((ch, d + 1))
                frontier.append((ch, d + 1))
        return out

    def future_tail(self, wf: Workflow, stage: Stage, device: int) -> float:
        """Discounted downstream (and frontier-sibling) state-preservation
        value of placing v on d."""
        p = self.p
        if not p.enable_future or p.horizon <= 1:
            return 0.0
        q = wf.num_queries
        tail = 0.0
        resident_count = sum(
            1 for d2 in self.state.cluster.ids()
            if d2 != device and self.state.is_resident(stage.model, d2))
        scarcity = 1.0 / (1.0 + resident_count)
        # frontier-sibling demand: creating a NEW m(v) residency is worth
        # a share of the switch cost the queued same-model siblings would
        # otherwise pay (or wait out), with diminishing returns as more
        # devices already host the model.
        if not self.state.is_resident(stage.model, device):
            siblings = self._frontier_models.get(stage.model, 1) - 1
            # bounded by cluster size: queued siblings beyond the device
            # count add no marginal residency value within one wave (the
            # merged serving frontier can queue dozens of same-model
            # stages; an unbounded linear term would drown every other
            # signal and thrash residency)
            siblings = min(siblings, self.state.cluster.n)
            if siblings > 0:
                prof = self.state.profiles[stage.model]
                tail += (p.sibling_factor * siblings
                         * prof.switch_cost * scarcity)
        for uid, dist in self._descendants_within(wf, stage.sid,
                                                  p.horizon - 1):
            u = wf.stages[uid]
            g = p.gamma ** dist
            if u.model == stage.model:
                prof = self.state.profiles[u.model]
                tail += (g * 0.5 * p.lam_switch * prof.switch_cost
                         * scarcity)
            if (p.enable_prefix and stage.prefix_group is not None
                    and u.prefix_group == stage.prefix_group
                    and u.cache_reuse and u.model == stage.model):
                base_u = self.cm.base_cost(u, device, q)
                tail += (g * p.lam_prefix * base_u * u.prefill_fraction
                         * self.cm.p.prefix_saving)
            if p.enable_locality and dist == 1:
                # direct child inherits v's output: colocating later saves
                # β·σ(v,u); reward keeping that option cheap on d
                sigma_k = stage.output_tokens * q * u.comm_weight / 1000.0
                tail += g * p.lam_transfer * \
                    self.state.cluster.transfer_coef * sigma_k * 0.5
        return tail

    def corrected_eft(self, wf: Workflow, stage: Stage,
                      device: int) -> float:
        """State-corrected stage duration on d (no wait): ĉ(v,d,s)."""
        bd = self.cm.breakdown(wf, stage, device, wf.num_queries)
        return max(1e-6, bd.total)

    # ------------------------------------------------------------------
    def planner_score(self, wf: Workflow, stage: Stage, slot: int,
                      device: int, quality_base: float,
                      solo_best: float = 0.0) -> float:
        """Ψ(v, k, d | s_t, H).

        Slot 0 scores are an estimated-finish-time value in seconds:
        −(wait + state-corrected cost) plus the discounted future tail,
        so immediate efficiency and future-state quality share one unit
        and the planner's wave competition approximates completion-time
        impact (§3.2's  −C_imm + γ·V_future  decomposition).
        """
        p = self.p
        q = wf.num_queries
        if slot == 0:
            bd = self.cm.breakdown(wf, stage, device, q)
            eft = p.lam_wait * self.state.wait_time(device)
            eft += bd.base
            eft += p.lam_switch * bd.switch
            if p.enable_locality:
                eft += p.lam_transfer * bd.transfer
                eft -= p.lam_colo * bd.locality_benefit
            if p.enable_prefix:
                eft -= p.lam_prefix * bd.prefix_benefit
            psi = quality_base - eft
            psi += self.future_tail(wf, stage, device)
            if p.enable_same_model and self.state.is_resident(
                    stage.model, device):
                prof = self.state.profiles[stage.model]
                psi += p.lam_same_model * prof.switch_cost \
                    * p.bonus_factor
            # model-specialized placement preference (deep heterogeneous
            # workflows, §4.1 implementation summary): a stable per-model
            # device affinity that damps residency churn across waves.
            if p.specialize_factor and p.enable_same_model:
                prof = self.state.profiles[stage.model]
                if device in _preferred_devices(
                        stage.model, self.state.cluster.n):
                    psi += p.specialize_factor * prof.switch_cost
            return psi
        # extra shard slot: marginal completion-time gain minus occupancy.
        # Under device pressure (more ready stages than devices) taking a
        # device for a shard defers another stage's primary — charge that
        # opportunity cost so bounded shard execution activates only when
        # devices would otherwise idle (paper: "enables bounded
        # multi-device shard execution when beneficial").
        if not p.enable_shard or slot >= stage.max_shards:
            return float("-inf")
        # completion with this extra shard = the slowest partition; the
        # candidate device contributes its own STATE-CORRECTED per-query
        # cost (a cold/unswitched device can make sharding a net loss
        # even when the primary runs warm).
        corrected_d = self.corrected_eft(wf, stage, device)
        solo = solo_best if solo_best > 0 else corrected_d
        completion_new = max(solo, corrected_d) / (slot + 1)
        overhead = solo * self.cm.p.shard_overhead
        gain = (solo / slot - completion_new - overhead) * p.lam_parallel
        gain -= p.lam_wait * self.state.wait_time(device)
        gain -= self._device_pressure_cost
        return gain

    # ------------------------------------------------------------------
    # vectorized frontier engine
    # ------------------------------------------------------------------
    def _stage_cost_vec(self, wf: Workflow, stage: Stage,
                        ids: list[int]) -> np.ndarray:
        shard = self._cost_vecs.setdefault(wf.wid, {})
        v = shard.get(stage.sid)
        if v is None:
            v = np.array([stage.cost_on(d) for d in ids], dtype=float)
            shard[stage.sid] = v
        return v

    def _base_row(self, wf: Workflow, stage: Stage, ids: list[int],
                  speeds: np.ndarray, q: int) -> np.ndarray:
        """Cached per-device base-cost row (state-independent)."""
        shard = self._cost_vecs.setdefault(wf.wid, {})
        key = (stage.sid, "b")
        v = shard.get(key)
        if v is None:
            v = self._stage_cost_vec(wf, stage, ids) * q / speeds
            shard[key] = v
        return v

    def _base_row_sum(self, wf: Workflow, stage: Stage, ids: list[int],
                      speeds: np.ndarray, q: int) -> float:
        shard = self._cost_vecs.setdefault(wf.wid, {})
        key = (stage.sid, "bs")
        v = shard.get(key)
        if v is None:
            v = float(self._base_row(wf, stage, ids, speeds, q).sum())
            shard[key] = v
        return v

    def _model_vec(self, ctx: _WaveCtx, m: str) -> dict:
        """Per-model shared vectors (residency mask, scarcity, switch
        cost row, bonuses) for the current residency snapshot."""
        mv = ctx.model_vecs.get(m)
        if mv is not None:
            return mv
        p = self.p
        mask = np.array([rm == m for rm in ctx.res_model])
        mask_i = mask.astype(np.int64)
        scar = 1.0 / (1.0 + (int(mask_i.sum()) - mask_i))
        prof = self.state.profiles[m]
        mv = {
            "mask": mask,
            "scar": scar,
            "prof": prof,
            "switch": np.where(mask, 0.0,
                               prof.switch_cost * self.cm.p.switch_scale),
        }
        if p.enable_same_model:
            mv["res_bonus"] = np.where(
                mask, p.lam_same_model * prof.switch_cost * p.bonus_factor,
                0.0)
            if p.specialize_factor:
                pref = set(_preferred_devices(m, ctx.n_dev))
                mv["spec_bonus"] = np.where(
                    np.array([d in pref for d in ctx.ids]),
                    p.specialize_factor * prof.switch_cost, 0.0)
        ctx.model_vecs[m] = mv
        return mv

    def _gather_warm(self, ctx: _WaveCtx, sig: tuple) -> np.ndarray:
        """Warm-query vector for one (prefix-group, model) signature."""
        wq = ctx.warm_cache.get(sig)
        if wq is None:
            group, model = sig
            vals = []
            for d in ctx.ids:
                e = self.state.prefix.get(d, {}).get(group)
                vals.append(e.warm_queries
                            if e is not None and e.model == model else 0)
            wq = np.array(vals, dtype=np.int64)
            ctx.warm_cache[sig] = wq
        return wq

    def _tail_plan(self, wf: Workflow, sid: str,
                   ctx: _WaveCtx) -> tuple[np.ndarray, np.ndarray]:
        """Static tail term plan for one stage: ([K, D] payload rows in
        scalar DFS order, [K] bool flags marking scarcity-scaled terms).
        State-independent given topology + params, so cached per stage
        until the workflow's generation changes."""
        shard = self._tail_plans.setdefault(wf.wid, {})
        plan = shard.get(sid)
        if plan is not None and plan[0].shape[1] == ctx.n_dev:
            return plan
        p = self.p
        cm = self.cm
        s = wf.stages[sid]
        m = s.model
        prof = self.state.profiles[m]
        cluster = self.state.cluster
        q = wf.num_queries
        rows: list[np.ndarray] = []
        flags: list[bool] = []
        for uid, dist in wf.descendants_within(sid, p.horizon - 1):
            u = wf.stages[uid]
            g = p.gamma ** dist
            if u.model == m:
                rows.append(np.full(
                    ctx.n_dev, g * 0.5 * p.lam_switch * prof.switch_cost))
                flags.append(True)
            if (p.enable_prefix and s.prefix_group is not None
                    and u.prefix_group == s.prefix_group
                    and u.cache_reuse and u.model == m):
                base_u = self._base_row(wf, u, ctx.ids, ctx.speeds, q)
                rows.append(g * p.lam_prefix * base_u
                            * u.prefill_fraction * cm.p.prefix_saving)
                flags.append(False)
            if p.enable_locality and dist == 1:
                sigma_k = (s.output_tokens * q * u.comm_weight / 1000.0)
                rows.append(np.full(
                    ctx.n_dev, g * p.lam_transfer
                    * cluster.transfer_coef * sigma_k * 0.5))
                flags.append(False)
        plan = (np.array(rows) if rows else np.zeros((0, ctx.n_dev)),
                np.array(flags, dtype=bool))
        shard[sid] = plan
        return plan

    def _alloc(self, R: int, K: int, ctx: _WaveCtx) -> WaveComponents:
        n = ctx.n_dev
        return WaveComponents(
            sids=[None] * R, models=[None] * R, sigs=[None] * R,
            row_of={},
            base=np.empty((R, n)), switch=np.empty((R, n)),
            transfer=np.zeros((R, n)), prefix=np.zeros((R, n)),
            locality=np.zeros((R, n)), tail=np.zeros((R, n)),
            res_bonus=np.zeros((R, n)), spec_bonus=np.zeros((R, n)),
            elig=np.ones((R, n), dtype=bool),
            tail_terms=np.zeros((R, K, n)),
            shared_frac=np.zeros(R), prefill_frac=np.zeros(R),
            constrained=[False] * R, max_slots=[1] * R, n_terms=[0] * R,
            res_model=[], counts={}, generation=-1, model_vecs={})

    def _sib_row(self, ctx: _WaveCtx, mv: dict, m: str) -> np.ndarray:
        """Frontier-sibling tail seed for one row's model (sibling
        count bounded by cluster size, as in ``future_tail``)."""
        p = self.p
        siblings = min(ctx.counts.get(m, 1) - 1, ctx.n_dev)
        if siblings > 0:
            coef = p.sibling_factor * siblings * mv["prof"].switch_cost
            return np.where(~mv["mask"], coef * mv["scar"], 0.0)
        return ctx.zeros

    def _materialize_terms(self, wf: Workflow, sid: str, ctx: _WaveCtx,
                           mv: dict, comp: WaveComponents,
                           i: int) -> None:
        static, flags = self._tail_plan(wf, sid, ctx)
        k_i = static.shape[0]
        comp.n_terms[i] = k_i
        if k_i:
            fac = np.where(flags[:, None], mv["scar"][None, :], 1.0)
            comp.tail_terms[i, :k_i] = static * fac

    def _fold_tails(self, comp: WaveComponents, idxs: list[int],
                    sib_rows: list[np.ndarray]) -> None:
        """Sequential left fold (scalar accumulation order) of the
        cached term vectors on top of each row's sibling seed."""
        if not idxs:
            return
        ia = np.array(idxs)
        block = np.stack(sib_rows)
        terms = comp.tail_terms[ia]
        for k in range(terms.shape[1]):
            block = block + terms[:, k, :]
        comp.tail[ia] = block

    def _prefix_rows(self, wf: Workflow, comp: WaveComponents,
                     ctx: _WaveCtx, groups: dict) -> None:
        """Signature-batched prefix benefit: one 2-D pass per
        (prefix-group, model) signature."""
        cm = self.cm
        q = wf.num_queries
        for sig, grp in groups.items():
            wq = self._gather_warm(ctx, sig)
            ovb = np.minimum(1.0, wq / max(q, 1))
            gi = np.array(grp)
            ov = ovb[None, :] * comp.shared_frac[gi][:, None]
            base_g = comp.base[gi]
            comp.prefix[gi] = np.where(
                ov > 0.0,
                base_g * comp.prefill_frac[gi][:, None]
                * cm.p.prefix_saving * ov * cm.p.prefix_scale,
                0.0)

    def _fill_rows(self, wf: Workflow, rows: list[tuple[int, str]],
                   comp: WaveComponents, ctx: _WaveCtx) -> None:
        """Compute every component for the given (row index, sid) pairs
        over all devices — the full-build path, also used for
        newly-ready rows during delta rescoring."""
        p = self.p
        cm = self.cm
        state = self.state
        cluster = state.cluster
        q = wf.num_queries
        n_dev = ctx.n_dev
        pos = ctx.pos
        tscale = ctx.tscale
        future_on = p.enable_future and p.horizon > 1
        sig_groups: dict[tuple, list[int]] = {}
        tail_idx: list[int] = []
        sib_rows: list[np.ndarray] = []
        for i, sid in rows:
            s = wf.stages[sid]
            m = s.model
            mv = self._model_vec(ctx, m)
            comp.sids[i] = sid
            comp.models[i] = m
            comp.shared_frac[i] = s.shared_fraction
            comp.prefill_frac[i] = s.prefill_fraction
            comp.base[i] = self._base_row(wf, s, ctx.ids, ctx.speeds, q)
            comp.switch[i] = mv["switch"]
            if p.enable_same_model:
                comp.res_bonus[i] = mv["res_bonus"]
                if p.specialize_factor:
                    comp.spec_bonus[i] = mv["spec_bonus"]
            if s.parents:
                transfer = np.zeros(n_dev)
                for par in s.parents:
                    locs = state.output_loc.get((wf.wid, par), ())
                    if not locs:
                        continue
                    src = locs[0]
                    parent = wf.stages[par]
                    sigma_k = (parent.output_tokens * q
                               * s.comm_weight / 1000.0)
                    contrib = (cluster.transfer_coef
                               * tscale[pos[src]] * tscale) * sigma_k
                    local = np.zeros(n_dev, dtype=bool)
                    for d in locs:
                        if d in pos:
                            local[pos[d]] = True
                    transfer = transfer + np.where(local, 0.0, contrib)
                comp.transfer[i] = transfer * cm.p.transfer_scale
                cnt = np.zeros(n_dev)
                for par in s.parents:
                    for d in state.output_loc.get((wf.wid, par), ()):
                        if d in pos:
                            cnt[pos[d]] += 1
                frac = cnt / len(s.parents)
                comp.locality[i] = comp.base[i] * cm.p.locality_saving \
                    * frac
            else:
                comp.transfer[i] = 0.0
                comp.locality[i] = 0.0
            if s.cache_reuse and s.prefix_group is not None:
                sig = (s.prefix_group, s.model)
                comp.sigs[i] = sig
                sig_groups.setdefault(sig, []).append(i)
            else:
                comp.sigs[i] = None
                comp.prefix[i] = 0.0
            if s.eligible:
                comp.elig[i] = np.array(
                    [d in set(s.eligible) for d in ctx.ids])
                comp.constrained[i] = True
            else:
                comp.elig[i] = True
                comp.constrained[i] = False
            comp.max_slots[i] = s.max_shards if p.enable_shard else 1
            if future_on:
                self._materialize_terms(wf, sid, ctx, mv, comp, i)
                tail_idx.append(i)
                sib_rows.append(self._sib_row(ctx, mv, m))
            else:
                comp.tail[i] = 0.0
        self._prefix_rows(wf, comp, ctx, sig_groups)
        self._fold_tails(comp, tail_idx, sib_rows)

    def _assemble(self, comp: WaveComponents,
                  wait: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """One 2-D pass reproducing ``planner_score``'s exact term
        order, so every entry is bit-identical to the scalar path."""
        p = self.p
        wait_term = p.lam_wait * wait
        eft = wait_term[None, :] + comp.base
        eft = eft + p.lam_switch * comp.switch
        if p.enable_locality:
            eft = eft + p.lam_transfer * comp.transfer
            eft = eft - p.lam_colo * comp.locality
        if p.enable_prefix:
            eft = eft - p.lam_prefix * comp.prefix
        psi = 0.0 - eft
        psi = psi + comp.tail
        if p.enable_same_model:
            psi = psi + comp.res_bonus
            if p.specialize_factor:
                psi = psi + comp.spec_bonus
        total = comp.base + comp.switch + comp.transfer - comp.prefix \
            - comp.locality - 0.0
        eft_total = np.maximum(1e-6, total)
        raw = np.where(comp.elig, psi, NEG)
        eftm = np.where(comp.elig, eft_total, np.inf)
        return raw, eftm

    def _finalize(self, comp: WaveComponents, ctx: _WaveCtx,
                  built_full: bool = False) -> FrontierScores:
        if len(comp.row_of) != len(comp.sids):
            comp.row_of = {sid: i for i, sid in enumerate(comp.sids)}
            groups: dict = {}
            for i, sig in enumerate(comp.sigs):
                if sig is not None:
                    groups.setdefault(sig, []).append(i)
            comp.sig_groups = groups
        comp.res_model = list(ctx.res_model)
        comp.counts = dict(ctx.counts)
        comp.model_vecs = ctx.model_vecs
        comp.warm = dict(ctx.warm_cache)
        raw, eftm = self._assemble(comp, ctx.wait)
        return FrontierScores(
            ready=list(comp.sids), devices=ctx.ids, raw=raw, eft=eftm,
            base=comp.base, eligible=comp.elig,
            max_slots=list(comp.max_slots),
            constrained=list(comp.constrained), wait=ctx.wait,
            pressure=self._device_pressure_cost,
            shard_overhead=self.cm.p.shard_overhead,
            lam_parallel=self.p.lam_parallel, lam_wait=self.p.lam_wait,
            comp=comp, built_full=built_full)

    def _plan_k(self, wf: Workflow, ready: Sequence[str],
                ctx: _WaveCtx) -> int:
        if not (self.p.enable_future and self.p.horizon > 1):
            return 0
        k = 0
        for sid in ready:
            k = max(k, self._tail_plan(wf, sid, ctx)[0].shape[0])
        return k

    def score_matrix(self, wf: Workflow,
                     ready: Sequence[str]) -> FrontierScores:
        """Batched Ψ/EFT tables for the whole ready frontier.

        Computes, with signature-grouped 2-D numpy passes, exactly what
        ``planner_score(slot=0)`` + ``corrected_eft`` compute per
        (stage, device) pair — same term order, so results are
        bit-identical to the scalar path.  Call ``set_frontier`` first
        (as the planner does).
        """
        self._check_generation(wf)
        ctx = _WaveCtx(self.state, dict(self._frontier_models))
        comp = self._alloc(len(ready), self._plan_k(wf, ready, ctx), ctx)
        comp.generation = wf.generation
        comp.wf = wf
        self._fill_rows(wf, list(enumerate(ready)), comp, ctx)
        return self._finalize(comp, ctx, built_full=True)

    def _warm_entry(self, sig: tuple, device: int) -> int:
        group, model = sig
        e = self.state.prefix.get(device, {}).get(group)
        return e.warm_queries if e is not None and e.model == model else 0

    def _patch_warm(self, comp_p: WaveComponents, sigs: set,
                    dirty_pos: Optional[list[int]],
                    ctx: _WaveCtx) -> set:
        """Seed ``ctx.warm_cache`` for every carried signature and
        return the signatures whose warm vector moved.

        With a claimed dirty-device list, only those columns are
        re-read (the dirty-set protocol guarantees warm-prefix state is
        unchanged elsewhere).  With ``dirty_pos=None`` — no
        single-consumer claim available — each signature's vector is
        re-gathered in full and diffed against the snapshot, so
        correctness never depends on who drained the marks."""
        changed: set = set()
        for sig in sigs:
            wq = comp_p.warm.get(sig)
            if wq is None:                 # never gathered before
                changed.add(sig)
                self._gather_warm(ctx, sig)
                continue
            if dirty_pos is None:
                fresh = self._gather_warm(ctx, sig)
                if not np.array_equal(fresh, wq):
                    changed.add(sig)
                continue
            patched = None
            for j in dirty_pos:
                val = self._warm_entry(sig, ctx.ids[j])
                if val != wq[j]:
                    if patched is None:
                        patched = wq.copy()
                    patched[j] = val
            if patched is not None:
                changed.add(sig)
                wq = patched
            ctx.warm_cache[sig] = wq
        return changed

    def _refresh_dirty_rows(self, wf: Workflow, comp: WaveComponents,
                            ctx: _WaveCtx, rows: Sequence[int],
                            res_dirty: set, sib_dirty: set) -> None:
        """Re-derive per-model components for rows whose model's
        residency footprint or frontier sibling count changed."""
        p = self.p
        future_on = p.enable_future and p.horizon > 1
        refold_idx: list[int] = []
        sib_rows: list[np.ndarray] = []
        for i in rows:
            m = comp.models[i]
            if m in res_dirty:
                mv = self._model_vec(ctx, m)
                comp.switch[i] = mv["switch"]
                if p.enable_same_model:
                    comp.res_bonus[i] = mv["res_bonus"]
                    if p.specialize_factor:
                        comp.spec_bonus[i] = mv["spec_bonus"]
                if future_on:
                    self._materialize_terms(wf, comp.sids[i], ctx, mv,
                                            comp, i)
            if future_on and (m in res_dirty or m in sib_dirty):
                refold_idx.append(i)
                sib_rows.append(self._sib_row(
                    ctx, self._model_vec(ctx, m), m))
        self._fold_tails(comp, refold_idx, sib_rows)

    def rescore_matrix(self, wf: Workflow, ready: Sequence[str],
                       prev: Optional[FrontierScores] = None,
                       consume: bool = True,
                       dirty: Optional[set] = None) -> FrontierScores:
        """Incremental twin of :meth:`score_matrix`.

        Reuses the previous wave's component cache and recomputes only
        invalidated entries: rows of models whose residency footprint
        changed (mask/scarcity/switch vectors stale), rows of models
        whose frontier sibling count changed (tail seed stale — refolded
        from cached term vectors), newly-ready rows (full build), and
        prefix signatures whose warm state moved on a dirty device.
        Wait times enter only at assembly, so clock advancement never
        invalidates cached components.  Falls back to the full build
        when there is no usable previous wave.

        With ``consume=True`` (default) ``prev`` is CONSUMED: when the
        ready frontier is unchanged its component cache is recycled in
        place into the returned object, so never rescore twice from the
        same ``prev``.  Pass ``consume=False`` to keep ``prev`` intact
        (the planner does this when chaining intra-session waves off the
        preserved cross-session snapshot).  ``dirty`` is a claimed
        dirty-device set from a single-consumer ``drain_dirty()`` —
        when the caller can guarantee every state mutation since
        ``prev`` is marked in it (the planner's own intra-session
        waves), warm-prefix columns are patched only at those devices;
        a caller rescoring SEVERAL workflows for one wave must drain
        once and pass the same set to every call.  Without it
        (``dirty=None``), warm vectors are re-gathered in full and
        snapshot-diffed, so correctness never rests on mark ownership.
        Bit-identical to a fresh ``score_matrix`` call by construction;
        enforced by ``tests/test_delta_rescoring.py``.
        """
        self._check_generation(wf)
        comp_p = prev.comp if prev is not None else None
        if (comp_p is None or comp_p.wf is not wf
                or comp_p.generation != wf.generation
                or prev.devices != self.state.cluster.ids()):
            return self.score_matrix(wf, ready)
        p = self.p
        ctx = _WaveCtx(self.state, dict(self._frontier_models))
        dirty_pos = (None if dirty is None
                     else [ctx.pos[d] for d in dirty if d in ctx.pos])
        res_dirty: set[str] = set()
        for rm_old, rm_new in zip(comp_p.res_model, ctx.res_model):
            if rm_old != rm_new:
                if rm_old is not None:
                    res_dirty.add(rm_old)
                if rm_new is not None:
                    res_dirty.add(rm_new)
        for m, mv in comp_p.model_vecs.items():
            if m not in res_dirty:
                ctx.model_vecs[m] = mv

        if consume and list(ready) == comp_p.sids:
            # steady-state fast path: same frontier, recycle in place
            comp = comp_p
            sib_dirty = {m for m in set(comp.models)
                         if ctx.counts.get(m, 0)
                         != comp_p.counts.get(m, 0)}
            self._refresh_dirty_rows(wf, comp, ctx, range(len(ready)),
                                     res_dirty, sib_dirty)
            changed = self._patch_warm(comp_p, set(comp.sig_groups),
                                       dirty_pos, ctx)
            if changed:
                self._prefix_rows(wf, comp, ctx, {
                    sig: comp.sig_groups[sig] for sig in changed})
            return self._finalize(comp, ctx)

        new_rows: list[int] = []
        carried: list[tuple[int, int]] = []
        for i, sid in enumerate(ready):
            j = comp_p.row_of.get(sid)
            if j is None:
                new_rows.append(i)
            else:
                carried.append((i, j))
        comp = self._alloc(len(ready), self._plan_k(wf, ready, ctx), ctx)
        comp.generation = wf.generation
        comp.wf = wf
        if carried:
            inew = np.array([i for i, _ in carried])
            iold = np.array([j for _, j in carried])
            for name in ("base", "switch", "transfer", "prefix",
                         "locality", "tail", "res_bonus", "spec_bonus",
                         "elig", "shared_frac", "prefill_frac"):
                getattr(comp, name)[inew] = getattr(comp_p, name)[iold]
            kcopy = min(comp.tail_terms.shape[1],
                        comp_p.tail_terms.shape[1])
            if kcopy:
                comp.tail_terms[inew, :kcopy] = \
                    comp_p.tail_terms[iold, :kcopy]
            for i, j in carried:
                comp.sids[i] = comp_p.sids[j]
                comp.models[i] = comp_p.models[j]
                comp.sigs[i] = comp_p.sigs[j]
                comp.constrained[i] = comp_p.constrained[j]
                comp.max_slots[i] = comp_p.max_slots[j]
                comp.n_terms[i] = comp_p.n_terms[j]
        # warm state first, so new-row fills see patched gathers
        carried_sigs = {comp.sigs[i] for i, _ in carried
                        if comp.sigs[i] is not None}
        changed = self._patch_warm(comp_p, carried_sigs, dirty_pos, ctx)
        if new_rows:
            self._fill_rows(wf, [(i, ready[i]) for i in new_rows],
                            comp, ctx)
        sib_dirty = {m for m in {comp.models[i] for i, _ in carried}
                     if ctx.counts.get(m, 0) != comp_p.counts.get(m, 0)}
        self._refresh_dirty_rows(wf, comp, ctx,
                                 [i for i, _ in carried],
                                 res_dirty, sib_dirty)
        if changed:
            groups: dict[tuple, list[int]] = {}
            for i, _ in carried:
                if comp.sigs[i] in changed:
                    groups.setdefault(comp.sigs[i], []).append(i)
            self._prefix_rows(wf, comp, ctx, groups)
        return self._finalize(comp, ctx)
