"""State-aware scoring: runtime score S(v,d|s) and horizon-aware
planner score Ψ(v,k,d|s,H)  (paper §3.3–3.4, Appendix A.3).

    S(v,d|s) = −λ_q C_wait − λ_s C_switch − λ_tr C_transfer
               + λ_c B_colo + λ_p B_prefix + λ_r B_parallel
               (+ λ_m B_same_model — the "same-model bonus", ablated
                separately from switch cost per Appendix C.3)

    Ψ(v,k,d|s,H) = quality_base + S-terms (+ marginal shard gain for
                   k>0) + Σ_{u ∈ Desc_H(v)} γ^{dist(u)} · tail(u, v, d)

The tail folds downstream demand into current-frontier candidates
without expanding future stages into solver variables (paper §3.3):
  * same-model continuation — placing v on d keeps m(v) resident where
    descendant u (same model) could continue, weighted by how scarce
    m(v)-residency currently is;
  * prefix affinity — placing v on d warms grp(v) state that matching
    descendants can reuse;
  * child transfer pressure — direct children inherit v's output.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import Optional, Sequence

import numpy as np

from repro.core.costs import CostModel, cluster_arrays
from repro.core.frontier_solver import NEG
from repro.core.state import ExecutionState
from repro.core.workflow import Stage, Workflow


@dataclasses.dataclass(frozen=True)
class ScoreParams:
    lam_wait: float = 1.0          # λ_q
    lam_switch: float = 1.0        # λ_s
    lam_transfer: float = 1.0      # λ_tr
    lam_colo: float = 0.6          # λ_c
    lam_prefix: float = 1.5        # λ_p
    lam_parallel: float = 0.9      # λ_r
    lam_same_model: float = 0.5    # λ_m (same-model bonus)
    horizon: int = 4               # H (levels; 1 = frontier only)
    gamma: float = 0.6             # level discount
    sibling_factor: float = 0.4    # frontier-sibling demand folding
    bonus_factor: float = 0.4      # same-model bonus scale (of switch)
    margin_factor: float = 0.1     # wave regret margin (of mean base)
    specialize_factor: float = 0.15  # model-specialized device preference
    # ablation switches (Appendix C.3)
    enable_future: bool = True
    enable_locality: bool = True
    enable_same_model: bool = True
    enable_prefix: bool = True
    enable_shard: bool = True

    def scaled(self, *, state_mul: float = 1.0, locality_mul: float = 1.0,
               prefix_mul: float = 1.0) -> "ScoreParams":
        """Table 10 sensitivity: scale term groups."""
        return dataclasses.replace(
            self,
            lam_switch=self.lam_switch * state_mul,
            lam_same_model=self.lam_same_model * state_mul,
            lam_colo=self.lam_colo * locality_mul,
            lam_transfer=self.lam_transfer * locality_mul,
            lam_prefix=self.lam_prefix * prefix_mul,
        )


@functools.lru_cache(maxsize=4096)
def _preferred_devices(model: str, n_devices: int,
                       k: int = 2) -> tuple[int, ...]:
    """Stable per-model device affinity (hash-spread over the cluster).

    Memoized: the seed re-imported hashlib and re-hashed the model name
    for every candidate of every wave.
    """
    h = int(hashlib.sha256(model.encode()).hexdigest()[:8], 16)
    return tuple((h + i * 3) % n_devices for i in range(k))


@dataclasses.dataclass
class FrontierScores:
    """Full frontier × device score tables for one planning wave.

    ``raw[i, j]`` is the slot-0 planner score Ψ of ready stage i on
    device j (NEG where ineligible); ``eft`` the state-corrected stage
    durations (inf where ineligible); ``base`` the unmasked base costs
    (the wave margin is an all-pairs mean in the scalar path).  Shard
    slot weights are derived on demand from the cached EFT rows.
    """
    ready: list[str]
    devices: list[int]
    raw: np.ndarray                # [R, D]
    eft: np.ndarray                # [R, D]
    base: np.ndarray               # [R, D]
    eligible: np.ndarray           # [R, D] bool
    max_slots: list[int]
    constrained: list[bool]        # row has an eligibility restriction
    wait: np.ndarray               # [D]
    pressure: float
    shard_overhead: float
    lam_parallel: float
    lam_wait: float

    def shard_weights(self, i: int, slot: int,
                      solo_best: float) -> np.ndarray:
        """Ψ for shard slot ``slot`` ≥ 1 of ready stage ``i`` — the
        vectorized twin of the scalar ``planner_score`` shard branch."""
        eft = self.eft[i]
        completion_new = np.maximum(solo_best, eft) / (slot + 1)
        overhead = solo_best * self.shard_overhead
        gain = (solo_best / slot - completion_new - overhead) \
            * self.lam_parallel
        gain = gain - self.lam_wait * self.wait
        gain = gain - self.pressure
        if not self.constrained[i]:
            return gain
        return np.where(self.eligible[i], gain, NEG)


class Scorer:
    def __init__(self, state: ExecutionState, cost_model: CostModel,
                 params: Optional[ScoreParams] = None):
        self.state = state
        self.cm = cost_model
        self.p = params or ScoreParams()
        self._frontier_models: dict[str, int] = {}
        self._device_pressure_cost = 0.0
        self._cost_vecs: dict[tuple[str, str], np.ndarray] = {}

    def set_frontier(self, wf: Workflow, ready: Sequence[str]) -> None:
        """Record frontier model demand + device pressure."""
        self._frontier_models = {}
        for sid in ready:
            m = wf.stages[sid].model
            self._frontier_models[m] = self._frontier_models.get(m, 0) + 1
        n_dev = self.state.cluster.n
        # mean over ALL devices: pricing pressure off device 0 alone
        # biased shard displacement on heterogeneous clusters.
        ids = self.state.cluster.ids()
        speeds, _ = cluster_arrays(self.state.cluster)
        q = wf.num_queries
        total = 0.0
        for sid in ready:
            total += float(
                self._base_row(wf, wf.stages[sid], ids, speeds, q).sum())
        mean_base = total / max(len(ready) * n_dev, 1)
        # displacement only bites once primaries saturate the devices
        pressure = min(1.0, max(0.0, (len(ready) - 0.75 * n_dev)
                                / (0.5 * n_dev)))
        self._device_pressure_cost = mean_base * pressure

    # ------------------------------------------------------------------
    def runtime_score(self, wf: Workflow, stage: Stage,
                      device: int) -> float:
        """S(v, d | s_t)."""
        p = self.p
        q = wf.num_queries
        s = 0.0
        s -= p.lam_wait * self.state.wait_time(device)
        s -= p.lam_switch * self.cm.switch_cost(stage, device)
        if p.enable_locality:
            s -= p.lam_transfer * self.cm.transfer_cost(wf, stage, device, q)
            if stage.parents:
                colo = (self.state.parent_on_device(wf.wid, stage, device)
                        / len(stage.parents))
                s += p.lam_colo * colo * self.cm.base_cost(stage, device, q) \
                    * 0.25
        if p.enable_prefix:
            s += p.lam_prefix * self.cm.prefix_benefit(stage, device, q)
        if p.enable_same_model and self.state.is_resident(stage.model,
                                                          device):
            # small tie-breaker only: residency's real value is carried
            # by C_switch (immediate) and the horizon tail (future)
            prof = self.state.profiles[stage.model]
            s += p.lam_same_model * prof.switch_cost * p.bonus_factor
        return s

    # ------------------------------------------------------------------
    def _descendants_within(self, wf: Workflow, sid: str,
                            depth: int) -> list[tuple[str, int]]:
        out: list[tuple[str, int]] = []
        frontier = [(sid, 0)]
        seen = {sid}
        while frontier:
            cur, d = frontier.pop()
            if d >= depth:
                continue
            for ch in wf.stages[cur].children:
                if ch in seen:
                    continue
                seen.add(ch)
                out.append((ch, d + 1))
                frontier.append((ch, d + 1))
        return out

    def future_tail(self, wf: Workflow, stage: Stage, device: int) -> float:
        """Discounted downstream (and frontier-sibling) state-preservation
        value of placing v on d."""
        p = self.p
        if not p.enable_future or p.horizon <= 1:
            return 0.0
        q = wf.num_queries
        tail = 0.0
        resident_count = sum(
            1 for d2 in self.state.cluster.ids()
            if d2 != device and self.state.is_resident(stage.model, d2))
        scarcity = 1.0 / (1.0 + resident_count)
        # frontier-sibling demand: creating a NEW m(v) residency is worth
        # a share of the switch cost the queued same-model siblings would
        # otherwise pay (or wait out), with diminishing returns as more
        # devices already host the model.
        if not self.state.is_resident(stage.model, device):
            siblings = self._frontier_models.get(stage.model, 1) - 1
            if siblings > 0:
                prof = self.state.profiles[stage.model]
                tail += (p.sibling_factor * siblings
                         * prof.switch_cost * scarcity)
        for uid, dist in self._descendants_within(wf, stage.sid,
                                                  p.horizon - 1):
            u = wf.stages[uid]
            g = p.gamma ** dist
            if u.model == stage.model:
                prof = self.state.profiles[u.model]
                tail += (g * 0.5 * p.lam_switch * prof.switch_cost
                         * scarcity)
            if (p.enable_prefix and stage.prefix_group is not None
                    and u.prefix_group == stage.prefix_group
                    and u.cache_reuse and u.model == stage.model):
                base_u = self.cm.base_cost(u, device, q)
                tail += (g * p.lam_prefix * base_u * u.prefill_fraction
                         * self.cm.p.prefix_saving)
            if p.enable_locality and dist == 1:
                # direct child inherits v's output: colocating later saves
                # β·σ(v,u); reward keeping that option cheap on d
                sigma_k = stage.output_tokens * q * u.comm_weight / 1000.0
                tail += g * p.lam_transfer * \
                    self.state.cluster.transfer_coef * sigma_k * 0.5
        return tail

    def corrected_eft(self, wf: Workflow, stage: Stage,
                      device: int) -> float:
        """State-corrected stage duration on d (no wait): ĉ(v,d,s)."""
        bd = self.cm.breakdown(wf, stage, device, wf.num_queries)
        return max(1e-6, bd.total)

    # ------------------------------------------------------------------
    def planner_score(self, wf: Workflow, stage: Stage, slot: int,
                      device: int, quality_base: float,
                      solo_best: float = 0.0) -> float:
        """Ψ(v, k, d | s_t, H).

        Slot 0 scores are an estimated-finish-time value in seconds:
        −(wait + state-corrected cost) plus the discounted future tail,
        so immediate efficiency and future-state quality share one unit
        and the planner's wave competition approximates completion-time
        impact (§3.2's  −C_imm + γ·V_future  decomposition).
        """
        p = self.p
        q = wf.num_queries
        if slot == 0:
            bd = self.cm.breakdown(wf, stage, device, q)
            eft = p.lam_wait * self.state.wait_time(device)
            eft += bd.base
            eft += p.lam_switch * bd.switch
            if p.enable_locality:
                eft += p.lam_transfer * bd.transfer
                eft -= p.lam_colo * bd.locality_benefit
            if p.enable_prefix:
                eft -= p.lam_prefix * bd.prefix_benefit
            psi = quality_base - eft
            psi += self.future_tail(wf, stage, device)
            if p.enable_same_model and self.state.is_resident(
                    stage.model, device):
                prof = self.state.profiles[stage.model]
                psi += p.lam_same_model * prof.switch_cost \
                    * p.bonus_factor
            # model-specialized placement preference (deep heterogeneous
            # workflows, §4.1 implementation summary): a stable per-model
            # device affinity that damps residency churn across waves.
            if p.specialize_factor and p.enable_same_model:
                prof = self.state.profiles[stage.model]
                if device in _preferred_devices(
                        stage.model, self.state.cluster.n):
                    psi += p.specialize_factor * prof.switch_cost
            return psi
        # extra shard slot: marginal completion-time gain minus occupancy.
        # Under device pressure (more ready stages than devices) taking a
        # device for a shard defers another stage's primary — charge that
        # opportunity cost so bounded shard execution activates only when
        # devices would otherwise idle (paper: "enables bounded
        # multi-device shard execution when beneficial").
        if not p.enable_shard or slot >= stage.max_shards:
            return float("-inf")
        # completion with this extra shard = the slowest partition; the
        # candidate device contributes its own STATE-CORRECTED per-query
        # cost (a cold/unswitched device can make sharding a net loss
        # even when the primary runs warm).
        corrected_d = self.corrected_eft(wf, stage, device)
        solo = solo_best if solo_best > 0 else corrected_d
        completion_new = max(solo, corrected_d) / (slot + 1)
        overhead = solo * self.cm.p.shard_overhead
        gain = (solo / slot - completion_new - overhead) * p.lam_parallel
        gain -= p.lam_wait * self.state.wait_time(device)
        gain -= self._device_pressure_cost
        return gain

    # ------------------------------------------------------------------
    # vectorized frontier engine
    # ------------------------------------------------------------------
    def _stage_cost_vec(self, wf: Workflow, stage: Stage,
                        ids: list[int]) -> np.ndarray:
        key = (wf.wid, stage.sid)
        v = self._cost_vecs.get(key)
        if v is None:
            v = np.array([stage.cost_on(d) for d in ids], dtype=float)
            self._cost_vecs[key] = v
        return v

    def _base_row(self, wf: Workflow, stage: Stage, ids: list[int],
                  speeds: np.ndarray, q: int) -> np.ndarray:
        """Cached per-device base-cost row (state-independent)."""
        key = (wf.wid, stage.sid, "b")
        v = self._cost_vecs.get(key)
        if v is None:
            v = self._stage_cost_vec(wf, stage, ids) * q / speeds
            self._cost_vecs[key] = v
        return v

    def score_matrix(self, wf: Workflow,
                     ready: Sequence[str]) -> FrontierScores:
        """Batched Ψ/EFT tables for the whole ready frontier.

        Computes, with one pass of numpy vector ops per ready stage,
        exactly what ``planner_score(slot=0)`` + ``corrected_eft``
        compute per (stage, device) pair — same term order, so results
        are bit-identical to the scalar path.  Call ``set_frontier``
        first (as the planner does).
        """
        p = self.p
        state = self.state
        cm = self.cm
        q = wf.num_queries
        cluster = state.cluster
        ids = cluster.ids()
        n_dev = len(ids)
        pos = {d: j for j, d in enumerate(ids)}
        speeds, tscale = cluster_arrays(cluster)

        free = np.array([state.free_at.get(d, 0.0) for d in ids])
        wait = np.maximum(0.0, free - state.now)
        res_model = [state.residency.get(d) for d in ids]

        models = {wf.stages[sid].model for sid in ready}
        res_mask: dict[str, np.ndarray] = {}
        scarcity: dict[str, np.ndarray] = {}
        switch_vec: dict[str, np.ndarray] = {}
        res_bonus: dict[str, np.ndarray] = {}
        spec_bonus: dict[str, np.ndarray] = {}
        for m in models:
            mask = np.array([rm == m for rm in res_model])
            res_mask[m] = mask
            mask_i = mask.astype(np.int64)
            scarcity[m] = 1.0 / (1.0 + (int(mask_i.sum()) - mask_i))
            prof = state.profiles[m]
            switch_vec[m] = np.where(
                mask, 0.0, prof.switch_cost * cm.p.switch_scale)
            if p.enable_same_model:
                res_bonus[m] = np.where(
                    mask,
                    p.lam_same_model * prof.switch_cost * p.bonus_factor,
                    0.0)
                if p.specialize_factor:
                    pref = set(_preferred_devices(m, n_dev))
                    spec_bonus[m] = np.where(
                        np.array([d in pref for d in ids]),
                        p.specialize_factor * prof.switch_cost, 0.0)

        # warm-prefix queries per (group, model), gathered once per wave
        warm: dict[tuple[str, str], np.ndarray] = {}
        for sid in ready:
            s = wf.stages[sid]
            if s.prefix_group is None or not s.cache_reuse:
                continue
            key = (s.prefix_group, s.model)
            if key in warm:
                continue
            wq = []
            for d in ids:
                e = state.prefix.get(d, {}).get(s.prefix_group)
                wq.append(e.warm_queries
                          if e is not None and e.model == s.model else 0)
            warm[key] = np.array(wq, dtype=np.int64)

        zeros = np.zeros(n_dev)
        wait_term = p.lam_wait * wait
        R = len(ready)
        raw = np.empty((R, n_dev))
        eftm = np.empty((R, n_dev))
        basem = np.empty((R, n_dev))
        eligm = np.empty((R, n_dev), dtype=bool)
        max_slots: list[int] = []
        constrained: list[bool] = []

        for i, sid in enumerate(ready):
            s = wf.stages[sid]
            m = s.model
            prof = state.profiles[m]
            mask = res_mask[m]
            base = self._base_row(wf, s, ids, speeds, q)

            switch = switch_vec[m]

            transfer = zeros
            if s.parents:
                transfer = np.zeros(n_dev)
                for par in s.parents:
                    locs = state.output_loc.get((wf.wid, par), ())
                    if not locs:
                        continue
                    src = locs[0]
                    parent = wf.stages[par]
                    sigma_k = (parent.output_tokens * q
                               * s.comm_weight / 1000.0)
                    contrib = (cluster.transfer_coef
                               * tscale[pos[src]] * tscale) * sigma_k
                    local = np.zeros(n_dev, dtype=bool)
                    for d in locs:
                        if d in pos:
                            local[pos[d]] = True
                    transfer = transfer + np.where(local, 0.0, contrib)
                transfer = transfer * cm.p.transfer_scale

            if (s.cache_reuse and s.prefix_group is not None
                    and warm[(s.prefix_group, s.model)].any()):
                wq = warm[(s.prefix_group, s.model)]
                ov = np.minimum(1.0, wq / max(q, 1)) * s.shared_fraction
                prefix = np.where(
                    ov > 0.0,
                    base * s.prefill_fraction * cm.p.prefix_saving
                    * ov * cm.p.prefix_scale,
                    0.0)
            else:
                prefix = zeros

            if s.parents:
                cnt = np.zeros(n_dev)
                for par in s.parents:
                    for d in state.output_loc.get((wf.wid, par), ()):
                        if d in pos:
                            cnt[pos[d]] += 1
                frac = cnt / len(s.parents)
                locality = base * cm.p.locality_saving * frac
            else:
                locality = zeros

            # discounted future tail, accumulated in the scalar DFS order
            tail = zeros
            if p.enable_future and p.horizon > 1:
                tail = np.zeros(n_dev)
                scar = scarcity[m]
                siblings = self._frontier_models.get(m, 1) - 1
                if siblings > 0:
                    coef = p.sibling_factor * siblings * prof.switch_cost
                    tail = tail + np.where(~mask, coef * scar, 0.0)
                for uid, dist in wf.descendants_within(sid, p.horizon - 1):
                    u = wf.stages[uid]
                    g = p.gamma ** dist
                    if u.model == m:
                        tail = tail + (g * 0.5 * p.lam_switch
                                       * prof.switch_cost) * scar
                    if (p.enable_prefix and s.prefix_group is not None
                            and u.prefix_group == s.prefix_group
                            and u.cache_reuse and u.model == m):
                        base_u = self._base_row(wf, u, ids, speeds, q)
                        tail = tail + g * p.lam_prefix * base_u \
                            * u.prefill_fraction * cm.p.prefix_saving
                    if p.enable_locality and dist == 1:
                        sigma_k = (s.output_tokens * q
                                   * u.comm_weight / 1000.0)
                        tail = tail + g * p.lam_transfer \
                            * cluster.transfer_coef * sigma_k * 0.5

            # assemble Ψ in planner_score's exact accumulation order
            eft = wait_term + base
            eft = eft + p.lam_switch * switch
            if p.enable_locality:
                eft = eft + p.lam_transfer * transfer
                eft = eft - p.lam_colo * locality
            if p.enable_prefix:
                eft = eft - p.lam_prefix * prefix
            psi = 0.0 - eft
            psi = psi + tail
            if p.enable_same_model:
                psi = psi + res_bonus[m]
                if p.specialize_factor:
                    psi = psi + spec_bonus[m]

            total = base + switch + transfer - prefix - locality - 0.0
            eft_total = np.maximum(1e-6, total)

            if s.eligible:
                elig = np.array([d in set(s.eligible) for d in ids])
                raw[i] = np.where(elig, psi, NEG)
                eftm[i] = np.where(elig, eft_total, np.inf)
                eligm[i] = elig
                constrained.append(True)
            else:
                raw[i] = psi
                eftm[i] = eft_total
                eligm[i] = True
                constrained.append(False)
            basem[i] = base
            max_slots.append(s.max_shards if p.enable_shard else 1)

        return FrontierScores(
            ready=list(ready), devices=ids, raw=raw, eft=eftm,
            base=basem, eligible=eligm, max_slots=max_slots,
            constrained=constrained, wait=wait,
            pressure=self._device_pressure_cost,
            shard_overhead=cm.p.shard_overhead,
            lam_parallel=p.lam_parallel, lam_wait=p.lam_wait)
