"""Exact solver specialized to FATE's frontier placement problem.

The frontier ILP (Appendix A.2) is an assignment problem over
(stage-slot × device) with one side constraint family — monotone slot
activation.  We solve it exactly with branch-and-bound whose relaxation
drops only monotonicity and is solved by the Hungarian algorithm
(``scipy.optimize.linear_sum_assignment``):

  * relaxation optimum is an admissible upper bound;
  * if the relaxed solution already satisfies monotonicity it is OPTIMAL
    for the full problem (the common case: slot-0 scores dominate);
  * otherwise branch on a violated stage: (A) forbid the violating
    higher slot, (B) force the lower slot to be assigned.

Every solve returns status OPTIMAL with the true optimum (the paper's
Table 12 reports all-OPTIMAL CP-SAT solves; our analogue benchmark
reports the same property for this solver).  The generic
``repro.core.cpsat`` solver cross-validates this one in the tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np
from scipy.optimize import linear_sum_assignment

NEG = -1e15


@dataclasses.dataclass
class FrontierProblem:
    """weights[r][c]: score of placing row r = (stage, slot) on device c;
    -inf (<= NEG) marks ineligible pairs.  rows lists (stage_key, slot).

    ``hint`` is an optional warm-start vector mapping row keys
    ``(stage_key, slot)`` to device ids — typically the previous wave's
    solution.  The solver turns it into a feasible incumbent that seeds
    branch-and-bound pruning; it never changes the returned optimum (or
    which optimal assignment is returned — see
    :func:`solve_frontier_exact`).  Entries for rows or devices absent
    from this problem are ignored, so a stale hint is always safe.

    ``exclusive`` optionally lists mutual-exclusion groups of stage
    keys: within each group at most ONE key may have any assigned rows
    in a feasible solution.  The cost/quality router uses this to offer
    one stage under several model families — ``(wid, sid)`` plus its
    ``(wid, sid, alias)`` variants form one group — while guaranteeing
    a single family wins the stage.  ``None``/empty adds no constraint
    and no branching, so unrouted problems solve identically.
    """
    rows: list[tuple]             # (stage_key, slot_index)
    devices: list[int]
    weights: np.ndarray           # [n_rows, n_devices]
    hint: Optional[dict] = None   # (stage_key, slot) -> device id
    exclusive: Optional[list[list]] = None   # groups of stage keys

    def slot_rows(self, stage_key) -> list[int]:
        """Row indices belonging to ``stage_key`` (all slots)."""
        return [i for i, (s, _) in enumerate(self.rows) if s == stage_key]


def merge_problems(problems: list[FrontierProblem]) -> FrontierProblem:
    """Stack per-workflow frontier problems into one shared problem.

    All inputs must share the device axis (same ids, same order); rows
    keep their own keys — the shared-frontier planner keys them by
    ``(wid, sid)`` so stage ids from different DAGs never collide.  A
    single merged solve lets many in-flight workflows compete for the
    same devices under one exact optimum instead of sequential
    per-workflow greedy carve-outs.
    """
    if not problems:
        raise ValueError("merge_problems: empty problem list")
    devices = problems[0].devices
    for pr in problems[1:]:
        if pr.devices != devices:
            raise ValueError("merge_problems: mismatched device axes")
    rows: list[tuple] = []
    hint: dict = {}
    exclusive: list[list] = []
    for pr in problems:
        rows.extend(pr.rows)
        if pr.hint:
            hint.update(pr.hint)   # (wid, sid)-keyed rows never collide
        if pr.exclusive:
            exclusive.extend(pr.exclusive)
    weights = np.concatenate([pr.weights for pr in problems], axis=0)
    return FrontierProblem(rows, devices, weights, hint=hint or None,
                           exclusive=exclusive or None)


@dataclasses.dataclass
class FrontierSolution:
    """Result of one exact frontier solve: the optimal (or incumbent,
    on timeout) ``(stage_key, slot) -> device`` assignment plus solver
    statistics for the Table 12 analogue."""
    status: str
    objective: float
    assignment: dict[tuple, int]  # (stage_key, slot) -> device id
    wall_time: float
    nodes: int


def combine_solutions(sols: list["FrontierSolution"]) -> "FrontierSolution":
    """Union of per-pool solutions from a hierarchical sharded solve.

    The pools partition both the device axis and the row set, so the
    per-pool assignments are disjoint on rows *and* devices and their
    union is a feasible assignment of the original merged problem.
    Assignment insertion order follows pool order (the caller solves
    pools in index order), keeping downstream materialization
    deterministic.  Objective/nodes/wall-clock are summed; status
    degrades to the weakest member (any non-OPTIMAL pool makes the
    combined solve FEASIBLE).
    """
    if not sols:
        return FrontierSolution("OPTIMAL", 0.0, {}, 0.0, 0)
    assignment: dict[tuple, int] = {}
    for s in sols:
        assignment.update(s.assignment)
    status = "OPTIMAL" if all(s.status == "OPTIMAL" for s in sols) \
        else "FEASIBLE"
    return FrontierSolution(
        status=status,
        objective=float(sum(s.objective for s in sols)),
        assignment=assignment,
        wall_time=float(sum(s.wall_time for s in sols)),
        nodes=int(sum(s.nodes for s in sols)),
    )


_AUG_BUFFERS: dict[tuple[int, int], np.ndarray] = {}


def _aug_buffer(n_r: int, n_c: int) -> np.ndarray:
    """Reusable augmented-cost scratch matrix for `_hungarian`.

    The branch-and-bound loop calls the relaxation many times per solve
    with an identical shape; reusing one buffer per shape avoids a
    fresh (n_r × (n_c+n_r)) allocation per node.

    NOT thread-safe: concurrent solves with the same shape would share
    scratch; keep frontier solves on one thread (process-parallelism is
    fine) or make this thread-local first."""
    buf = _AUG_BUFFERS.get((n_r, n_c))
    if buf is None:
        buf = np.empty((n_r, n_c + n_r))
        if len(_AUG_BUFFERS) > 32:       # bound the cache
            _AUG_BUFFERS.clear()
        _AUG_BUFFERS[(n_r, n_c)] = buf
    buf.fill(NEG)
    return buf


def _hungarian(weights: np.ndarray, forced: set[int],
               banned: set[int]) -> Optional[tuple[float, dict[int, int]]]:
    """Max-weight assignment; rows may stay unassigned unless forced.

    Implemented by augmenting with per-row dummy columns of weight 0
    (or -inf for forced rows).  Returns (objective, {row: col}) over
    real columns only, or None if a forced row cannot be placed.
    """
    n_r, n_c = weights.shape
    aug = _aug_buffer(n_r, n_c)
    aug[:, :n_c] = weights
    for r in range(n_r):
        if r in banned:
            aug[r, :n_c] = NEG
        aug[r, n_c + r] = NEG if r in forced else 0.0
    rr, cc = linear_sum_assignment(aug, maximize=True)
    obj = 0.0
    out: dict[int, int] = {}
    for r, c in zip(rr, cc):
        v = aug[r, c]
        if v <= NEG / 2:
            if r in forced:
                return None          # forced row unplaceable
            continue
        if c < n_c:
            obj += v
            out[r] = c
    return obj, out


# how far below the hint incumbent's objective the pruning bound is
# seeded: strictly positive (and > the solver's 1e-12 tie tolerance) so
# the DFS still visits — in the same order — every node whose relaxation
# reaches the true optimum, making warm-started placements bit-identical
# to cold solves; large enough to actually prune dominated subtrees.
_HINT_EPS = 1e-9


def _hint_incumbent(problem: FrontierProblem
                    ) -> Optional[tuple[float, dict[int, int]]]:
    """Feasible warm-start assignment from ``problem.hint``.

    Walks rows in order, accepting each hinted (row, device) pair that
    keeps the assignment feasible: device eligible and unused, slot
    monotonicity (slot k only on top of an accepted slot k−1, which the
    planner's row ordering guarantees precedes it), and mutual
    exclusion (once one key of an ``exclusive`` group is accepted, the
    group's other keys are skipped).  Returns
    ``(objective, {row_index: col_index})`` or None when nothing from
    the hint is applicable.  Feasibility ⇒ the objective lower-bounds
    the optimum, so seeding with it can never cut the optimum off.
    """
    hint = problem.hint or {}
    if not hint:
        return None
    col_of = {d: j for j, d in enumerate(problem.devices)}
    group_of: dict = {}
    for gi, grp in enumerate(problem.exclusive or ()):
        for key in grp:
            group_of[key] = gi
    chosen: dict[int, tuple] = {}        # group index -> accepted key
    used: set[int] = set()
    accepted: set[tuple] = set()         # (stage_key, slot) taken
    out: dict[int, int] = {}
    obj = 0.0
    for r, (key, slot) in enumerate(problem.rows):
        d = hint.get((key, slot))
        if d is None:
            continue
        c = col_of.get(d)
        if c is None or c in used:
            continue
        w = float(problem.weights[r, c])
        if w <= NEG / 2:
            continue
        if slot > 0 and (key, slot - 1) not in accepted:
            continue
        gi = group_of.get(key)
        if gi is not None and chosen.get(gi, key) != key:
            continue
        if gi is not None:
            chosen[gi] = key
        used.add(c)
        accepted.add((key, slot))
        out[r] = c
        obj += w
    return (obj, out) if out else None


def solve_frontier_exact(problem: FrontierProblem,
                         time_limit: float = 5.0) -> FrontierSolution:
    """Exactly solve one frontier placement problem.

    Branch-and-bound over the Hungarian relaxation (see module
    docstring); always returns the true optimum with status
    ``OPTIMAL`` unless ``time_limit`` is exceeded (then ``FEASIBLE``
    with the incumbent).  When ``problem.hint`` carries a previous
    wave's assignment, a feasible incumbent is installed ε below its
    objective before the search, so dominated subtrees prune from node
    one while the returned assignment stays bit-identical to an
    unhinted (cold) solve.
    """
    t0 = time.perf_counter()
    rows = problem.rows
    stage_slots: dict = {}
    for i, (s, k) in enumerate(rows):
        stage_slots.setdefault(s, {})[k] = i
    # mutual-exclusion groups resolved to per-key row-index sets (keys
    # with no rows in this problem drop out; singleton groups constrain
    # nothing)
    ex_groups: list[list[frozenset]] = []
    for grp in problem.exclusive or ():
        rowsets = [frozenset(stage_slots[key].values())
                   for key in grp if key in stage_slots]
        if len(rowsets) > 1:
            ex_groups.append(rowsets)

    best_obj = -np.inf
    best_assign: dict[int, int] = {}
    warm = _hint_incumbent(problem)
    if warm is not None:
        # ε-below seeding: any subtree whose relaxation cannot beat the
        # hint's (feasible, hence ≤ optimal) objective is pruned; nodes
        # at or above the optimum survive, so the first optimum found in
        # DFS order — the cold solve's answer — is still the one kept.
        best_obj = warm[0] - _HINT_EPS
        best_assign = dict(warm[1])
    nodes = 0
    # stack of (forced_rows, banned_rows)
    stack: list[tuple[frozenset, frozenset]] = [(frozenset(), frozenset())]
    seen: set[tuple[frozenset, frozenset]] = set()
    deadline = t0 + time_limit
    status = "OPTIMAL"

    while stack:
        if time.perf_counter() > deadline:
            status = "FEASIBLE"
            break
        forced, banned = stack.pop()
        if (forced, banned) in seen:
            continue
        seen.add((forced, banned))
        nodes += 1
        sol = _hungarian(problem.weights, set(forced), set(banned))
        if sol is None:
            continue
        obj, assign = sol
        if obj <= best_obj + 1e-12:
            continue
        # check slot monotonicity: slot k assigned requires slot k-1
        violation = None
        for s, slots in stage_slots.items():
            for k in sorted(slots):
                if k == 0:
                    continue
                hi, lo = slots[k], slots[k - 1]
                if hi in assign and lo not in assign:
                    violation = (lo, hi)
                    break
            if violation:
                break
        if violation is None:
            # check mutual exclusion: at most one key per group assigned
            ex_violation = None
            for rowsets in ex_groups:
                live = [rs for rs in rowsets
                        if any(r in assign for r in rs)]
                if len(live) >= 2:
                    ex_violation = (live[0], live[1])
                    break
            if ex_violation is None:
                best_obj = obj
                best_assign = assign
                continue
            # two keys A, B of one group both hold rows: any feasible
            # solution uses at most one of them, so it survives the
            # branch banning the other — complete dichotomy
            rows_a, rows_b = ex_violation
            stack.append((forced, banned | rows_a))
            stack.append((forced, banned | rows_b))
            continue
        lo, hi = violation
        # branch A: ban the higher slot; branch B: force the lower slot
        stack.append((forced, banned | {hi}))
        stack.append((forced | {lo}, banned))

    if not np.isfinite(best_obj):
        best_obj = 0.0
        best_assign = {}
    assignment = {rows[r]: problem.devices[c]
                  for r, c in best_assign.items()}
    return FrontierSolution(status=status, objective=float(best_obj),
                            assignment=assignment,
                            wall_time=time.perf_counter() - t0,
                            nodes=nodes)
