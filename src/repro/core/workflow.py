"""Workflow DAG model: stages, attributes, and per-instance query batches.

Mirrors the paper's formulation (§2): each stage v carries
``(m(v), A(v), R(v), c_v, φ(v), Pa(v), Ch(v))`` — model type, eligible
devices, bounded shard degree, base runtime profile, stage-local
features (prompt metadata, shared-prefix group, cache flags), and DAG
neighbors.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

# Globally-unique stage identity: (workflow id, stage id).  The
# single-workflow planner keys rows by bare ``sid``; the shared-frontier
# serving layer tags every row with its owning workflow so many in-flight
# DAGs can contend inside one frontier problem.
StageKey = tuple[str, str]


@dataclasses.dataclass
class Stage:
    sid: str
    model: str                               # m(v): model alias
    eligible: tuple[int, ...] = ()           # A(v): device ids; () = all
    max_shards: int = 1                      # R(v)
    # base runtime profile c_v(d): per-query seconds on device d;
    # keyed by device id, with -1 as the default entry.
    base_cost: dict[int, float] = dataclasses.field(default_factory=dict)
    # φ(v) — stage-local features
    prefix_group: Optional[str] = None       # shared-prefix group id
    shared_fraction: float = 1.0             # queries in shared groups
    keep_cache: bool = True
    cache_reuse: bool = True
    output_tokens: float = 256.0             # output-size proxy (tokens)
    prefill_fraction: float = 0.6            # share of cost that is prefill
    comm_weight: float = 1.0                 # communication weight
    role: str = "worker"
    level: int = 0
    parents: tuple[str, ...] = ()
    children: tuple[str, ...] = ()
    # cost/quality routing (core/routing.py): alternate model aliases
    # the planner may serve this stage with, as (alias, quality) pairs
    # where quality in (0, 1] is relative to the default ``model``
    # (implicitly quality 1.0).  Empty = routing never touches the
    # stage, so legacy workflows are untouched by construction.
    candidates: tuple[tuple[str, float], ...] = ()

    def cost_on(self, device: int) -> float:
        if device in self.base_cost:
            return self.base_cost[device]
        return self.base_cost.get(-1, 1.0)

    def to_dict(self) -> dict:
        """Plain-JSON document (``base_cost`` device keys stringified;
        derived ``children``/``level`` omitted — ``Workflow._wire``
        recomputes them).  Inverse of :meth:`from_dict`."""
        return {
            "sid": self.sid, "model": self.model,
            "eligible": list(self.eligible),
            "max_shards": self.max_shards,
            "base_cost": {str(d): c for d, c in self.base_cost.items()},
            "prefix_group": self.prefix_group,
            "shared_fraction": self.shared_fraction,
            "keep_cache": self.keep_cache,
            "cache_reuse": self.cache_reuse,
            "output_tokens": self.output_tokens,
            "prefill_fraction": self.prefill_fraction,
            "comm_weight": self.comm_weight,
            "role": self.role,
            "parents": list(self.parents),
            "candidates": [[m, q] for m, q in self.candidates],
        }

    @classmethod
    def from_dict(cls, doc: dict) -> "Stage":
        """Rebuild a stage from :meth:`to_dict` output."""
        doc = dict(doc)
        doc["eligible"] = tuple(doc.get("eligible") or ())
        doc["parents"] = tuple(doc.get("parents") or ())
        doc["base_cost"] = {int(d): c
                            for d, c in doc.get("base_cost", {}).items()}
        # pre-routing documents have no "candidates" key; absent or
        # null loads as "no alternates" (routing disabled for the stage)
        doc["candidates"] = tuple((str(m), float(q))
                                  for m, q in doc.get("candidates") or ())
        return cls(**doc)


@dataclasses.dataclass
class Workflow:
    wid: str
    stages: dict[str, Stage]
    num_queries: int = 16                    # batch of independent queries
    family: str = ""
    meta: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        self._generation = 0
        self._wire()

    @property
    def generation(self) -> int:
        """Topology generation counter.

        Bumped by :meth:`invalidate_topology` whenever the stage graph is
        mutated after construction.  Consumers that memoize per-workflow
        derived data (descendant tables here, base-cost rows and tail
        term plans in :mod:`repro.core.scoring`) key or guard their
        caches on this counter so a mutated workflow is never scored
        against stale topology.
        """
        return self._generation

    def invalidate_topology(self) -> None:
        """Declare an in-place mutation of ``stages`` (added stages,
        rewired parents, edited cost profiles).  Re-wires children /
        levels / topo order, drops the descendant cache, and bumps
        :attr:`generation` so downstream memoized scorers re-derive."""
        self._generation += 1
        self._wire()

    def stage_key(self, sid: str) -> StageKey:
        """Workflow-tagged stage id for cross-DAG frontiers."""
        return (self.wid, sid)

    def _wire(self) -> None:
        """Recompute children from parents and topological levels."""
        self._desc_cache: dict[int, dict[str, tuple[tuple[str, int], ...]]]
        self._desc_cache = {}
        kids: dict[str, list[str]] = {s: [] for s in self.stages}
        for s in self.stages.values():
            for p in s.parents:
                if p not in self.stages:
                    raise ValueError(f"{self.wid}: unknown parent {p}")
                kids[p].append(s.sid)
        for sid, ch in kids.items():
            self.stages[sid].children = tuple(sorted(ch))
        # levels via Kahn topological pass (also validates acyclicity)
        indeg = {s.sid: len(s.parents) for s in self.stages.values()}
        frontier = [sid for sid, d in indeg.items() if d == 0]
        seen = 0
        level = {sid: 0 for sid in frontier}
        order: list[str] = []
        while frontier:
            nxt: list[str] = []
            for sid in frontier:
                order.append(sid)
                seen += 1
                for ch in self.stages[sid].children:
                    indeg[ch] -= 1
                    level[ch] = max(level.get(ch, 0),
                                    level.get(sid, 0) + 1)
                    if indeg[ch] == 0:
                        nxt.append(ch)
            frontier = nxt
        if seen != len(self.stages):
            raise ValueError(f"{self.wid}: cycle detected")
        for sid, lv in level.items():
            self.stages[sid].level = lv
        self._topo = order

    @property
    def topo_order(self) -> list[str]:
        return list(self._topo)

    def descendants_within(self, sid: str,
                           depth: int) -> tuple[tuple[str, int], ...]:
        """Horizon-bounded descendant list ``((uid, dist), ...)``.

        Cached per depth so the planner's per-(stage, device) scoring
        never re-walks the DAG (the seed implementation re-ran this BFS
        for every candidate pair).  The traversal order is the exact
        LIFO order of the original ``Scorer._descendants_within`` so
        vectorized score accumulation stays bit-identical to the scalar
        path.
        """
        table = self._desc_cache.get(depth)
        if table is None:
            table = {}
            for start in self.stages:
                out: list[tuple[str, int]] = []
                frontier = [(start, 0)]
                seen = {start}
                while frontier:
                    cur, d = frontier.pop()
                    if d >= depth:
                        continue
                    for ch in self.stages[cur].children:
                        if ch in seen:
                            continue
                        seen.add(ch)
                        out.append((ch, d + 1))
                        frontier.append((ch, d + 1))
                table[start] = tuple(out)
            self._desc_cache[depth] = table
        return table[sid]

    def levels(self) -> dict[int, list[str]]:
        out: dict[int, list[str]] = {}
        for s in self.stages.values():
            out.setdefault(s.level, []).append(s.sid)
        return {k: sorted(v) for k, v in sorted(out.items())}

    def max_level(self) -> int:
        return max((s.level for s in self.stages.values()), default=0)

    def sources(self) -> list[str]:
        return [s.sid for s in self.stages.values() if not s.parents]

    def sinks(self) -> list[str]:
        return [s.sid for s in self.stages.values() if not s.children]

    def validate(self) -> None:
        for s in self.stages.values():
            if s.max_shards < 1:
                raise ValueError(f"{s.sid}: R(v) must be >= 1")
            if not s.base_cost:
                raise ValueError(f"{s.sid}: missing runtime profile")

    def to_dict(self) -> dict:
        """Plain-JSON document of the DAG (stages in insertion order —
        ``stages`` dict order determines topo tie-breaks, so it is
        part of the serialized contract).  Inverse of
        :meth:`from_dict`; ``meta`` must be JSON-serializable."""
        return {
            "wid": self.wid,
            "stages": [s.to_dict() for s in self.stages.values()],
            "num_queries": self.num_queries,
            "family": self.family,
            "meta": dict(self.meta),
        }

    @classmethod
    def from_dict(cls, doc: dict) -> "Workflow":
        """Rebuild a workflow from :meth:`to_dict` output (re-wires
        children/levels/topo order from the stage parent lists)."""
        stages = {}
        for sdoc in doc["stages"]:
            st = Stage.from_dict(sdoc)
            stages[st.sid] = st
        return cls(wid=doc["wid"], stages=stages,
                   num_queries=doc.get("num_queries", 16),
                   family=doc.get("family", ""),
                   meta=dict(doc.get("meta") or {}))


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """Runtime proxy profile for a model alias (Appendix C.1)."""
    name: str
    size_gb: float                 # memory footprint
    prefill_coef: float            # sec per 1k prompt tokens per query
    decode_coef: float             # sec per 1k output tokens per query
    switch_cost: float             # model load/activation seconds
    family: str = "generic"


DEFAULT_PROFILES: dict[str, ModelProfile] = {
    # Qwen-style / DeepSeek-style / Llama-style 7–8B profiles (paper C.1)
    "qwen-7b": ModelProfile("qwen-7b", 15.0, 0.011, 0.105, 6.5,
                            family="qwen"),
    "deepseek-7b": ModelProfile("deepseek-7b", 14.5, 0.012, 0.115, 7.0,
                                family="deepseek"),
    "llama-8b": ModelProfile("llama-8b", 16.0, 0.013, 0.120, 7.5,
                             family="llama"),
    "qwen-14b": ModelProfile("qwen-14b", 28.0, 0.021, 0.195, 11.0,
                             family="qwen"),
    "llama-3b": ModelProfile("llama-3b", 6.5, 0.006, 0.055, 3.2,
                             family="llama"),
}
