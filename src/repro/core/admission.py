"""SLO-aware admission control for the serving runtime (control plane).

The paper's central claim is that scheduling decisions should be
conditioned on predicted *future* state, not the immediate queue alone
(§1, §3.2).  PR 2's :class:`~repro.core.executor.ServingExecutor`
still admitted every Poisson arrival unconditionally — exactly the
"optimize immediate queue state only" failure mode under overload.
This module adds the missing serving-time decision layer:

* **Admission** — on each arrival, a cheap *future-state probe* (a
  delta-rescored one-wave ``plan_shared`` lookahead over the merged
  frontier, run on a throwaway planning overlay) predicts the
  workflow's completion latency under current contention.  If the
  prediction violates the per-workflow SLO (a configurable latency
  multiplier over the workflow's critical-path lower bound), the
  arrival is deferred into a bounded backlog — or rejected when the
  backlog is full or the deadline is already unreachable.
* **Deferral / re-admission** — on completion events the backlog is
  re-probed oldest-feasible-first; entries whose deadline became
  unreachable are shed (rejected) so they never consume capacity they
  cannot convert into SLO-met goodput.
* **Preemption trigger** — an admitted workflow whose predicted
  latency sits within ``preempt_slack`` of its budget is flagged
  ``preempt=True``; the executor then revokes committed-but-unissued
  placements so the urgent DAG competes in a fresh merged solve
  immediately instead of waiting for the next completion event.

The controller never mutates the real :class:`ExecutionState`: probes
run on copy-on-write overlays, so the dirty-set protocol that keeps
``Scorer.rescore_matrix`` bit-identical to full rebuilds is untouched
(see :mod:`repro.core.state`).

Probe-margin correction (``SLOConfig.online_margin``): the raw probe
under-estimates latency under load, so its prediction is inflated by a
safety margin before the SLO comparison.  The margin is either the
hand-set ``probe_margin`` constant or — when ``online_margin`` is on —
a live per-model-family :class:`~repro.core.calibration.ProbeCorrector`
estimate: the serving executor reports every workflow completion back
via :meth:`AdmissionController.record_completion`, the corrector folds
the observed/predicted latency ratio into its EWMA, and every later
admission probe and deferral re-probe uses the corrected margin.  All
predicted-vs-observed pairs are kept on ``probe_log`` for the
``sched_bench --calibrate`` gate
(:func:`repro.workflowbench.metrics.probe_error_summary`).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Optional, Sequence

from repro.core.calibration import ProbeCorrector
from repro.core.state import ExecutionState
from repro.core.workflow import Workflow


@dataclasses.dataclass(frozen=True)
class ClassSpec:
    """One admission class's scheduling contract.

    ``weight`` orders classes for backlog re-probing, congestion-floor
    accounting, displacement protection, and running-shard preemption
    (strictly-lower-weight workflows are preemptible by a tight
    higher-weight admission).  ``latency_scale`` overrides the global
    :attr:`SLOConfig.latency_scale` for the class's deadlines (``None``
    inherits it); ``backlog_limit`` likewise bounds the class's OWN
    deferral-queue share instead of the shared global limit.
    """
    weight: float = 1.0
    latency_scale: Optional[float] = None
    backlog_limit: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Per-workflow latency SLO and control-plane knobs.

    The deadline of a workflow arriving at ``t`` is
    ``t + latency_scale * cp_lower_bound(wf)`` — a multiple of the
    fastest possible execution on an empty cluster, so heavy DAGs get
    proportionally more budget than small ones.

    Multi-class serving (``classes`` non-empty) layers weighted SLOs on
    top: each admission class carries a :class:`ClassSpec` (weight,
    deadline scale, backlog share), the backlog is re-probed
    class-major (effective weight, then age — where effective weight is
    ``weight + aging_rate * wait``, the anti-starvation promotion), the
    congestion floors of a high-class candidate exclude lower-class
    committed-but-unissued work, and ``preempt_running`` arms
    kill/replay preemption of issued-and-running lower-class shards
    (see :meth:`repro.core.scheduler.Scheduler._preempt_running`).
    With ``classes`` EMPTY (the default) every class-aware branch is
    skipped and the controller is bit-identical to the single-class
    one — the compatibility contract ``tests/test_multiclass.py``
    asserts.
    """
    latency_scale: float = 2.5      # deadline = arrival + scale * cp_lb
    backlog_limit: int = 8          # bounded deferral queue length
    # safety factor on predicted latency: the probe's floors ignore
    # transfer costs and residual layer serialization, so raw
    # predictions under-estimate under load.  With online_margin this
    # constant is only the corrector's PRIOR: the effective margin is
    # learned per model family from observed completions.
    probe_margin: float = 1.5
    # preempt when predicted * slack > budget; must be > probe_margin
    # or the trigger window (budget/slack, budget/margin] is empty
    preempt_slack: float = 2.5
    admission: bool = True          # False: track SLOs, admit everything
    preemption: bool = True         # False: never revoke commitments
    # online predicted-vs-observed probe correction (EWMA residual
    # tracker per model family, see repro.core.calibration); the
    # corrector starts at probe_margin so an un-warmed controller is
    # identical to the static one
    online_margin: bool = False
    margin_alpha: float = 0.4       # EWMA step of the ratio tracker
    # ring-buffer cap on the predicted-vs-observed probe log (None =
    # unbounded, the benchmark/test default; long-running serving
    # deployments set a cap so the log cannot grow without bound)
    probe_log_limit: Optional[int] = None
    # -- multi-class control plane (empty = single-class, bit-identical
    # to the pre-class controller) --------------------------------------
    classes: Mapping[str, ClassSpec] = \
        dataclasses.field(default_factory=dict)
    # anti-starvation aging: a backlog entry's effective weight grows
    # by aging_rate per second of wait, so a bottom-class entry
    # overtakes a fresh top-class one after
    # (w_top - w_bottom) / aging_rate seconds — the starvation bound
    # docs/PRIORITY.md derives (0.0 = strict class order forever)
    aging_rate: float = 0.0
    # kill/replay preemption of ISSUED-and-running strictly-lower-class
    # shards when a higher-class arrival would otherwise be deferred
    # (or admits SLO-tight); the scheduler revokes the run token,
    # credits partial state back, and re-enqueues the stage
    preempt_running: bool = False
    preempt_running_max: int = 2    # max victims per trigger
    # a stage killed this many times becomes immune (anti-livelock:
    # guarantees bottom-class progress under sustained platinum load)
    preempt_kill_cap: int = 2
    # seconds the freed devices are held for the trigger's replan
    # before the victim stage re-enters the merged solve
    preempt_holdoff: float = 0.05

    def __post_init__(self):
        if self.classes:
            coerced = {k: (v if isinstance(v, ClassSpec)
                           else ClassSpec(**v))
                       for k, v in self.classes.items()}
            object.__setattr__(self, "classes", coerced)

    def class_spec(self, klass: str) -> Optional[ClassSpec]:
        """The configured :class:`ClassSpec` for ``klass`` (``None``
        when unconfigured — callers fall back to the global knobs)."""
        return self.classes.get(klass) if self.classes else None

    def class_weight(self, klass: str) -> float:
        """Scheduling weight of ``klass`` (1.0 when unconfigured)."""
        spec = self.class_spec(klass)
        return spec.weight if spec is not None else 1.0

    def deadline(self, arrival: float, cp_lb: float,
                 klass: str = "default") -> float:
        """Absolute completion deadline for a workflow with critical-path
        lower bound ``cp_lb`` that arrived at ``arrival``.  With a
        class-configured ``latency_scale`` override, the class's scale
        replaces the global one (single-class configs ignore
        ``klass``)."""
        scale = self.latency_scale
        spec = self.class_spec(klass)
        if spec is not None and spec.latency_scale is not None:
            scale = spec.latency_scale
        return arrival + scale * cp_lb


@dataclasses.dataclass
class AdmissionDecision:
    """Outcome of one admission probe.

    ``action`` is ``"admit"``, ``"defer"``, or ``"reject"``;
    ``predicted_latency`` is the probe's completion-latency estimate
    (seconds from the decision instant, BEFORE the safety margin);
    ``margin`` is the multiplicative safety margin the SLO comparison
    used (hand-set or corrector-supplied); ``deadline`` is absolute sim
    time; ``preempt`` asks the executor to revoke unissued commitments
    so the admitted workflow is replanned against immediately.
    """
    action: str
    predicted_latency: float
    deadline: float
    cp_lb: float
    preempt: bool = False
    margin: float = 1.0


@dataclasses.dataclass(frozen=True)
class ProbeRecord:
    """One admitted workflow's probe prediction vs serving reality.

    ``predicted`` is the raw probe estimate at the (final) admit
    decision, ``margin`` the multiplicative safety factor applied to
    it, and ``observed`` the measured completion latency from that
    decision instant — the evidence stream behind the online probe
    correction and the ``--calibrate`` benchmark gate.
    """
    wid: str
    family: str
    predicted: float
    margin: float
    observed: float
    decided_at: float
    finished_at: float

    @property
    def abs_error(self) -> float:
        """``|margin · predicted − observed|`` seconds — the gap the
        online corrector shrinks."""
        return abs(self.margin * self.predicted - self.observed)


def stage_floor_costs(wf: Workflow, cluster,
                      live: Optional[Sequence[int]] = None
                      ) -> dict[str, float]:
    """Per-stage minimum base cost over eligible devices (seconds).

    State-free lower bound: ignores switches, transfers, queueing and
    every benefit term — the fastest any single device could run the
    stage's full query batch.  ``live`` (the reduced device set under
    partial outage) restricts the minimum to live eligible devices;
    stages whose every eligible device is down fall back to the full
    eligible set so the bound stays finite.
    """
    out: dict[str, float] = {}
    q = wf.num_queries
    for sid, st in wf.stages.items():
        devs = st.eligible if st.eligible else cluster.ids()
        if live is not None:
            up = [d for d in devs if d in live]
            devs = up or devs
        out[sid] = min(st.cost_on(d) * q / cluster.devices[d].speed
                       for d in devs)
    return out


def stage_effective_floors(wf: Workflow, cluster, profiles: dict,
                           floor: Optional[dict] = None
                           ) -> dict[str, float]:
    """Switch-aware per-stage work floor (congestion accounting).

    Base floor cost plus HALF the model's load (switch) cost whenever
    the stage's model differs from a parent's — cross-model edges are
    what churns residency under contention, and charging the full load
    per edge overcounts (chains re-use residencies across devices)
    while ignoring it lets model-alternating DAGs look 5× lighter than
    they run.  Used by the admission probes' congestion floors; the
    SLO deadline normalizer uses the path-based
    :func:`critical_path_lower_bound` instead.  Pass a precomputed
    ``floor`` (:func:`stage_floor_costs`) to avoid recomputation.
    """
    if floor is None:
        floor = stage_floor_costs(wf, cluster)
    out: dict[str, float] = {}
    for sid, st in wf.stages.items():
        c = floor[sid]
        if st.parents and any(wf.stages[p].model != st.model
                              for p in st.parents):
            prof = profiles.get(st.model)
            if prof is not None:
                c += 0.5 * prof.switch_cost
        out[sid] = c
    return out


def stage_tail_bounds(wf: Workflow, cluster,
                      floor: Optional[dict] = None) -> dict[str, float]:
    """Critical-path-to-sink lower bound per stage.

    ``tails[sid]`` = the stage's own floor cost plus the longest floor
    path through its descendants; the workflow cannot finish earlier
    than ``start(sid) + tails[sid]`` once ``sid`` is on the critical
    path.  State-free, so cacheable per workflow topology.  Pass a
    precomputed ``floor`` (:func:`stage_floor_costs`) to avoid
    recomputation.
    """
    if floor is None:
        floor = stage_floor_costs(wf, cluster)
    tails: dict[str, float] = {}
    for sid in reversed(wf.topo_order):
        ch = wf.stages[sid].children
        tails[sid] = floor[sid] + max((tails[c] for c in ch), default=0.0)
    return tails


def critical_path_lower_bound(wf: Workflow, cluster,
                              profiles: Optional[dict] = None,
                              tails: Optional[dict] = None) -> float:
    """Fastest plausible makespan of ``wf`` on an idle ``cluster``.

    Longest source-to-sink path of per-stage floor costs, plus — when
    model ``profiles`` are given — one weight-load (switch cost) per
    distinct model along that path: even an idle cluster must activate
    each model at least once before the chain can run on it.  Without
    the switch term the bound is wildly optimistic for
    model-alternating workflows (5× observed on the conflict suite),
    which would make every deadline normalized by it unreachable.
    This is the normalizer of every SLO deadline (:class:`SLOConfig`).
    Pass precomputed ``tails`` (:func:`stage_tail_bounds`) to avoid
    recomputation.
    """
    if tails is None:
        tails = stage_tail_bounds(wf, cluster)
    if not wf.stages:
        return 0.0
    cp = max(tails[s] for s in wf.sources())
    if not profiles:
        return cp
    # walk the arg-max path and charge each distinct model's load once
    sid = max(wf.sources(), key=lambda s: tails[s])
    models = {wf.stages[sid].model}
    while wf.stages[sid].children:
        sid = max(wf.stages[sid].children, key=lambda c: tails[c])
        models.add(wf.stages[sid].model)
    for m in models:
        prof = profiles.get(m)
        if prof is not None:
            cp += prof.switch_cost
    return cp


class AdmissionController:
    """Future-state-aware admission/deferral/preemption decisions.

    One controller instance serves one :meth:`ServingExecutor.run`
    call.  It owns the bounded backlog of deferred workflows and the
    list of rejected workflow ids; the executor owns the frontier and
    applies the decisions (admit into the shared frontier, clear the
    committed pool on ``preempt``).

    Probing: policies that expose a ``planner`` with ``plan_shared``
    (FATE) get the planned probe — a one-wave merged-frontier solve on
    a throwaway overlay, delta-rescored off the planner's cached wave
    snapshots, predicting both the candidate's completion latency and
    the busy-time displacement it inflicts on in-flight workflows.
    Other policies fall back to an analytic backlog/critical-path
    estimate, so admission control composes with every baseline.
    """

    def __init__(self, slo: SLOConfig,
                 corrector: Optional[ProbeCorrector] = None):
        self.slo = slo
        # online probe-margin correction: explicit corrector wins;
        # otherwise slo.online_margin builds one primed with the
        # hand-set margin (None = static probe_margin forever)
        if corrector is None and slo.online_margin:
            corrector = ProbeCorrector(prior=slo.probe_margin,
                                       alpha=slo.margin_alpha)
        self.corrector = corrector
        # (original arrival time, workflow), oldest first
        self.backlog: list[tuple[float, Workflow]] = []
        self.rejected: list[str] = []
        self.deadlines: dict[str, float] = {}
        # admission class per live workflow id (registered by the
        # scheduler before any decision touches the wid; absent =
        # "default").  Only consulted when slo.classes is non-empty.
        self.klass: dict[str, str] = {}
        # live view of the owning scheduler's ISSUED stage-key set
        # (bound via bind_issued) — the class-aware congestion floor
        # charges lower-class workflows only for their issued (sunk)
        # stages, and committed-but-unissued work is preemptible
        self._issued_view: Optional[Callable[[], set]] = None
        self.n_deferrals = 0
        self.n_probes = 0
        # admitted-but-unfinished probe predictions awaiting their
        # observed completion latency, and the completed-pair log
        self.pending: dict[str, tuple[float, float, str, float]] = {}
        self.probe_log: list[ProbeRecord] = []
        self._tails: dict[str, dict[str, float]] = {}
        self._floor: dict[str, dict[str, float]] = {}
        self._efloor: dict[str, dict[str, float]] = {}
        self._cp: dict[str, float] = {}
        self._family: dict[str, str] = {}
        # live-set generation the bound caches were computed under;
        # a fault-epoch bump (device down/up) invalidates them all
        self._fault_epoch = 0
        # O(in-flight)-scan memos, keyed on (frontier.version,
        # fault_epoch): the total outstanding floor work and the
        # in-flight (remaining-tail, deadline) slack pairs.  Both are
        # pure functions of the frontier contents + live set, so a
        # version/epoch match returns the cached value and probes stop
        # re-walking every in-flight DAG.  Derived caches — not part of
        # state_dict (a restored controller rebuilds them lazily).
        self._floor_work_memo: Optional[tuple] = None
        self._slack_memo: Optional[tuple] = None

    # -- cached critical-path bounds -------------------------------------
    def _sync_fault_epoch(self, state: ExecutionState) -> None:
        """Invalidate floor/tail/cp caches when the live set changed."""
        ep = getattr(state, "fault_epoch", 0)
        if ep != self._fault_epoch:
            self._fault_epoch = ep
            self._tails.clear()
            self._floor.clear()
            self._efloor.clear()
            self._cp.clear()

    def tail_bounds(self, wf: Workflow,
                    state: ExecutionState) -> dict[str, float]:
        """Memoized :func:`stage_tail_bounds` for ``wf`` (also fills
        the floor-cost and switch-aware critical-path caches).

        Bounds are conditioned on the LIVE device set: under partial
        outage the per-stage floors rise to the fastest surviving
        device, so admission tightens instead of over-committing
        against capacity that no longer exists.
        """
        self._sync_fault_epoch(state)
        t = self._tails.get(wf.wid)
        if t is None:
            live = set(state.live_ids()) if state.down else None
            floor = stage_floor_costs(wf, state.cluster, live=live)
            t = stage_tail_bounds(wf, state.cluster, floor=floor)
            self._tails[wf.wid] = t
            self._floor[wf.wid] = floor
            self._efloor[wf.wid] = stage_effective_floors(
                wf, state.cluster, state.profiles, floor=floor)
            self._cp[wf.wid] = critical_path_lower_bound(
                wf, state.cluster, state.profiles, tails=t)
        return t

    def cp_lower_bound(self, wf: Workflow,
                       state: ExecutionState) -> float:
        """Memoized :func:`critical_path_lower_bound` for ``wf``
        (switch-aware: includes one load per critical-path model)."""
        self.tail_bounds(wf, state)
        return self._cp[wf.wid]

    def forget(self, wid: str) -> None:
        """Release cached bounds for a finished workflow."""
        self._tails.pop(wid, None)
        self._floor.pop(wid, None)
        self._efloor.pop(wid, None)
        self._cp.pop(wid, None)
        self._family.pop(wid, None)
        self.deadlines.pop(wid, None)
        self.pending.pop(wid, None)
        self.klass.pop(wid, None)

    # -- admission classes -----------------------------------------------
    def bind_issued(self, view: Callable[[], set]) -> None:
        """Bind a zero-arg callable returning the owning scheduler's
        live issued stage-key set (class-aware floors read it; the
        single-class path never calls it)."""
        self._issued_view = view

    def note_class(self, wid: str, klass: str) -> None:
        """Register a workflow's admission class before its first
        decision (the scheduler calls this on every arrival)."""
        self.klass[wid] = klass

    def _klass_of(self, wid: str) -> str:
        return self.klass.get(wid, "default")

    def _eff_weight(self, klass: str, wait: float) -> float:
        """Aged class weight of a backlog entry: the configured weight
        plus ``aging_rate`` per second already waited — the
        anti-starvation promotion that bounds bottom-class wait at
        ``(w_max - w) / aging_rate`` seconds behind the heaviest
        class."""
        return (self.slo.class_weight(klass)
                + self.slo.aging_rate * max(wait, 0.0))

    # -- durability ------------------------------------------------------
    def state_dict(self) -> dict:
        """Plain-JSON capture of the controller's decision state: the
        backlog (by workflow id — the owning scheduler snapshot
        carries the workflow objects), rejections, SLO deadlines,
        counters, pending probe predictions, the probe log, and the
        online :class:`ProbeCorrector` EWMAs.  The derived bound
        caches (tails/floors/critical paths) are pure functions of
        workflow + live set and are NOT captured — a restored
        controller rebuilds them lazily, bit-identically."""
        return {
            "backlog": [[arr, wf.wid] for arr, wf in self.backlog],
            "rejected": list(self.rejected),
            "deadlines": dict(self.deadlines),
            "klass": dict(self.klass),
            "n_deferrals": self.n_deferrals,
            "n_probes": self.n_probes,
            "pending": {wid: list(v)
                        for wid, v in self.pending.items()},
            "probe_log": [dataclasses.asdict(r)
                          for r in self.probe_log],
            "corrector": (self.corrector.to_dict()
                          if self.corrector is not None else None),
        }

    def load_state(self, doc, workflows) -> None:
        """Restore the state captured by :meth:`state_dict`
        (``workflows`` maps backlog workflow ids back to their
        rehydrated objects)."""
        self.backlog = [(arr, workflows[wid])
                        for arr, wid in doc["backlog"]]
        self.rejected = list(doc["rejected"])
        self.deadlines = dict(doc["deadlines"])
        self.klass = dict(doc.get("klass") or {})
        self.n_deferrals = int(doc["n_deferrals"])
        self.n_probes = int(doc["n_probes"])
        self.pending = {wid: tuple(v)
                        for wid, v in doc["pending"].items()}
        self.probe_log = [ProbeRecord(**r)
                          for r in doc["probe_log"]]
        cor = doc.get("corrector")
        if cor is not None:
            self.corrector = ProbeCorrector.from_dict(cor)

    # -- probe-margin correction -----------------------------------------
    def probe_family(self, wf: Workflow,
                     state: ExecutionState) -> str:
        """Corrector key of a workflow: its model-family composition.

        The sorted set of model families its stages span (e.g.
        ``"qwen"`` for a single-family DAG, ``"llama+qwen"`` for an
        alternating one) — distinct compositions have systematically
        different probe residuals (a multi-family DAG churns residency,
        a single-family one queues behind warm devices), so folding
        them into one EWMA would let one workload's ratio poison the
        other's margin.  Memoized per workflow id.
        """
        fam = self._family.get(wf.wid)
        if fam is None:
            fams = set()
            for st in wf.stages.values():
                prof = state.profiles.get(st.model)
                fams.add(prof.family if prof is not None else "generic")
            fam = "+".join(sorted(fams)) or "generic"
            self._family[wf.wid] = fam
        return fam

    def probe_margin(self, wf: Workflow, state: ExecutionState) -> float:
        """Live multiplicative safety margin for one workflow's probe:
        the corrector's per-family EWMA estimate when online correction
        is active, else the hand-set ``SLOConfig.probe_margin``."""
        if self.corrector is None:
            return self.slo.probe_margin
        return self.corrector.margin(self.probe_family(wf, state))

    def _note_admit(self, wf: Workflow, state: ExecutionState,
                    dec: "AdmissionDecision") -> None:
        """Bookkeeping for a (re-)admission: deadline registration plus
        the pending predicted-latency record the completion observer
        will close out."""
        self.deadlines[wf.wid] = dec.deadline
        self.pending[wf.wid] = (state.now, dec.predicted_latency,
                                self.probe_family(wf, state), dec.margin)

    def record_completion(self, wid: str, finish_t: float) -> None:
        """Close the probe loop for one completed workflow: log the
        predicted-vs-observed pair and feed the corrector's EWMA (the
        serving executor calls this on every workflow completion)."""
        p = self.pending.pop(wid, None)
        if p is None:
            return
        decided_at, predicted, family, margin = p
        observed = max(0.0, finish_t - decided_at)
        self.probe_log.append(ProbeRecord(
            wid=wid, family=family, predicted=predicted, margin=margin,
            observed=observed, decided_at=decided_at,
            finished_at=finish_t))
        if self.corrector is not None:
            self.corrector.observe(family, predicted, observed)
        limit = self.slo.probe_log_limit
        if limit is not None and len(self.probe_log) > limit:
            del self.probe_log[: len(self.probe_log) - limit]

    def activation_work(self, wf: Workflow, state: ExecutionState,
                        done=frozenset()) -> float:
        """One-time model-activation work of a workflow's remaining
        stages: half a weight-load per DISTINCT model still to run.

        The per-stage effective floors charge switch cost only on
        cross-model edges, so a single-model DAG looks switch-free to
        the congestion accounting even though every admitted DAG must
        activate its models at least once somewhere — under a deep
        merged queue that blind spot made predicted latency FLAT in
        queue depth while observed latency climbed with it (the probe
        ratio drifted ~1.6→2.6 across one overloaded burst).  Half a
        load mirrors the effective-floor convention: chains reuse
        residencies across devices, so charging full loads overcounts.
        """
        models = {st.model for sid, st in wf.stages.items()
                  if sid not in done}
        out = 0.0
        for m in models:
            prof = state.profiles.get(m)
            if prof is not None:
                out += 0.5 * prof.switch_cost
        return out

    def remaining_floor_work(self, frontier,
                             state: ExecutionState) -> float:
        """Total effective-floor seconds of work still outstanding
        across every in-flight workflow (not-yet-completed stages,
        switch-aware per :func:`stage_effective_floors`, plus each
        DAG's one-time :meth:`activation_work`).

        Divided by the device count this is a work-conserving bound on
        how long the cluster needs to drain its current admissions —
        queued frontier work is invisible to per-device ``free_at``
        (stages occupy devices only once issued), so probes must
        account for it explicitly.

        Memoized on ``(frontier.version, fault_epoch)``: the sum only
        changes when a workflow is admitted/retired or a stage
        completes (all bump the frontier version) or the live set
        changes, so back-to-back probes between events reuse it
        instead of re-walking every in-flight DAG.
        """
        self._sync_fault_epoch(state)
        ver = getattr(frontier, "version", None)
        if ver is not None and self._floor_work_memo is not None:
            m_ver, m_ep, m_total = self._floor_work_memo
            if m_ver == ver and m_ep == self._fault_epoch:
                return m_total
        total = 0.0
        for wid, wf in frontier.workflows.items():
            self.tail_bounds(wf, state)
            floor = self._efloor[wid]
            done = frontier.completed[wid]
            total += sum(c for sid, c in floor.items()
                         if sid not in done)
            total += self.activation_work(wf, state, done)
        if ver is not None:
            self._floor_work_memo = (ver, self._fault_epoch, total)
        return total

    # -- probes ----------------------------------------------------------
    def probe(self, wf: Workflow, state: ExecutionState, frontier,
              policy, claimed: set) -> tuple[float, float]:
        """Predict ``(completion latency, displacement)`` of admitting
        ``wf`` now.

        Latency is seconds from ``state.now`` until the candidate's
        predicted completion; displacement is the mean extra busy time
        per device its first-wave placements would add (the marginal
        delay in-flight workflows absorb).  Dispatches to the planned
        probe when the policy exposes a shared-frontier planner.
        """
        self.n_probes += 1
        planner = getattr(policy, "planner", None)
        if planner is not None and hasattr(planner, "plan_shared"):
            return self._probe_planned(wf, state, frontier, planner,
                                       claimed)
        return self._probe_analytic(wf, state, frontier, claimed)

    def _probe_planned(self, wf: Workflow, state: ExecutionState,
                       frontier, planner,
                       claimed: set) -> tuple[float, float]:
        """One-wave lookahead through the real merged-frontier solver.

        Runs ``plan_shared`` with the candidate's sources appended to
        the current ready frontier, on a copy-on-write overlay
        (``max_waves=1``), so the probe reuses the planner's cached
        delta-rescoring state and costs one incremental wave — not a
        cold solve.  The candidate's predicted completion is the max
        over its sources of (estimated source finish on the overlay +
        that source's critical-path tail); sources the solver deferred
        start no earlier than the first device release.
        """
        from repro.core.costs import CostModel
        from repro.core.planner import _apply_estimate

        cluster = state.cluster
        sim = state.overlay()
        before = {d: sim.device_free(d) for d in cluster.ids()}
        workflows = dict(frontier.workflows)
        workflows[wf.wid] = wf
        ready = list(frontier.ready(claimed))
        ready += [(wf.wid, sid) for sid in wf.sources()]
        placements = planner.plan_shared(workflows, sim, ready,
                                         max_waves=1)
        # plan_shared simulates on its OWN internal overlay; replay the
        # wave's estimated effects onto this probe's overlay (same
        # estimator — including the planner's calibrated cost params —
        # same order) so the reads below see post-placement device
        # state rather than the pre-plan snapshot.
        cm = CostModel(sim, getattr(planner, "cost_params", None))
        for p in placements:
            _apply_estimate(workflows[p.wid], sim, p, cm)
        tails = self.tail_bounds(wf, state)
        floor = self._floor[wf.wid]
        placed: dict[str, float] = {}
        my_busy = 0.0
        # within one solver wave the assignment is injective per device
        # (at-most-one row per column), so a device in a candidate
        # placement carries ONLY that placement's delta — no other
        # workflow's busy time can be misattributed here.
        for p in placements:
            if p.wid != wf.wid:
                continue
            fin = max(sim.device_free(d) for d in p.devices)
            placed[p.sid] = fin
            my_busy += sum(max(0.0, sim.device_free(d) - before[d])
                           for d in p.devices)
        live = sim.live_ids() if sim.down else cluster.ids()
        release = min(sim.device_free(d) for d in live)
        completion = state.now
        for sid in wf.sources():
            if sid in placed:
                est = placed[sid] + (tails[sid] - floor[sid])
            else:           # solver deferred the source: it queues
                est = max(release, state.now) + tails[sid]
            completion = max(completion, est)
        n_dev = max(len(live), 1)
        predicted = max(completion - state.now,
                        self._congestion_floor(wf, state, frontier))
        displacement = my_busy / n_dev
        return predicted, displacement

    def _congestion_floor(self, wf: Workflow, state: ExecutionState,
                          frontier) -> float:
        """Queued-work completion floor for candidate ``wf``.

        Queued frontier work is not on any device's τ yet, so wave
        estimates and ``backlog_seconds`` are blind to it.  Two bounds
        bracket the truth under the merged exact solver, which is
        neither FIFO nor strictly fair: a fair-share bound (the
        candidate's own floor work served on its 1/k share of the
        cluster, k = in-flight DAGs + 1) and a work-conserving drain
        bound (everything outstanding plus the candidate, amortized
        over all devices, as if the candidate finished last).  Their
        mean keeps light workflows admissible under heavy mixed load
        while still charging heavy arrivals for the queue they join.
        Both bounds amortize over the LIVE device count, so admission
        tightens under partial outage.

        Multi-class runs dispatch to the class-aware variant: the
        fair-share bound weights the candidate's cluster share by its
        class weight, and the drain bound charges strictly-lower-class
        workflows only for their ISSUED (sunk) stages — their committed
        and queued future work is preemptible, so a platinum candidate
        does not wait behind it.
        """
        if self.slo.classes:
            return self._congestion_floor_classed(wf, state, frontier)
        n_dev = max(state.n_live, 1)
        self.tail_bounds(wf, state)
        own = (sum(self._efloor[wf.wid].values())
               + self.activation_work(wf, state))
        k = len(frontier.workflows) + 1
        fair = own * k / n_dev
        drain = (self.remaining_floor_work(frontier, state)
                 + own) / n_dev
        return 0.5 * (fair + drain)

    def _congestion_floor_classed(self, wf: Workflow,
                                  state: ExecutionState,
                                  frontier) -> float:
        """Class-aware congestion floor (``slo.classes`` non-empty).

        Weighted fair share: the candidate holds ``w_c / (W + w_c)`` of
        the cluster, ``W`` the total in-flight weight — with uniform
        weights this reduces exactly (same float operations) to the
        single-class ``own * k / n_dev``.  Drain bound: workflows of
        strictly lower weight contribute only the effective floors of
        their ISSUED stages (work already on devices is sunk; committed
        or queued work is preemptible by this candidate), while equal-
        or-higher classes contribute their full remaining work plus
        activation, exactly as the single-class accounting does.  Not
        memoized: the issued set changes without a frontier-version
        bump, so the ``(version, epoch)`` memo key cannot cover it.
        """
        n_dev = max(state.n_live, 1)
        self.tail_bounds(wf, state)
        own = (sum(self._efloor[wf.wid].values())
               + self.activation_work(wf, state))
        w_c = self.slo.class_weight(self._klass_of(wf.wid))
        issued = (self._issued_view()
                  if self._issued_view is not None else None)
        issued_by_wid: dict[str, list[str]] = {}
        if issued:
            for iw, sid in issued:
                issued_by_wid.setdefault(iw, []).append(sid)
        total = 0.0
        w_sum = 0.0
        for wid, wf2 in frontier.workflows.items():
            w2 = self.slo.class_weight(self._klass_of(wid))
            w_sum += w2
            self.tail_bounds(wf2, state)
            floor = self._efloor[wid]
            done = frontier.completed[wid]
            if w2 < w_c - 1e-12:
                # strictly lower class: only sunk (issued) work counts
                total += sum(floor[sid]
                             for sid in sorted(issued_by_wid.get(wid, ()))
                             if sid not in done)
                continue
            total += sum(c for sid, c in floor.items()
                         if sid not in done)
            total += self.activation_work(wf2, state, done)
        fair = own * (w_sum + w_c) / (w_c * n_dev)
        drain = (total + own) / n_dev
        return 0.5 * (fair + drain)

    def _probe_analytic(self, wf: Workflow, state: ExecutionState,
                        frontier, claimed: set) -> tuple[float, float]:
        """Planner-free fallback probe (baseline policies).

        Predicted latency = mean device backlog + critical-path lower
        bound inflated by frontier contention (ready stages per
        device); displacement = the candidate's total floor work
        amortized over the live cluster.
        """
        cluster = state.cluster
        n_dev = max(state.n_live, 1)
        avg_wait = state.backlog_seconds() / n_dev
        n_ready = len(frontier.ready(claimed)) + len(wf.sources())
        contention = max(1.0, n_ready / n_dev)
        cp = self.cp_lower_bound(wf, state)
        work = sum(self._floor[wf.wid].values())
        predicted = max(avg_wait + cp * contention,
                        self._congestion_floor(wf, state, frontier))
        return predicted, work / n_dev

    # -- batched probing -------------------------------------------------
    def probe_batch(self, wfs: Sequence[Workflow],
                    state: ExecutionState, frontier, policy,
                    claimed: set) -> dict[str, tuple[float, float]]:
        """Shared-overlay probe for one same-instant arrival batch.

        Simultaneous arrivals in one event batch see identical device
        state, so probing them one-by-one runs N one-wave lookahead
        solves that differ only in which candidate's sources joined the
        frontier.  This probes them through a SINGLE delta-rescored
        overlay wave with ALL candidates' sources appended, attributing
        per-candidate completion estimates and displacement from the
        one shared solution (within a wave each device carries at most
        one placement, so attribution is exact).

        Returns ``{wid: (raw_completion_latency, displacement)}`` —
        the completion estimate is NOT floored by the congestion floor;
        :meth:`decide` applies the floor at decision time, so a later
        candidate's floor sees earlier batch admissions exactly as
        sequential probing would.  Candidates the pre-probe
        short-circuits of :meth:`decide` would never probe (admission
        off, or critical path already past the deadline) are omitted.
        """
        out: dict[str, tuple[float, float]] = {}
        if not self.slo.admission:
            return out
        cands: list[Workflow] = []
        for wf in wfs:
            cp = self.cp_lower_bound(wf, state)
            deadline = self.slo.deadline(state.now, cp,
                                         self._klass_of(wf.wid))
            if cp > deadline - state.now + 1e-12:
                continue                      # decide() rejects unprobed
            cands.append(wf)
        if not cands:
            return out
        self.n_probes += len(cands)
        planner = getattr(policy, "planner", None)
        if planner is not None and hasattr(planner, "plan_shared"):
            return self._probe_planned_batch(cands, state, frontier,
                                             planner, claimed)
        for wf in cands:                      # analytic probe is cheap:
            cluster_est = self._probe_analytic_raw(wf, state, frontier,
                                                   claimed)
            out[wf.wid] = cluster_est
        return out

    def _probe_analytic_raw(self, wf: Workflow, state: ExecutionState,
                            frontier,
                            claimed: set) -> tuple[float, float]:
        """:meth:`_probe_analytic` without the congestion floor —
        the batched path applies the floor in :meth:`decide`."""
        n_dev = max(state.n_live, 1)
        avg_wait = state.backlog_seconds() / n_dev
        n_ready = len(frontier.ready(claimed)) + len(wf.sources())
        contention = max(1.0, n_ready / n_dev)
        cp = self.cp_lower_bound(wf, state)
        work = sum(self._floor[wf.wid].values())
        return avg_wait + cp * contention, work / n_dev

    def _probe_planned_batch(self, wfs: Sequence[Workflow],
                             state: ExecutionState, frontier, planner,
                             claimed: set
                             ) -> dict[str, tuple[float, float]]:
        """One shared one-wave lookahead covering every candidate.

        Mirrors :meth:`_probe_planned` (same overlay protocol, same
        estimator replay, same per-source completion formula) but with
        all candidates' sources in one merged ready set, so the batch
        costs one incremental wave instead of N.
        """
        from repro.core.costs import CostModel
        from repro.core.planner import _apply_estimate

        cluster = state.cluster
        sim = state.overlay()
        before = {d: sim.device_free(d) for d in cluster.ids()}
        workflows = dict(frontier.workflows)
        ready = list(frontier.ready(claimed))
        for wf in wfs:
            workflows[wf.wid] = wf
            ready += [(wf.wid, sid) for sid in wf.sources()]
        placements = planner.plan_shared(workflows, sim, ready,
                                         max_waves=1)
        cm = CostModel(sim, getattr(planner, "cost_params", None))
        for p in placements:
            _apply_estimate(workflows[p.wid], sim, p, cm)
        cand_ids = {wf.wid for wf in wfs}
        placed: dict[tuple[str, str], float] = {}
        busy: dict[str, float] = {}
        for p in placements:
            if p.wid not in cand_ids:
                continue
            fin = max(sim.device_free(d) for d in p.devices)
            placed[(p.wid, p.sid)] = fin
            busy[p.wid] = busy.get(p.wid, 0.0) + sum(
                max(0.0, sim.device_free(d) - before[d])
                for d in p.devices)
        live = sim.live_ids() if sim.down else cluster.ids()
        release = min(sim.device_free(d) for d in live)
        n_dev = max(len(live), 1)
        out: dict[str, tuple[float, float]] = {}
        for wf in wfs:
            tails = self.tail_bounds(wf, state)
            floor = self._floor[wf.wid]
            completion = state.now
            for sid in wf.sources():
                fin = placed.get((wf.wid, sid))
                if fin is not None:
                    est = fin + (tails[sid] - floor[sid])
                else:
                    est = max(release, state.now) + tails[sid]
                completion = max(completion, est)
            out[wf.wid] = (completion - state.now,
                           busy.get(wf.wid, 0.0) / n_dev)
        return out

    # -- decisions -------------------------------------------------------
    def decide(self, wf: Workflow, state: ExecutionState, frontier,
               policy, claimed: set, arrival: float,
               probe: Optional[tuple[float, float]] = None
               ) -> AdmissionDecision:
        """Pure decision (no backlog bookkeeping): admit / defer /
        reject ``wf`` given its original ``arrival`` time.

        The SLO comparison inflates the raw probe prediction by
        :meth:`probe_margin` — the hand-set constant, or the
        corrector's live per-family estimate when online correction is
        active — so deferral re-probes automatically track the
        corrected margin too.

        ``probe``, when given, is a precomputed RAW (unfloored)
        ``(completion_latency, displacement)`` pair from
        :meth:`probe_batch`; the congestion floor is applied here, at
        decision time, so batch-mates admitted earlier in the same
        event batch raise this candidate's floor exactly as sequential
        probing would.
        """
        klass = self._klass_of(wf.wid)
        cp = self.cp_lower_bound(wf, state)
        deadline = self.slo.deadline(arrival, cp, klass)
        if not self.slo.admission:
            return AdmissionDecision("admit", cp, deadline, cp)
        budget = deadline - state.now
        if cp > budget + 1e-12:
            # unreachable even alone on an idle cluster: shed the load
            return AdmissionDecision("reject", cp, deadline, cp)
        if probe is not None:
            est, displacement = probe
            predicted = max(est,
                            self._congestion_floor(wf, state, frontier))
        else:
            predicted, displacement = self.probe(wf, state, frontier,
                                                 policy, claimed)
        margin = self.probe_margin(wf, state)
        fits = margin * predicted <= budget + 1e-12
        if fits and not self._displaces_inflight(state, frontier,
                                                 displacement, klass):
            preempt = (self.slo.preemption
                       and predicted * self.slo.preempt_slack > budget)
            return AdmissionDecision("admit", predicted, deadline, cp,
                                     preempt=preempt, margin=margin)
        return AdmissionDecision("defer", predicted, deadline, cp,
                                 margin=margin)

    def _displaces_inflight(self, state: ExecutionState, frontier,
                            displacement: float,
                            klass: str = "default") -> bool:
        """True if the candidate's displacement would push an
        otherwise-on-track in-flight workflow past its deadline.

        Workflows already predicted to miss are NOT protected — under
        overload everything is late, and refusing all admissions for
        the sake of already-lost deadlines would idle the cluster.
        In multi-class runs, STRICTLY-LOWER-weight workflows are not
        protected either: a platinum candidate may displace batch
        deadlines (the batch tier's protection is its completion
        guarantee plus aging, not deadline isolation).
        """
        if displacement <= 0.0:
            return False
        w_c = (self.slo.class_weight(klass)
               if self.slo.classes else None)
        for rem, deadline, wid in self._inflight_slack(state, frontier):
            if (w_c is not None
                    and self.slo.class_weight(self._klass_of(wid))
                    < w_c - 1e-12):
                continue
            without = state.now + rem
            if without <= deadline + 1e-12 < without + displacement:
                return True
        return False

    def _inflight_slack(self, state: ExecutionState,
                        frontier) -> list[tuple[float, float, str]]:
        """Memoized ``(remaining-tail, deadline, wid)`` triples for
        every in-flight workflow with a registered deadline.

        Keyed on ``(frontier.version, fault_epoch)`` like
        :meth:`remaining_floor_work`: the remaining tails only change
        when stages complete (version bump) or the live set changes.
        Deadlines registered for workflows not yet admitted into the
        frontier are excluded by construction (matching the unmemoized
        scan, which skipped wids absent from ``frontier.workflows``),
        so mid-sweep ``_note_admit`` calls cannot stale the memo.
        """
        self._sync_fault_epoch(state)
        ver = getattr(frontier, "version", None)
        if ver is not None and self._slack_memo is not None:
            m_ver, m_ep, m_pairs = self._slack_memo
            if m_ver == ver and m_ep == self._fault_epoch:
                return m_pairs
        pairs: list[tuple[float, float, str]] = []
        for wid, deadline in self.deadlines.items():
            wf = frontier.workflows.get(wid)
            if wf is None:
                continue
            tails = self.tail_bounds(wf, state)
            done = frontier.completed[wid]
            rem = max((tails[sid] for sid in wf.topo_order
                       if sid not in done), default=0.0)
            pairs.append((rem, deadline, wid))
        if ver is not None:
            self._slack_memo = (ver, self._fault_epoch, pairs)
        return pairs

    def _shed(self, wid: str, policy) -> None:
        """Record a rejection and release every cache that references
        the shed workflow — including the policy's planner/scorer
        caches, which the admission probes populated (a rejected
        workflow never runs, so without this a long-lived serving
        executor leaks one score table + topology cache per shed
        arrival)."""
        self.rejected.append(wid)
        self.forget(wid)
        if hasattr(policy, "forget_workflow"):
            policy.forget_workflow(wid)

    def _backlog_full(self, klass: str) -> bool:
        """Whether a deferral of class ``klass`` would overflow its
        queue: the class's own ``backlog_limit`` counted against its
        own entries when one is configured, else the shared global
        limit against the whole backlog."""
        spec = self.slo.class_spec(klass)
        if spec is not None and spec.backlog_limit is not None:
            n = sum(1 for _arr, w in self.backlog
                    if self._klass_of(w.wid) == klass)
            return n >= spec.backlog_limit
        return len(self.backlog) >= self.slo.backlog_limit

    def on_arrival(self, wf: Workflow, state: ExecutionState, frontier,
                   policy, claimed: set,
                   probe: Optional[tuple[float, float]] = None,
                   dec: Optional[AdmissionDecision] = None
                   ) -> AdmissionDecision:
        """Arrival-time decision with backlog bookkeeping applied:
        deferrals land in the bounded backlog (or degrade to reject
        when it is full); rejects are recorded.  ``probe`` forwards a
        precomputed raw estimate from :meth:`probe_batch`; ``dec``
        forwards a decision the caller already computed (the
        scheduler's running-shard preemption path re-decides after
        reclaiming devices and hands the final decision in)."""
        if dec is None:
            dec = self.decide(wf, state, frontier, policy, claimed,
                              arrival=state.now, probe=probe)
        if dec.action == "defer":
            if self._backlog_full(self._klass_of(wf.wid)):
                dec.action = "reject"
            else:
                self.backlog.append((state.now, wf))
                self.n_deferrals += 1
        if dec.action == "reject":
            self._shed(wf.wid, policy)
        elif dec.action == "admit":
            self._note_admit(wf, state, dec)
        return dec

    def readmit(self, state: ExecutionState, frontier, policy,
                claimed: set, force: bool = False
                ) -> list[tuple[float, Workflow, AdmissionDecision]]:
        """Re-admission sweep over the backlog.

        Single-class: oldest-feasible-first, exactly the historical
        order.  Multi-class (``slo.classes`` non-empty): CLASS-MAJOR —
        entries are probed by descending effective weight
        (``weight + aging_rate * wait``), ties by age (the stable sort
        preserves the backlog's arrival order), so a deferred platinum
        entry is re-probed before older batch entries while aging
        still promotes long-waiting batch work past fresh platinum.

        Entries whose deadline became unreachable are shed (rejected);
        the first entry whose fresh probe admits is returned (at most
        one per call, so the caller's frontier update is visible to the
        next sweep).  With ``force=True`` the oldest reachable entry
        (in sweep order) is admitted regardless of its probe — the
        executor uses this to drain the backlog when no further
        completion events exist.
        Returns ``[(original_arrival, workflow, decision)]``.
        """
        entries = self.backlog
        if self.slo.classes:
            entries = sorted(
                entries,
                key=lambda e: -self._eff_weight(
                    self._klass_of(e[1].wid), state.now - e[0]))
        admitted: list[tuple[float, Workflow, AdmissionDecision]] = []
        keep: list[tuple[float, Workflow]] = []
        for arrival, wf in entries:
            if admitted:
                keep.append((arrival, wf))
                continue
            cp = self.cp_lower_bound(wf, state)
            deadline = self.slo.deadline(arrival, cp,
                                         self._klass_of(wf.wid))
            if state.now + cp > deadline + 1e-12:
                self._shed(wf.wid, policy)         # expired
                continue
            if not force and self.slo.admission:
                # the probe's prediction is floored at the congestion
                # floor, so when margin·floor already exceeds the
                # budget the decision is defer regardless of what the
                # solver lookahead would say — skip the probe (FP-safe:
                # predicted = max(est, floor) ≥ floor exactly, and
                # x ↦ fl(m·x) is monotone for m > 0, so
                # m·floor > budget + ε implies m·predicted > budget + ε
                # and decide() could only defer)
                floor = self._congestion_floor(wf, state, frontier)
                margin = self.probe_margin(wf, state)
                if margin * floor > (deadline - state.now) + 1e-12:
                    keep.append((arrival, wf))
                    continue
            dec = self.decide(wf, state, frontier, policy, claimed,
                              arrival=arrival)
            if dec.action == "admit" or force:
                dec.action = "admit"
                self._note_admit(wf, state, dec)
                admitted.append((arrival, wf, dec))
            else:
                keep.append((arrival, wf))
        self.backlog = keep
        return admitted
