"""Device/cluster model for the workflow runtime.

A "device" is the scheduling unit the paper places stages on.  In the
TPU adaptation a device is a mesh slice (e.g. one v5e pod or sub-slice);
in the benchmark runtime it is a simulated accelerator with a runtime
proxy profile (the paper's own evaluation methodology, Appendix C.1).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Device:
    did: int
    name: str = ""
    memory_gb: float = 24.0
    speed: float = 1.0             # runtime multiplier (heterogeneity): cost/speed
    # β_{i,j} transfer coefficient is cluster-level; per-device scale here
    transfer_scale: float = 1.0


@dataclasses.dataclass(frozen=True)
class Cluster:
    devices: tuple[Device, ...]
    # β seconds per 1k tokens moved between distinct devices
    transfer_coef: float = 0.06
    # within-host discount pairs could refine β; keep a single coefficient
    # (the paper uses "a constant edge-transfer coefficient", C.1)

    @property
    def n(self) -> int:
        return len(self.devices)

    def beta(self, src: int, dst: int) -> float:
        if src == dst or src < 0:
            return 0.0
        return (self.transfer_coef
                * self.devices[src].transfer_scale
                * self.devices[dst].transfer_scale)

    def ids(self) -> list[int]:
        return [d.did for d in self.devices]


def homogeneous_cluster(n: int = 8, memory_gb: float = 24.0,
                        transfer_coef: float = 0.06) -> Cluster:
    """The paper's main setting: 8 identical GPUs."""
    return Cluster(tuple(Device(i, f"dev{i}", memory_gb) for i in range(n)),
                   transfer_coef=transfer_coef)


def heterogeneous_cluster(n: int = 8, transfer_coef: float = 0.06) -> Cluster:
    """Mixed-speed variant (for Helix-style heterogeneity stress)."""
    devs = []
    for i in range(n):
        speed = 1.0 if i % 2 == 0 else 0.7
        devs.append(Device(i, f"dev{i}", 24.0 if i % 2 == 0 else 16.0,
                           speed=speed))
    return Cluster(tuple(devs), transfer_coef=transfer_coef)
