"""FATE frontier planner: builds the frontier ILP from horizon-aware
scores, solves it exactly, and materializes shard-slot placements
(paper §3.3, Appendix A.2).

Two score-generation paths feed the same exact solver:

* the incremental vectorized engine (default) — the first wave of a
  planning session calls ``Scorer.score_matrix`` (signature-batched
  2-D build); every later wave — and every later ``plan()`` call for
  the same workflow — calls ``Scorer.rescore_matrix``, which reuses the
  previous wave's component cache and recomputes only entries that the
  commit-and-advance state changes invalidated.  Runs on a
  copy-on-write planning overlay;
* the scalar path (``use_matrix=False``) — the seed's per-(stage,
  slot, device) ``planner_score`` loop, kept as the reference baseline
  for parity tests and ``benchmarks/sched_bench.py``.

Both produce bit-identical weights, hence identical placements.

``plan_shared`` extends the same machinery to a merged multi-workflow
frontier: per-workflow score matrices (each delta-rescored against its
own previous wave) are stacked into one assignment problem whose rows
are ``(wid, sid)``-tagged, so many in-flight DAGs contend for devices
inside a single exact solve.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Mapping, Optional, Sequence

import numpy as np

from repro.core.costs import CostModel, CostParams, shard_partition
from repro.core.frontier_solver import (NEG, FrontierProblem,
                                        FrontierSolution,
                                        combine_solutions, merge_problems,
                                        solve_frontier_exact)
from repro.core.routing import RoutingConfig, StageRouter, variant_stage
from repro.core.scoring import FrontierScores, ScoreParams, Scorer
from repro.core.state import ExecutionState
from repro.core.workflow import Stage, StageKey, Workflow


@dataclasses.dataclass
class Placement:
    """A committed stage placement: devices[0] is the primary (slot 0).

    ``model`` is the routed model family serving the stage (cost/
    quality routing, :mod:`repro.core.routing`) — ``None`` means the
    stage's default ``Stage.model``, which is also what every
    pre-routing placement deserializes to.
    """
    wid: str
    sid: str
    devices: tuple[int, ...]
    shard_sizes: tuple[int, ...]
    score: float = 0.0
    planned_at: float = 0.0
    model: Optional[str] = None


@dataclasses.dataclass
class SolveRecord:
    """Per-solve stats for the Table 12 analogue."""
    wall_time: float
    nodes: int
    status: str
    n_rows: int
    n_devices: int
    objective: float


class FrontierPlanner:
    """Commit-and-advance frontier planner (the FATE policy's core).

    Wraps the scoring engine and the exact frontier solver into
    Algorithm 2's wave loop; see the module docstring for the score
    path taxonomy.  Switches:

    * ``use_matrix`` — vectorized engine (default) vs the seed's
      scalar reference loop;
    * ``use_delta`` — incremental delta rescoring (default) vs a full
      matrix rebuild every wave (the parity/benchmark reference);
    * ``warm_start`` — carry each merged-frontier solve's assignment
      into the next solve as a solution hint
      (:class:`FrontierProblem.hint`).  Hints only seed
      branch-and-bound pruning, so placements are bit-identical with
      warm starts on or off.

    Invariant: all four configurations produce identical placements on
    identical inputs (``tests/test_score_matrix_parity.py``,
    ``tests/test_delta_rescoring.py``, ``tests/test_preemption.py``).
    """

    def __init__(self, params: Optional[ScoreParams] = None,
                 time_limit: float = 5.0, use_matrix: bool = True,
                 use_delta: bool = True, warm_start: bool = True,
                 cost_params: Optional[CostParams] = None,
                 max_waves: Optional[int] = None, pools=1,
                 routing: Optional[RoutingConfig] = None):
        self.params = params or ScoreParams()
        # hierarchical sharded solve: > 1 splits every merged-frontier
        # wave into that many disjoint device pools (affinity-aware) and
        # solves each pool exactly; 1 keeps the monolithic merged solve;
        # "auto" derives the count per wave from device count and
        # frontier width (see _effective_pools).
        # See docs/SCALE.md for the partition scheme and its invariants.
        self.pools = pools if pools == "auto" else max(1, int(pools))
        # cost/quality model routing (docs/GATEWAY.md): when set, stages
        # declaring candidate families get extra (wid, sid, alias) rows
        # in the frontier solve under a mutual-exclusion constraint.
        # None (default) adds no rows — bit-identical to the unrouted
        # planner by construction.
        self.routing = routing
        self._router = (StageRouter(routing) if routing is not None
                        else None)
        # test/bench hook: explicit device-id pools (list of id lists)
        # that override the residency-aware partitioner when set.
        self._forced_partition: Optional[list[list[int]]] = None
        # default wave cap of plan_shared (None = plan until the merged
        # frontier is exhausted); per-call max_waves overrides it — the
        # admission probe always passes 1 regardless of this default
        self.max_waves = max_waves
        # cost-model calibration of every CostModel this planner builds
        # (both score paths and the commit-and-advance estimator) —
        # None keeps the hand-set defaults; a CalibrationProfile's
        # cost_params() goes here when a profile is loaded
        self.cost_params = cost_params
        self.time_limit = time_limit
        self.use_matrix = use_matrix
        # use_delta=False forces a full matrix rebuild every wave — the
        # reference for incremental-vs-full parity tests and benchmarks
        self.use_delta = use_delta
        self.warm_start = warm_start
        # rolling ((wid, sid), slot) -> device hint fed to the next
        # merged solve; revoked (preempted) commitments re-enter later
        # waves with their previous devices as the warm start.
        self._shared_hint: dict = {}
        self.solve_log: list[SolveRecord] = []
        self._scorer: Optional[Scorer] = None
        # last wave's score tables per workflow: the seed of the next
        # delta rescore (within a plan() session and across sessions).
        # Bounded: long-lived planners seeing a stream of unique wids
        # (serving without retirement calls) evict oldest-first.
        self._wave_scores: dict[str, FrontierScores] = {}
        self._max_cached_workflows = 64
        # per-phase timing accumulators (benchmarks --profile)
        self.phase_ms = {"full_build": 0.0, "delta_rescore": 0.0,
                         "solve": 0.0}

    def _get_scorer(self, sim: ExecutionState) -> Scorer:
        if self._scorer is None:
            self._scorer = Scorer(sim, CostModel(sim, self.cost_params),
                                  self.params)
        else:
            self._scorer.rebind(sim)
        return self._scorer

    def _store_snapshot(self, wid: str, fs: FrontierScores) -> None:
        if wid not in self._wave_scores and \
                len(self._wave_scores) >= self._max_cached_workflows:
            self.forget_workflow(next(iter(self._wave_scores)))
        self._wave_scores[wid] = fs

    def forget_workflow(self, wid: str) -> None:
        """Release cached scores/topology/hints for a retired workflow."""
        self._wave_scores.pop(wid, None)
        if self._scorer is not None:
            self._scorer.forget_workflow(wid)
        if self._router is not None:
            self._router.forget_workflow(wid)
        if self._shared_hint:
            self._shared_hint = {k: d for k, d in
                                 self._shared_hint.items()
                                 if k[0][0] != wid}

    def drop_device_hints(self, device: int) -> None:
        """Scrub warm-start hints pointing at a downed device.

        The exact solver skips infeasible hints anyway; dropping them
        here keeps the hint dictionary from steering branch-and-bound
        toward a device that no longer exists.
        """
        if self._shared_hint:
            self._shared_hint = {k: d for k, d in
                                 self._shared_hint.items()
                                 if d != device}

    def plan(self, wf: Workflow, state: ExecutionState,
             ready: list[str]) -> list[Placement]:
        """Commit-and-advance planning (Algorithm 2): repeatedly solve
        frontier waves, advancing a simulated execution-state view
        between waves (each device takes at most one assignment per
        wave; estimated completion effects — residency, prefix warmth,
        availability — feed the next wave's scores)."""
        out: list[Placement] = []
        if self.use_matrix:
            sim = state.overlay()          # copy-on-write planning view
            scorer = self._get_scorer(sim)
            cm = scorer.cm                 # hoisted out of the wave loop
        else:
            sim = _simulate_copy(state)    # seed behavior: full dict copy
            cm = scorer = None
        remaining = list(ready)
        # cross-session snapshot: only the FIRST wave's tables (free of
        # this session's estimated placements) seed the next plan() call
        prev = (self._wave_scores.get(wf.wid)
                if self.use_matrix and self.use_delta else None)
        n_wave = 0
        while remaining:
            if self.use_matrix:
                # wave 0 rescoring verifies against full snapshots (no
                # claim on the base state's marks); later waves patch
                # from the overlay's own single-consumer dirty set
                wave, fs = self._plan_wave_fast(
                    wf, sim, remaining, cm, scorer,
                    prev if self.use_delta else None,
                    consume=(n_wave != 1),
                    dirty=(sim.drain_dirty() if n_wave else None))
                if n_wave == 0 and fs is not None:
                    self._store_snapshot(wf.wid, fs)
                prev = fs
                n_wave += 1
            else:
                wave = self._plan_wave(wf, sim, remaining)
            if not wave:
                break
            apply_cm = cm if cm is not None \
                else CostModel(sim, self.cost_params)
            for p in wave:
                _apply_estimate(wf, sim, p, apply_cm)
            placed = {p.sid for p in wave}
            remaining = [s for s in remaining if s not in placed]
            out.extend(wave)
        return out

    # ------------------------------------------------------------------
    # multi-workflow shared frontier
    # ------------------------------------------------------------------
    def plan_shared(self, workflows: dict[str, Workflow],
                    state: ExecutionState,
                    ready: Sequence[StageKey],
                    max_waves: Optional[int] = None,
                    priorities: Optional[Mapping[str, float]] = None
                    ) -> list[Placement]:
        """Commit-and-advance over the merged frontier of many DAGs.

        Each in-flight workflow's ready rows are scored by the same
        incremental engine (model demand and device pressure merged
        across workflows), stacked into one ``(wid, sid)``-keyed
        assignment problem, and solved exactly — so workflows compete
        for devices inside a single wave instead of being placed
        greedily one DAG at a time.

        ``max_waves`` bounds the number of solver waves — the
        admission controller's future-state probe runs a single wave
        (``max_waves=1``) to predict an arrival's marginal impact
        without paying for a full plan.  ``None`` (default) falls back
        to the planner-level ``max_waves`` (itself ``None`` = plan
        until the frontier is exhausted).

        ``priorities`` optionally maps ``wid`` to a class weight that
        multiplies the workflow's objective rows, biasing the shared
        solve toward higher-class work without changing feasibility.
        A weight of exactly 1.0 is skipped entirely, so uniform
        priorities solve the bit-identical unweighted problem.
        """
        if max_waves is None:
            max_waves = self.max_waves
        if not ready:
            return []
        sim = state.overlay()
        scorer = self._get_scorer(sim)
        cm = scorer.cm
        out: list[Placement] = []
        remaining: list[StageKey] = [k for k in ready
                                     if k[0] in workflows]
        # per-workflow intra-session wave chains; index 0 of each chain
        # is the preserved cross-session snapshot (estimate-free)
        session: dict[str, tuple[FrontierScores, int]] = {}
        n_waves = 0
        while remaining:
            wave = self._plan_wave_shared(workflows, sim, remaining,
                                          scorer, session,
                                          priorities=priorities)
            if not wave:
                break
            for p in wave:
                _apply_estimate(workflows[p.wid], sim, p, cm)
            placed = {(p.wid, p.sid) for p in wave}
            remaining = [k for k in remaining if k not in placed]
            out.extend(wave)
            n_waves += 1
            if max_waves is not None and n_waves >= max_waves:
                break
        return out

    def _plan_wave_shared(self, workflows: dict[str, Workflow],
                          sim: ExecutionState,
                          remaining: Sequence[StageKey],
                          scorer: Scorer,
                          session: dict,
                          priorities: Optional[Mapping[str, float]] = None
                          ) -> list[Placement]:
        by_wid: dict[str, list[str]] = {}
        for wid, sid in remaining:
            by_wid.setdefault(wid, []).append(sid)
        # merged frontier demand: cross-DAG same-model stages are
        # siblings too, and pressure reflects total contention
        counts: dict[str, int] = {}
        entries = []
        for wid, sids in by_wid.items():
            wf = workflows[wid]
            for sid in sids:
                counts[wf.stages[sid].model] = \
                    counts.get(wf.stages[sid].model, 0) + 1
                entries.append((wf, sid))
        pressure = scorer._pressure(entries)
        problems: list[FrontierProblem] = []
        base_sum, base_n = 0.0, 0
        per_wf: list[tuple[str, FrontierScores, list[str]]] = []
        # one drain per wave: every workflow's rescore must see the same
        # dirty-device set (a per-call drain would feed only the first).
        # The session's first wave makes no claim at all — it verifies
        # against full warm snapshots instead.
        dirty = sim.drain_dirty() if session else None
        for wid, sids in by_wid.items():
            wf = workflows[wid]
            scorer.set_frontier_shared(wf, sids, counts, pressure)
            t0 = time.perf_counter()
            entry = session.get(wid)
            if entry is None:             # first wave for this workflow
                prev, n_scored = self._wave_scores.get(wid), 0
            else:
                prev, n_scored = entry
            if not self.use_delta:
                prev = None
            fs = scorer.rescore_matrix(wf, sids, prev,
                                       consume=(n_scored != 1),
                                       dirty=dirty)
            key = "full_build" if fs.built_full else "delta_rescore"
            self.phase_ms[key] += (time.perf_counter() - t0) * 1e3
            if n_scored == 0:
                self._store_snapshot(wid, fs)  # cross-session snapshot
            session[wid] = (fs, n_scored + 1)
            per_wf.append((wid, fs, sids))
            flat = fs.base.reshape(-1).tolist()
            base_sum += sum(flat)
            base_n += len(flat)
        margin = (self.params.margin_factor * (base_sum / base_n)
                  if base_n else 1.0)
        partition = None
        n_pools = self._effective_pools(len(sim.cluster.ids()),
                                        len(remaining))
        if n_pools > 1 or self._forced_partition is not None:
            partition = self._partition_frontier(sim, workflows, by_wid,
                                                 counts, n_pools)
        if partition is not None:
            return self._solve_pooled(workflows, sim, per_wf, margin,
                                      partition, priorities=priorities)
        for wid, fs, sids in per_wf:
            fsm = self._mask_down(fs, sim)
            rows, weights = self._rows_from_scores(
                fsm, sids, margin, key_of=lambda s, w=wid: (w, s))
            weights = _scale_weights(weights, priorities, wid)
            exclusive = None
            if self._router is not None:
                wf = workflows[wid]
                # re-arm the merged frontier context: the scoring loop
                # above left the scorer on the LAST workflow's caches
                scorer.set_frontier_shared(wf, sids, counts, pressure)
                vrows, vweights, groups = self._variant_rows(
                    wf, sim, scorer, fsm, sids, margin,
                    key_of=lambda s, w=wid: (w, s))
                if vrows:
                    rows = rows + vrows
                    weights = weights + _scale_weights(
                        vweights, priorities, wid)
                    exclusive = groups
            if rows:
                hint = None
                if self.warm_start and self._shared_hint:
                    hint = {r: self._shared_hint[r] for r in rows
                            if r in self._shared_hint} or None
                problems.append(FrontierProblem(
                    rows, fs.devices, np.array(weights), hint=hint,
                    exclusive=exclusive))
        if not problems:
            return []
        problem = merge_problems(problems)
        t0 = time.perf_counter()
        sol = solve_frontier_exact(problem, self.time_limit)
        self.phase_ms["solve"] += (time.perf_counter() - t0) * 1e3
        if self.warm_start:
            # next wave's (and next replan's) warm start; revoked
            # commitments reappear as rows and pick their old device
            # hints back up.  Rebuild rather than grow without bound.
            if len(self._shared_hint) > 8192:
                self._shared_hint = dict(sol.assignment)
            else:
                self._shared_hint.update(sol.assignment)
        self.solve_log.append(SolveRecord(
            wall_time=sol.wall_time, nodes=sol.nodes, status=sol.status,
            n_rows=len(problem.rows), n_devices=len(problem.devices),
            objective=sol.objective))
        return self._materialize_shared(workflows, sim, sol)

    # ------------------------------------------------------------------
    # hierarchical sharded solve (device-pool partitioning)
    # ------------------------------------------------------------------
    def _effective_pools(self, n_devices: int, n_rows: int) -> int:
        """Resolve the pool count for one wave.

        A fixed integer ``pools`` passes through unchanged.  With
        ``pools="auto"`` the count is derived per wave: one pool per
        16 devices, further capped so each pool keeps a useful share of
        the frontier (at least ~4 ready rows per pool) — small clusters
        and narrow frontiers resolve to 1, which IS the monolithic
        merged solve (``tests/test_pools_auto.py`` asserts parity).
        Deterministic in its two inputs.
        """
        if self.pools != "auto":
            return self.pools
        return max(1, min(n_devices // 16, n_rows // 4))

    def _partition_frontier(self, sim: ExecutionState,
                            workflows: dict[str, Workflow],
                            by_wid: dict[str, list[str]],
                            counts: dict[str, int],
                            n_pools: int = 0
                            ) -> Optional[tuple[list[list[int]],
                                                dict[str, int]]]:
        """Split one wave into per-pool subproblems, or ``None``.

        Builds ``pools`` disjoint device pools (column positions into
        the canonical cluster id order) by greedily packing residency
        groups — same-resident-model devices stay together, groups
        ordered by merged-frontier demand — then assigns every workflow
        wholly to one pool by resident-model affinity with
        load-balancing tie-breaks.  All choices are deterministic
        functions of the (sorted) inputs, so identical states partition
        identically.

        Returns ``None`` — caller falls back to the monolithic merged
        solve for this wave — whenever some workflow has a ready stage
        with no live eligible device in any single pool, or the pool
        count cannot be realized.  The fallback keeps the pool
        invariants (each pool solved independently ⇒ at most one
        assignment per device per wave requires disjoint pools covering
        every candidate device of every row in the subproblem).
        """
        ids = sim.cluster.ids()
        pos_of = {d: j for j, d in enumerate(ids)}
        if self._forced_partition is not None:
            pool_cols = [sorted(pos_of[d] for d in grp)
                         for grp in self._forced_partition]
            if sorted(j for cols in pool_cols for j in cols) \
                    != list(range(len(ids))):
                raise ValueError(
                    "forced partition must cover every device exactly "
                    "once")
        else:
            if not n_pools:
                n_pools = self.pools if self.pools != "auto" else 1
            if n_pools <= 1 or n_pools >= len(ids):
                return None
            groups = sim.residency_groups()
            ordered = sorted((m for m in groups if m is not None),
                             key=lambda m: (-counts.get(m, 0), m))
            if None in groups:
                ordered.append(None)
            pool_cols = [[] for _ in range(n_pools)]
            for m in ordered:
                pi = min(range(n_pools),
                         key=lambda i: (len(pool_cols[i]), i))
                pool_cols[pi].extend(pos_of[d] for d in groups[m])
            # no pool may be empty: steal trailing columns from the
            # fullest pool (deterministic donor choice)
            for pi in range(n_pools):
                while not pool_cols[pi]:
                    donor = max(range(n_pools),
                                key=lambda i: (len(pool_cols[i]), -i))
                    if len(pool_cols[donor]) <= 1:
                        return None
                    pool_cols[pi].append(pool_cols[donor].pop())
            pool_cols = [sorted(cols) for cols in pool_cols]
        down = getattr(sim, "down", None) or set()
        # per-pool live-device tallies by resident model (affinity) and
        # overall (feasibility fast path for unconstrained stages)
        n_pools = len(pool_cols)
        pool_live = [0] * n_pools
        aff: dict[str, list[int]] = {}
        for pi, cols in enumerate(pool_cols):
            for j in cols:
                d = ids[j]
                if d in down:
                    continue
                pool_live[pi] += 1
                m = sim.residency.get(d)
                if m is not None:
                    aff.setdefault(m, [0] * n_pools)[pi] += 1
        zeros = [0] * n_pools
        wid_pool: dict[str, int] = {}
        rows_per_pool = [0] * n_pools
        for wid, sids in by_wid.items():
            wf = workflows[wid]
            feasible = []
            for pi, cols in enumerate(pool_cols):
                if not pool_live[pi]:
                    continue
                ok = True
                for sid in sids:
                    elig = wf.stages[sid].eligible
                    if not elig:
                        continue        # any live device serves
                    if not any(ids[j] in elig and ids[j] not in down
                               for j in cols):
                        ok = False
                        break
                if ok:
                    feasible.append(pi)
            if not feasible:
                return None
            best = max(feasible, key=lambda pi: (
                sum(aff.get(wf.stages[sid].model, zeros)[pi]
                    for sid in sids),
                -rows_per_pool[pi], -pi))
            wid_pool[wid] = best
            rows_per_pool[best] += len(sids)
        return pool_cols, wid_pool

    def _solve_pooled(self, workflows: dict[str, Workflow],
                      sim: ExecutionState,
                      per_wf: list[tuple[str, FrontierScores, list[str]]],
                      margin: float,
                      partition: tuple[list[list[int]], dict[str, int]],
                      priorities: Optional[Mapping[str, float]] = None
                      ) -> list[Placement]:
        """Exact per-pool solves of one partitioned wave.

        Score tables are built (and delta-rescored) on the full device
        axis exactly as in the monolithic path — the wave margin too —
        then column-sliced per pool via :meth:`FrontierScores.restrict`,
        so a single-pool partition reproduces the monolithic solve
        bit-for-bit.  Pools are solved in index order and the disjoint
        per-pool assignments unioned (:func:`combine_solutions`), which
        keeps materialization order deterministic.
        """
        pool_cols, wid_pool = partition
        sols = []
        for pi, cols in enumerate(pool_cols):
            probs: list[FrontierProblem] = []
            n_rows = 0
            for wid, fs, sids in per_wf:
                if wid_pool.get(wid) != pi:
                    continue
                sub = self._mask_down(fs, sim).restrict(cols)
                rows, weights = self._rows_from_scores(
                    sub, sids, margin, key_of=lambda s, w=wid: (w, s))
                weights = _scale_weights(weights, priorities, wid)
                exclusive = None
                if self._router is not None:
                    # variants scored over the pool's device columns
                    # (solo_best pool-local, like the default rows);
                    # the scorer still carries this wave's merged
                    # counts/pressure from the scoring loop
                    vrows, vweights, groups = self._variant_rows(
                        workflows[wid], sim, self._scorer, sub, sids,
                        margin, key_of=lambda s, w=wid: (w, s))
                    if vrows:
                        rows = rows + vrows
                        weights = weights + _scale_weights(
                            vweights, priorities, wid)
                        exclusive = groups
                if not rows:
                    continue
                hint = None
                if self.warm_start and self._shared_hint:
                    # stale entries pointing outside the pool are
                    # ignored by the solver (absent-device hints)
                    hint = {r: self._shared_hint[r] for r in rows
                            if r in self._shared_hint} or None
                probs.append(FrontierProblem(
                    rows, sub.devices, np.array(weights), hint=hint,
                    exclusive=exclusive))
                n_rows += len(rows)
            if not probs:
                continue
            problem = merge_problems(probs)
            t0 = time.perf_counter()
            sol = solve_frontier_exact(problem, self.time_limit)
            self.phase_ms["solve"] += (time.perf_counter() - t0) * 1e3
            self.solve_log.append(SolveRecord(
                wall_time=sol.wall_time, nodes=sol.nodes,
                status=sol.status, n_rows=len(problem.rows),
                n_devices=len(problem.devices),
                objective=sol.objective))
            sols.append(sol)
        if not sols:
            return []
        combined = combine_solutions(sols)
        if self.warm_start:
            if len(self._shared_hint) > 8192:
                self._shared_hint = dict(combined.assignment)
            else:
                self._shared_hint.update(combined.assignment)
        return self._materialize_shared(workflows, sim, combined)

    # ------------------------------------------------------------------
    # vectorized wave
    # ------------------------------------------------------------------
    @staticmethod
    def _mask_down(fs: FrontierScores, state: ExecutionState
                   ) -> FrontierScores:
        """Solver view of a score table with downed devices excluded.

        Returns ``fs`` unchanged on the (fault-free) fast path.  When
        ``state.down`` is non-empty, a SHALLOW masked copy is built —
        downed columns forced to ``NEG`` / ``inf`` / ineligible, every
        row flagged constrained — so cached tables (the delta-rescore
        seeds) are never mutated and the mask costs nothing once the
        device recovers.
        """
        down = getattr(state, "down", None)
        if not down:
            return fs
        pos = [j for j, d in enumerate(fs.devices) if d in down]
        if not pos:
            return fs
        raw = fs.raw.copy()
        raw[:, pos] = NEG
        eft = fs.eft.copy()
        eft[:, pos] = np.inf
        eligible = fs.eligible.copy()
        eligible[:, pos] = False
        return dataclasses.replace(
            fs, raw=raw, eft=eft, eligible=eligible,
            constrained=[True] * len(fs.ready))

    def _variant_rows(self, wf: Workflow, sim: ExecutionState,
                      scorer: Scorer, fs: FrontierScores,
                      ready: list[str], margin: float,
                      key_of=lambda s: s
                      ) -> tuple[list[tuple], list[np.ndarray],
                                 list[list]]:
        """Extra solver rows for routed model-family variants.

        For every ready stage with admissible candidates
        (:class:`~repro.core.routing.StageRouter`), scores the routed
        twin per (slot, device) through the scalar engine — bit-
        identical to a matrix row by the repo's parity invariant — and
        normalizes slot-0 weights against the DEFAULT family's best
        (``margin + raw − best_default``), so a family only outbids the
        default when its best device genuinely scores higher.  Returns
        ``(rows, weights, exclusive_groups)`` with rows keyed
        ``key_of(sid) + (alias,)``; all empty when routing is off or no
        stage declares candidates, leaving the solve untouched.
        """
        if self._router is None:
            return [], [], []
        rows: list[tuple] = []
        weights: list[np.ndarray] = []
        groups: list[list] = []
        devices = fs.devices
        down = getattr(sim, "down", None) or ()
        for i, sid in enumerate(ready):
            stage = wf.stages[sid]
            cands = self._router.candidates(wf.wid, stage, sim.profiles)
            if not cands:
                continue
            raw_def = fs.raw[i]
            if np.all(raw_def <= NEG / 2):
                continue            # default unplaceable: don't route
            best_def = raw_def[raw_def > NEG / 2].max()
            base_key = key_of(sid)
            group = [base_key]
            for alias, _quality, vstage in cands:
                eligible = (set(vstage.eligible) if vstage.eligible
                            else None)
                raw = np.full(len(devices), NEG)
                efts = np.full(len(devices), np.inf)
                for j, d in enumerate(devices):
                    if d in down:
                        continue
                    if eligible is not None and d not in eligible:
                        continue
                    raw[j] = scorer.planner_score(wf, vstage, 0, d, 0.0)
                    efts[j] = scorer.corrected_eft(wf, vstage, d)
                if np.all(raw <= NEG / 2):
                    continue
                key = (*base_key, alias) if isinstance(base_key, tuple) \
                    else (base_key, alias)
                rows.append((key, 0))
                weights.append(np.where(raw > NEG / 2,
                                        margin + raw - best_def, NEG))
                solo_best = float(np.min(efts))
                max_slots = (vstage.max_shards
                             if self.params.enable_shard else 1)
                for k in range(1, max_slots):
                    w = np.full(len(devices), NEG)
                    for j, d in enumerate(devices):
                        if d in down:
                            continue
                        if eligible is not None and d not in eligible:
                            continue
                        w[j] = scorer.planner_score(
                            wf, vstage, k, d, 0.0, solo_best=solo_best)
                    if np.all(w <= NEG / 2):
                        continue
                    rows.append((key, k))
                    weights.append(w)
                group.append(key)
            if len(group) > 1:
                groups.append(group)
        return rows, weights, groups

    def _rows_from_scores(self, fs: FrontierScores, ready: list[str],
                          margin: float, key_of=lambda s: s
                          ) -> tuple[list[tuple], list[np.ndarray]]:
        """Regret-margin solver rows from one score table."""
        rows: list[tuple] = []
        weights: list[np.ndarray] = []
        for i, sid in enumerate(ready):
            raw = fs.raw[i]
            if fs.constrained[i]:
                if np.all(raw <= NEG / 2):
                    continue
                best = raw[raw > NEG / 2].max()
                w0 = np.where(raw > NEG / 2, margin + raw - best, NEG)
            else:                       # no eligibility holes: fast path
                best = raw.max()
                w0 = margin + raw - best
            solo_best = float(np.min(fs.eft[i]))
            rows.append((key_of(sid), 0))
            weights.append(w0)
            for k in range(1, fs.max_slots[i]):
                w = fs.shard_weights(i, k, solo_best)
                if fs.constrained[i] and np.all(w <= NEG / 2):
                    continue
                rows.append((key_of(sid), k))
                weights.append(w)
        return rows, weights

    def _plan_wave_fast(self, wf: Workflow, state: ExecutionState,
                        ready: list[str], cm: CostModel,
                        scorer: Scorer,
                        prev: Optional[FrontierScores] = None,
                        consume: bool = True,
                        dirty: Optional[set] = None
                        ) -> tuple[list[Placement],
                                   Optional[FrontierScores]]:
        """One solver wave fed by the incremental scoring engine."""
        if not ready:
            return [], None
        scorer.set_frontier(wf, ready)
        t0 = time.perf_counter()
        fs = scorer.rescore_matrix(wf, ready, prev, consume=consume,
                                   dirty=dirty)
        key = "full_build" if fs.built_full else "delta_rescore"
        self.phase_ms[key] += (time.perf_counter() - t0) * 1e3
        devices = fs.devices

        # margin: same all-pairs mean as the scalar path, accumulated
        # in the same (row-major, builtin-sum) order for bit parity.
        flat = fs.base.reshape(-1).tolist()
        margin = (self.params.margin_factor * (sum(flat) / len(flat))
                  if flat else 1.0)

        fsm = self._mask_down(fs, state)
        rows, weights = self._rows_from_scores(fsm, ready, margin)
        exclusive = None
        if self._router is not None:
            vrows, vweights, groups = self._variant_rows(
                wf, state, scorer, fsm, ready, margin)
            if vrows:
                rows = rows + vrows
                weights = weights + vweights
                exclusive = groups
        if not rows:
            return [], fs

        problem = FrontierProblem(rows, devices, np.array(weights),
                                  exclusive=exclusive)
        t0 = time.perf_counter()
        sol = solve_frontier_exact(problem, self.time_limit)
        self.phase_ms["solve"] += (time.perf_counter() - t0) * 1e3
        self.solve_log.append(SolveRecord(
            wall_time=sol.wall_time, nodes=sol.nodes, status=sol.status,
            n_rows=len(rows), n_devices=len(devices),
            objective=sol.objective))
        return self._materialize(wf, state, cm, sol), fs

    # ------------------------------------------------------------------
    # scalar wave (seed reference path)
    # ------------------------------------------------------------------
    def _plan_wave(self, wf: Workflow, state: ExecutionState,
                   ready: list[str]) -> list[Placement]:
        """One CP-SAT wave over the current ready frontier."""
        if not ready:
            return []
        cm = CostModel(state, self.cost_params)
        scorer = Scorer(state, cm, self.params)
        scorer.set_frontier(wf, ready)
        q = wf.num_queries
        devices = state.cluster.ids()

        # Regret-based wave scores: each stage's best placement scores a
        # small positive margin; alternatives score margin − regret and
        # may go negative, in which case the solver defers the stage to
        # a later wave (e.g. queueing behind a model-resident device
        # instead of paying a switch now).  The sum objective then
        # approximates completion-time impact rather than raw placement
        # count — the "balancing versus future-state preservation"
        # tradeoff of §1 is decided by the score terms.
        base_costs = [cm.base_cost(wf.stages[sid], d, q)
                      for sid in ready for d in devices]
        margin = (self.params.margin_factor
                  * (sum(base_costs) / len(base_costs))
                  if base_costs else 1.0)

        rows: list[tuple] = []
        weights: list[np.ndarray] = []
        down = getattr(state, "down", None) or ()
        for sid in ready:
            stage = wf.stages[sid]
            eligible = set(stage.eligible) if stage.eligible else None
            max_slots = (stage.max_shards if self.params.enable_shard
                         else 1)
            raw = np.full(len(devices), NEG)
            efts = np.full(len(devices), np.inf)
            for j, d in enumerate(devices):
                if d in down:
                    continue
                if eligible is not None and d not in eligible:
                    continue
                raw[j] = scorer.planner_score(wf, stage, 0, d, 0.0)
                efts[j] = scorer.corrected_eft(wf, stage, d)
            if np.all(raw <= NEG / 2):
                continue
            best = raw[raw > NEG / 2].max()
            solo_best = float(np.min(efts))
            w0 = np.where(raw > NEG / 2, margin + raw - best, NEG)
            rows.append((sid, 0))
            weights.append(w0)
            for k in range(1, max_slots):
                w = np.full(len(devices), NEG)
                for j, d in enumerate(devices):
                    if d in down:
                        continue
                    if eligible is not None and d not in eligible:
                        continue
                    w[j] = scorer.planner_score(wf, stage, k, d, 0.0,
                                                solo_best=solo_best)
                if np.all(w <= NEG / 2):
                    continue
                rows.append((sid, k))
                weights.append(w)
        if not rows:
            return []

        problem = FrontierProblem(rows, devices, np.array(weights))
        sol = solve_frontier_exact(problem, self.time_limit)
        self.solve_log.append(SolveRecord(
            wall_time=sol.wall_time, nodes=sol.nodes, status=sol.status,
            n_rows=len(rows), n_devices=len(devices),
            objective=sol.objective))
        return self._materialize(wf, state, cm, sol)

    def _materialize(self, wf: Workflow, state: ExecutionState,
                     cm: CostModel, sol: FrontierSolution
                     ) -> list[Placement]:
        by_stage: dict = {}
        for (key, slot), dev in sol.assignment.items():
            by_stage.setdefault(key, {})[slot] = dev
        out: list[Placement] = []
        for key, slots in by_stage.items():
            if 0 not in slots:     # primary slot missing: drop (solver
                continue           # guarantees monotonicity, belt&braces)
            # routed variant rows key as (sid, alias); default as sid
            sid, model = key if isinstance(key, tuple) else (key, None)
            devs = tuple(slots[k] for k in sorted(slots))
            speeds = [state.cluster.devices[d].speed for d in devs]
            sizes = tuple(shard_partition(wf.num_queries, speeds))
            out.append(Placement(wid=wf.wid, sid=sid, devices=devs,
                                 shard_sizes=sizes, score=sol.objective,
                                 planned_at=state.now, model=model))
        return out

    def _materialize_shared(self, workflows: dict[str, Workflow],
                            state: ExecutionState, sol: FrontierSolution
                            ) -> list[Placement]:
        """Materialize a merged-frontier solution whose stage keys are
        ``(wid, sid)`` tuples."""
        by_stage: dict[tuple, dict[int, int]] = {}
        for (key, slot), dev in sol.assignment.items():
            by_stage.setdefault(key, {})[slot] = dev
        out: list[Placement] = []
        for key, slots in by_stage.items():
            if 0 not in slots:
                continue
            # routed variant rows key as (wid, sid, alias)
            wid, sid = key[0], key[1]
            model = key[2] if len(key) == 3 else None
            wf = workflows[wid]
            devs = tuple(slots[k] for k in sorted(slots))
            speeds = [state.cluster.devices[d].speed for d in devs]
            sizes = tuple(shard_partition(wf.num_queries, speeds))
            out.append(Placement(wid=wid, sid=sid, devices=devs,
                                 shard_sizes=sizes, score=sol.objective,
                                 planned_at=state.now, model=model))
        return out


def _simulate_copy(state: ExecutionState) -> ExecutionState:
    """Cheap planning copy of the execution state (dict-level)."""
    import copy
    sim = ExecutionState(
        cluster=state.cluster, profiles=state.profiles,
        residency=dict(state.residency),
        prefix={d: {g: copy.copy(e) for g, e in m.items()}
                for d, m in state.prefix.items()},
        output_loc=dict(state.output_loc),
        free_at=dict(state.free_at), now=state.now)
    sim.completed = set(state.completed)
    sim.down = set(state.down)
    sim.fault_epoch = state.fault_epoch
    return sim


def _scale_weights(weights: list, priorities: Optional[Mapping[str, float]],
                   wid: str) -> list:
    """Multiply one workflow's objective rows by its class priority.

    The exact-1.0 skip is load-bearing: uniform priorities must hand
    the solver the untouched weight arrays so single-class runs stay
    bit-identical to priority-free planning.
    """
    if not priorities:
        return weights
    w = float(priorities.get(wid, 1.0))
    if w == 1.0:
        return weights
    return [w * arr for arr in weights]


def _apply_estimate(wf: Workflow, sim: ExecutionState, p: Placement,
                    cm: Optional[CostModel] = None) -> None:
    """Advance the simulated state by a placement's estimated effects.

    A routed placement (``p.model`` set by :meth:`_variant_rows`' solver
    rows) is estimated against its routed twin — residency, prefix
    warmth, and duration all follow the family that will actually run.
    """
    if cm is None:
        cm = CostModel(sim)
    st = wf.stages[p.sid]
    if p.model is not None and p.model != st.model:
        st = variant_stage(st, p.model, sim.profiles)
    fins = []
    for d, nq in zip(p.devices, p.shard_sizes):
        t0 = max(sim.now, sim.device_free(d))
        dur = max(1e-6, cm.breakdown(wf, st, d, nq).total)
        sim.set_free_at(d, t0 + dur)
        # raw residency write (no switch counting / prefix pruning in
        # the planning estimate), but still marked for delta rescoring
        sim.residency[d] = st.model
        sim.touch_device(d)
        if st.keep_cache:
            sim.warm_prefix(d, st.prefix_group, st.model, nq, t0 + dur)
        fins.append(t0 + dur)
    sim.output_loc[(wf.wid, p.sid)] = p.devices
    sim.completed.add((wf.wid, p.sid))
