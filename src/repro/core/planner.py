"""FATE frontier planner: builds the frontier ILP from horizon-aware
scores, solves it exactly, and materializes shard-slot placements
(paper §3.3, Appendix A.2).

Two score-generation paths feed the same exact solver:

* the vectorized engine (default) — one ``Scorer.score_matrix`` call
  per wave computes the full frontier × device table with numpy over
  cached DAG topology, on a copy-on-write planning overlay;
* the scalar path (``use_matrix=False``) — the seed's per-(stage,
  slot, device) ``planner_score`` loop, kept as the reference baseline
  for parity tests and ``benchmarks/sched_bench.py``.

Both produce bit-identical weights, hence identical placements.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.costs import CostModel, shard_partition
from repro.core.frontier_solver import (NEG, FrontierProblem,
                                        FrontierSolution,
                                        solve_frontier_exact)
from repro.core.scoring import ScoreParams, Scorer
from repro.core.state import ExecutionState
from repro.core.workflow import Stage, Workflow


@dataclasses.dataclass
class Placement:
    """A committed stage placement: devices[0] is the primary (slot 0)."""
    wid: str
    sid: str
    devices: tuple[int, ...]
    shard_sizes: tuple[int, ...]
    score: float = 0.0
    planned_at: float = 0.0


@dataclasses.dataclass
class SolveRecord:
    """Per-solve stats for the Table 12 analogue."""
    wall_time: float
    nodes: int
    status: str
    n_rows: int
    n_devices: int
    objective: float


class FrontierPlanner:
    def __init__(self, params: Optional[ScoreParams] = None,
                 time_limit: float = 5.0, use_matrix: bool = True):
        self.params = params or ScoreParams()
        self.time_limit = time_limit
        self.use_matrix = use_matrix
        self.solve_log: list[SolveRecord] = []

    def plan(self, wf: Workflow, state: ExecutionState,
             ready: list[str]) -> list[Placement]:
        """Commit-and-advance planning (Algorithm 2): repeatedly solve
        frontier waves, advancing a simulated execution-state view
        between waves (each device takes at most one assignment per
        wave; estimated completion effects — residency, prefix warmth,
        availability — feed the next wave's scores)."""
        out: list[Placement] = []
        if self.use_matrix:
            sim = state.overlay()          # copy-on-write planning view
            cm = CostModel(sim)            # hoisted out of the wave loop
            scorer = Scorer(sim, cm, self.params)
        else:
            sim = _simulate_copy(state)    # seed behavior: full dict copy
            cm = scorer = None
        remaining = list(ready)
        while remaining:
            if self.use_matrix:
                wave = self._plan_wave_fast(wf, sim, remaining, cm,
                                            scorer)
            else:
                wave = self._plan_wave(wf, sim, remaining)
            if not wave:
                break
            apply_cm = cm if cm is not None else CostModel(sim)
            for p in wave:
                _apply_estimate(wf, sim, p, apply_cm)
            placed = {p.sid for p in wave}
            remaining = [s for s in remaining if s not in placed]
            out.extend(wave)
        return out

    # ------------------------------------------------------------------
    # vectorized wave
    # ------------------------------------------------------------------
    def _plan_wave_fast(self, wf: Workflow, state: ExecutionState,
                        ready: list[str], cm: CostModel,
                        scorer: Scorer) -> list[Placement]:
        """One solver wave fed by the batched scoring engine."""
        if not ready:
            return []
        scorer.set_frontier(wf, ready)
        fs = scorer.score_matrix(wf, ready)
        devices = fs.devices

        # margin: same all-pairs mean as the scalar path, accumulated
        # in the same (row-major, builtin-sum) order for bit parity.
        flat = fs.base.reshape(-1).tolist()
        margin = (self.params.margin_factor * (sum(flat) / len(flat))
                  if flat else 1.0)

        rows: list[tuple] = []
        weights: list[np.ndarray] = []
        for i, sid in enumerate(ready):
            raw = fs.raw[i]
            if fs.constrained[i]:
                if np.all(raw <= NEG / 2):
                    continue
                best = raw[raw > NEG / 2].max()
                w0 = np.where(raw > NEG / 2, margin + raw - best, NEG)
            else:                       # no eligibility holes: fast path
                best = raw.max()
                w0 = margin + raw - best
            solo_best = float(np.min(fs.eft[i]))
            rows.append((sid, 0))
            weights.append(w0)
            for k in range(1, fs.max_slots[i]):
                w = fs.shard_weights(i, k, solo_best)
                if fs.constrained[i] and np.all(w <= NEG / 2):
                    continue
                rows.append((sid, k))
                weights.append(w)
        if not rows:
            return []

        problem = FrontierProblem(rows, devices, np.array(weights))
        sol = solve_frontier_exact(problem, self.time_limit)
        self.solve_log.append(SolveRecord(
            wall_time=sol.wall_time, nodes=sol.nodes, status=sol.status,
            n_rows=len(rows), n_devices=len(devices),
            objective=sol.objective))
        return self._materialize(wf, state, cm, sol)

    # ------------------------------------------------------------------
    # scalar wave (seed reference path)
    # ------------------------------------------------------------------
    def _plan_wave(self, wf: Workflow, state: ExecutionState,
                   ready: list[str]) -> list[Placement]:
        """One CP-SAT wave over the current ready frontier."""
        if not ready:
            return []
        cm = CostModel(state)
        scorer = Scorer(state, cm, self.params)
        scorer.set_frontier(wf, ready)
        q = wf.num_queries
        devices = state.cluster.ids()

        # Regret-based wave scores: each stage's best placement scores a
        # small positive margin; alternatives score margin − regret and
        # may go negative, in which case the solver defers the stage to
        # a later wave (e.g. queueing behind a model-resident device
        # instead of paying a switch now).  The sum objective then
        # approximates completion-time impact rather than raw placement
        # count — the "balancing versus future-state preservation"
        # tradeoff of §1 is decided by the score terms.
        base_costs = [cm.base_cost(wf.stages[sid], d, q)
                      for sid in ready for d in devices]
        margin = (self.params.margin_factor
                  * (sum(base_costs) / len(base_costs))
                  if base_costs else 1.0)

        rows: list[tuple] = []
        weights: list[np.ndarray] = []
        for sid in ready:
            stage = wf.stages[sid]
            eligible = set(stage.eligible) if stage.eligible else None
            max_slots = (stage.max_shards if self.params.enable_shard
                         else 1)
            raw = np.full(len(devices), NEG)
            efts = np.full(len(devices), np.inf)
            for j, d in enumerate(devices):
                if eligible is not None and d not in eligible:
                    continue
                raw[j] = scorer.planner_score(wf, stage, 0, d, 0.0)
                efts[j] = scorer.corrected_eft(wf, stage, d)
            if np.all(raw <= NEG / 2):
                continue
            best = raw[raw > NEG / 2].max()
            solo_best = float(np.min(efts))
            w0 = np.where(raw > NEG / 2, margin + raw - best, NEG)
            rows.append((sid, 0))
            weights.append(w0)
            for k in range(1, max_slots):
                w = np.full(len(devices), NEG)
                for j, d in enumerate(devices):
                    if eligible is not None and d not in eligible:
                        continue
                    w[j] = scorer.planner_score(wf, stage, k, d, 0.0,
                                                solo_best=solo_best)
                if np.all(w <= NEG / 2):
                    continue
                rows.append((sid, k))
                weights.append(w)
        if not rows:
            return []

        problem = FrontierProblem(rows, devices, np.array(weights))
        sol = solve_frontier_exact(problem, self.time_limit)
        self.solve_log.append(SolveRecord(
            wall_time=sol.wall_time, nodes=sol.nodes, status=sol.status,
            n_rows=len(rows), n_devices=len(devices),
            objective=sol.objective))
        return self._materialize(wf, state, cm, sol)

    def _materialize(self, wf: Workflow, state: ExecutionState,
                     cm: CostModel, sol: FrontierSolution
                     ) -> list[Placement]:
        by_stage: dict[str, dict[int, int]] = {}
        for (sid, slot), dev in sol.assignment.items():
            by_stage.setdefault(sid, {})[slot] = dev
        out: list[Placement] = []
        for sid, slots in by_stage.items():
            if 0 not in slots:     # primary slot missing: drop (solver
                continue           # guarantees monotonicity, belt&braces)
            devs = tuple(slots[k] for k in sorted(slots))
            speeds = [state.cluster.devices[d].speed for d in devs]
            sizes = tuple(shard_partition(wf.num_queries, speeds))
            out.append(Placement(wid=wf.wid, sid=sid, devices=devs,
                                 shard_sizes=sizes, score=sol.objective,
                                 planned_at=state.now))
        return out


def _simulate_copy(state: ExecutionState) -> ExecutionState:
    """Cheap planning copy of the execution state (dict-level)."""
    import copy
    sim = ExecutionState(
        cluster=state.cluster, profiles=state.profiles,
        residency=dict(state.residency),
        prefix={d: {g: copy.copy(e) for g, e in m.items()}
                for d, m in state.prefix.items()},
        output_loc=dict(state.output_loc),
        free_at=dict(state.free_at), now=state.now)
    sim.completed = set(state.completed)
    return sim


def _apply_estimate(wf: Workflow, sim: ExecutionState, p: Placement,
                    cm: Optional[CostModel] = None) -> None:
    """Advance the simulated state by a placement's estimated effects."""
    if cm is None:
        cm = CostModel(sim)
    st = wf.stages[p.sid]
    fins = []
    for d, nq in zip(p.devices, p.shard_sizes):
        t0 = max(sim.now, sim.device_free(d))
        dur = max(1e-6, cm.breakdown(wf, st, d, nq).total)
        sim.free_at[d] = t0 + dur
        sim.residency[d] = st.model
        if st.keep_cache:
            sim.warm_prefix(d, st.prefix_group, st.model, nq, t0 + dur)
        fins.append(t0 + dur)
    sim.output_loc[(wf.wid, p.sid)] = p.devices
    sim.completed.add((wf.wid, p.sid))
