"""Unified event-driven scheduler core: typed config, explicit
lifecycle, and a first-class event stream.

This module is the single runtime behind both execution settings:

* :class:`Scheduler` — the event core.  Workflows are ``submit()``-ed
  (immediately or at a future arrival time), the clock advances one
  event batch per ``step()`` (or ``run_until(t)`` / ``drain()``), and
  every control-plane and data-plane transition is emitted as a typed,
  replayable event (:class:`ArrivalEvent` → :class:`AdmittedEvent` /
  :class:`DeferredEvent` / :class:`RejectedEvent`,
  :class:`PlacementEvent` → :class:`IssueEvent` →
  :class:`CompletionEvent`, plus :class:`PreemptionEvent`) consumable
  via iteration (:meth:`Scheduler.stream`) or
  :meth:`Scheduler.on` subscriptions.
* :class:`SchedulerConfig` — one frozen, JSON-round-trippable object
  collapsing every knob that used to be threaded per-call through the
  executors and ``workflowbench.runner`` (score params, SLO config,
  cost params, an embedded calibration profile, planner switches).
  ``SchedulerConfig.from_json(cfg.to_json()) == cfg``, so any run is
  reproducible from a single artifact (CI archives the config used
  for the gated benchmark runs).

The commit-and-advance mechanics (paper Algorithm 2) are unchanged:
policies commit :class:`~repro.core.planner.Placement`s into a pool,
the core issues dependency-ready actions as devices free, updates
(ρ, κ, ℓ, τ) on completion, and replans when the pool cannot cover
the ready frontier.  :class:`~repro.core.executor.WorkflowExecutor`
and :class:`~repro.core.executor.ServingExecutor` are now thin
adapters over this loop; the ``batch`` flag reproduces the
single-workflow batch runtime's exact semantics (per-workflow
``plan()`` dispatch, unconditional greedy fallback, persistent commit
pool, one completion per clock advance) so placements stay
bit-identical to the historical executors in both settings.

Per-query completion times are tracked through shard partitions so
P95 query latency is measurable (queries in different shards of the
sink stage finish at different times).
"""
from __future__ import annotations

import bisect
import dataclasses
import heapq
import json
from pathlib import Path
from typing import Callable, Iterator, Mapping, Optional, Sequence

from repro.core.admission import AdmissionController, SLOConfig
from repro.core.calibration import CalibrationProfile
from repro.core.costs import CostModel, CostParams
from repro.core.devices import Cluster, Device
from repro.core.faults import DeviceHealth, FaultInjector, FaultPlan
from repro.core.journal import EventJournal, JournalError
from repro.core.planner import Placement
from repro.core.routing import RoutingConfig, StageRouter
from repro.core.scoring import ScoreParams
from repro.core.state import ExecutionState
from repro.core.workflow import Stage, StageKey, Workflow

#: Schema version of :meth:`SchedulerConfig.to_json` documents.
CONFIG_VERSION = 1

#: Schema version of :meth:`SchedulerEvent.to_dict` documents.
EVENT_SCHEMA_VERSION = 1

#: Schema version of :meth:`Scheduler.snapshot` documents.
SNAPSHOT_VERSION = 1

#: Keep queued workflow arrivals on their own heap (``submit`` pushes
#: there) so the in-flight event heap — which ``_kill_run`` and the
#: invariant audit scan linearly — stays proportional to running work
#: even with 100k future arrivals enqueued.  Entries share the
#: ``(t, prio, seq)`` prefix, so popping the smaller head of the two
#: heaps reproduces the exact single-heap order (bit-identical event
#: streams; ``tests/test_arrival_queue.py`` flips this off to assert
#: it).
_SPLIT_ARRIVALS = True


class RecoveryError(RuntimeError):
    """Deterministic replay diverged from the journal: the regenerated
    event stream does not match what the pre-crash scheduler logged
    (or the journal tail extends past the restored run's quiescence).
    Either the snapshot/journal pair is mismatched or determinism was
    broken — the restored state cannot be trusted."""


def nearest_rank_p95(xs: Sequence[float],
                     default: float = float("nan")) -> float:
    """Nearest-rank 95th percentile of ``xs`` (``default`` if empty).

    The single percentile convention shared by batch results, serving
    stats, and the benchmark metrics — keep them in sync by calling
    this, not by re-deriving the index.
    """
    s = sorted(xs)
    if not s:
        return default
    idx = max(0, min(len(s) - 1, int(round(0.95 * (len(s) - 1)))))
    return s[idx]


def fresh_state(cluster, profiles=None) -> ExecutionState:
    """Empty execution state over ``cluster`` (cold devices, t=0),
    with the paper's default model profiles unless overridden."""
    from repro.core.workflow import DEFAULT_PROFILES
    return ExecutionState(cluster=cluster,
                          profiles=dict(profiles or DEFAULT_PROFILES))


# ---------------------------------------------------------------------------
# typed configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Complete, serializable configuration of one scheduler run.

    Collapses the knobs that used to be scattered across
    ``make_policy(**policy_kwargs)``, the two executor constructors,
    and the ``run_one``/``run_suite``/``run_serving`` signatures into
    one frozen object:

    * ``policy`` — registered policy name
      (:data:`repro.core.policies.POLICY_REGISTRY`);
    * ``policy_kwargs`` — extra constructor overrides for the policy
      (kept for back-compat and for policy-specific knobs like Halo's
      ``beam_width``; entries override the typed fields below);
    * ``score`` — :class:`~repro.core.scoring.ScoreParams` (λ weights,
      horizon, margin);
    * ``cost`` — :class:`~repro.core.costs.CostParams` global scales
      (``None`` = hand-set defaults);
    * ``slo`` — :class:`~repro.core.admission.SLOConfig`; ``None``
      disables the admission/deferral/preemption control plane;
    * ``calibration`` — an embedded
      :class:`~repro.core.calibration.CalibrationProfile`; when set,
      the execution state's model profiles AND the effective cost
      params are lowered from it (single source of truth), exactly as
      the runner's ``calibration=`` argument did;
    * ``time_limit`` / ``use_matrix`` / ``use_delta`` / ``warm_start``
      / ``max_waves`` — planner switches (see
      :class:`~repro.core.planner.FrontierPlanner`);
    * ``replan_on_completion`` — revoke unissued commitments on every
      completion batch (the serving replan trigger);
    * ``faults`` — a :class:`~repro.core.faults.FaultPlan` driving
      deterministic fault injection (device crashes, transient shard
      failures, slowdown/straggler episodes) plus the retry /
      quarantine / speculation recovery knobs; ``None`` (default)
      disables the fault machinery entirely and an EMPTY plan arms it
      without injecting anything — both are bit-identical to the
      fault-free scheduler (serving mode only; ignored by ``batch``);
    * ``event_buffer`` — ring-buffer cap on the retained event stream
      (``None`` = unbounded); long-running serving deployments set a
      cap so :attr:`Scheduler.events` cannot grow without bound;
    * ``pools`` — hierarchical sharded frontier solve: partition the
      merged ready frontier into this many residency-aware device
      pools and solve each pool exactly, combining the disjoint
      per-pool solutions (``1`` = the monolithic solve; the string
      ``"auto"`` derives the count per wave from device count and
      frontier width — see
      :class:`~repro.core.planner.FrontierPlanner`);
    * ``batch_probes`` — admission probes of simultaneous arrivals in
      one event batch share a single delta-rescored lookahead wave
      (see :meth:`~repro.core.admission.AdmissionController
      .probe_batch`) instead of running one solve per arrival;
    * ``routing`` — a :class:`~repro.core.routing.RoutingConfig`
      enabling cost/quality model-family routing: stages declaring
      ``candidates`` may be served by an alternate family that clears
      the quality floor (``None``, the default, is bit-identical to
      the unrouted planner);
    * ``gateway`` — plain-dict knobs for the HTTP serving gateway
      (``serving/gateway.py``: ``replicas``, ``host``, ``port``);
      inert to the scheduler core itself, carried here so one JSON
      artifact reproduces a served deployment.

    ``to_json``/``from_json`` round-trip the whole object — including
    the embedded calibration profile — so a benchmark gate can be
    reproduced from a single JSON artifact
    (``benchmarks/sched_bench.py --config``).
    """

    policy: str = "FATE"
    policy_kwargs: Mapping = dataclasses.field(default_factory=dict)
    score: ScoreParams = ScoreParams()
    cost: Optional[CostParams] = None
    slo: Optional[SLOConfig] = None
    calibration: Optional[CalibrationProfile] = None
    time_limit: float = 5.0
    use_matrix: bool = True
    use_delta: bool = True
    warm_start: bool = True
    max_waves: Optional[int] = None
    replan_on_completion: bool = True
    faults: Optional[FaultPlan] = None
    event_buffer: Optional[int] = None
    pools: "int | str" = 1
    batch_probes: bool = False
    routing: Optional[RoutingConfig] = None
    gateway: Optional[Mapping] = None

    # -- lowering --------------------------------------------------------
    def effective_cost_params(self) -> Optional[CostParams]:
        """The :class:`CostParams` every consumer should price with:
        ``cost`` with the calibration profile's fitted scales applied
        over it when a profile is embedded, else ``cost`` verbatim."""
        if self.calibration is None:
            return self.cost
        return self.calibration.cost_params(self.cost)

    def model_profiles(self) -> Optional[dict]:
        """Per-model profile dict for ``fresh_state`` (``None`` keeps
        the hand-set defaults) — the calibration profile's fitted
        constants when one is embedded."""
        if self.calibration is None:
            return None
        return self.calibration.model_profiles()

    def build_policy(self):
        """Instantiate the configured policy from the registry.

        Dispatches through the policy class's ``from_config`` hook
        (see :class:`~repro.core.policies.BasePolicy`), passing the
        calibration-lowered cost params; unknown names raise the
        registry's listing ``KeyError``.
        """
        from repro.core.policies import POLICY_REGISTRY, make_policy
        if self.policy not in POLICY_REGISTRY:
            make_policy(self.policy)        # raises the listing KeyError
        cls = POLICY_REGISTRY[self.policy]
        if hasattr(cls, "from_config"):
            return cls.from_config(self,
                                   cost_params=self.effective_cost_params())
        return cls(**dict(self.policy_kwargs))

    # -- serialization ---------------------------------------------------
    def to_json(self) -> str:
        """Serialize to a versioned JSON document (exact inverse of
        :meth:`from_json`, including the embedded calibration
        profile)."""
        doc = {
            "config_version": CONFIG_VERSION,
            "policy": self.policy,
            "policy_kwargs": dict(self.policy_kwargs),
            "score": dataclasses.asdict(self.score),
            "cost": (dataclasses.asdict(self.cost)
                     if self.cost is not None else None),
            "slo": (dataclasses.asdict(self.slo)
                    if self.slo is not None else None),
            "calibration": (json.loads(self.calibration.to_json())
                            if self.calibration is not None else None),
            "time_limit": self.time_limit,
            "use_matrix": self.use_matrix,
            "use_delta": self.use_delta,
            "warm_start": self.warm_start,
            "max_waves": self.max_waves,
            "replan_on_completion": self.replan_on_completion,
            "faults": (self.faults.to_dict()
                       if self.faults is not None else None),
            "event_buffer": self.event_buffer,
            "pools": self.pools,
            "batch_probes": self.batch_probes,
            "routing": (self.routing.to_dict()
                        if self.routing is not None else None),
            "gateway": (dict(self.gateway)
                        if self.gateway is not None else None),
        }
        return json.dumps(doc, indent=2, sort_keys=True) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "SchedulerConfig":
        """Rebuild a config from :meth:`to_json` output; rejects
        unknown schema versions."""
        doc = json.loads(text)
        version = int(doc.get("config_version", -1))
        if version != CONFIG_VERSION:
            raise ValueError(
                f"unsupported SchedulerConfig version {version} "
                f"(expected {CONFIG_VERSION})")
        cal = doc.get("calibration")
        pools = doc.get("pools", 1)
        if pools != "auto":
            pools = int(pools)
        return cls(
            policy=doc.get("policy", "FATE"),
            policy_kwargs=dict(doc.get("policy_kwargs") or {}),
            score=ScoreParams(**(doc.get("score") or {})),
            cost=(CostParams(**doc["cost"])
                  if doc.get("cost") is not None else None),
            slo=(SLOConfig(**doc["slo"])
                 if doc.get("slo") is not None else None),
            calibration=(CalibrationProfile.from_json(json.dumps(cal))
                         if cal is not None else None),
            time_limit=float(doc.get("time_limit", 5.0)),
            use_matrix=bool(doc.get("use_matrix", True)),
            use_delta=bool(doc.get("use_delta", True)),
            warm_start=bool(doc.get("warm_start", True)),
            max_waves=doc.get("max_waves"),
            replan_on_completion=bool(
                doc.get("replan_on_completion", True)),
            faults=(FaultPlan.from_dict(doc["faults"])
                    if doc.get("faults") is not None else None),
            event_buffer=doc.get("event_buffer"),
            pools=pools,
            batch_probes=bool(doc.get("batch_probes", False)),
            # pre-gateway documents have neither key: legacy configs
            # load with routing/gateway disabled, unchanged otherwise
            routing=(RoutingConfig.from_dict(doc["routing"])
                     if doc.get("routing") is not None else None),
            gateway=(dict(doc["gateway"])
                     if doc.get("gateway") is not None else None),
        )

    def save(self, path) -> Path:
        """Write :meth:`to_json` to ``path``; returns the path."""
        path = Path(path)
        path.write_text(self.to_json())
        return path

    @classmethod
    def load(cls, path) -> "SchedulerConfig":
        """Read a config previously written by :meth:`save`."""
        return cls.from_json(Path(path).read_text())


# ---------------------------------------------------------------------------
# event taxonomy
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SchedulerEvent:
    """Base of every record on the scheduler's replayable event
    stream; ``t`` is the simulation time the event occurred at.

    Every concrete subclass is registered in :data:`EVENT_REGISTRY`
    and round-trips through :meth:`to_dict`/:meth:`from_dict` — the
    serialization contract the write-ahead
    :class:`~repro.core.journal.EventJournal` depends on.
    """
    t: float

    def to_dict(self) -> dict:
        """Versioned plain-JSON document: the event's class name under
        ``"type"``, :data:`EVENT_SCHEMA_VERSION` under
        ``"event_version"``, and every dataclass field (tuples become
        lists).  Exact inverse of :meth:`from_dict`."""
        doc = {"event_version": EVENT_SCHEMA_VERSION,
               "type": type(self).__name__}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            doc[f.name] = list(v) if isinstance(v, tuple) else v
        return doc

    @staticmethod
    def from_dict(doc: Mapping) -> "SchedulerEvent":
        """Rebuild the concrete event from a :meth:`to_dict` document.

        Raises ``ValueError`` on an unknown ``"type"`` (not in
        :data:`EVENT_REGISTRY`) or a schema version other than
        :data:`EVENT_SCHEMA_VERSION` — a journal written by a future
        schema must be rejected, not half-parsed.  Unknown extra keys
        (e.g. the journal's ``"i"`` index tag) are ignored; list
        values are coerced back to the tuples the dataclasses carry.
        """
        version = int(doc.get("event_version", -1))
        if version != EVENT_SCHEMA_VERSION:
            raise ValueError(
                f"unsupported event schema version {version} "
                f"(expected {EVENT_SCHEMA_VERSION})")
        name = doc.get("type")
        cls = EVENT_REGISTRY.get(name)
        if cls is None:
            raise ValueError(
                f"unknown event type {name!r} "
                f"(registered: {sorted(EVENT_REGISTRY)})")
        kwargs = {}
        for f in dataclasses.fields(cls):
            if f.name in doc:
                v = doc[f.name]
                kwargs[f.name] = tuple(v) if isinstance(v, list) else v
        return cls(**kwargs)


@dataclasses.dataclass(frozen=True)
class ArrivalEvent(SchedulerEvent):
    """A submitted workflow's arrival time was reached (emitted
    before any admission decision)."""
    wid: str


@dataclasses.dataclass(frozen=True)
class AdmittedEvent(SchedulerEvent):
    """A workflow entered the shared frontier.  ``arrival`` is the
    ORIGINAL submission arrival (earlier than ``t`` for workflows that
    waited in the admission backlog); ``deadline`` is absolute sim
    time or ``None`` without an SLO."""
    wid: str
    arrival: float
    deadline: Optional[float] = None
    klass: str = "default"


@dataclasses.dataclass(frozen=True)
class DeferredEvent(SchedulerEvent):
    """The admission probe predicted an SLO miss: the arrival was
    parked in the bounded backlog for later re-admission."""
    wid: str
    predicted_latency: float
    deadline: float


@dataclasses.dataclass(frozen=True)
class RejectedEvent(SchedulerEvent):
    """The workflow was shed and will never execute (``reason`` is
    ``"admission"`` for arrival-time rejections, ``"expired"`` for
    backlog entries whose deadline became unreachable)."""
    wid: str
    reason: str = "admission"


@dataclasses.dataclass(frozen=True)
class PlacementEvent(SchedulerEvent):
    """The policy committed a placement into the action pool (not yet
    running — a later replan or preemption may still revoke it)."""
    wid: str
    sid: str
    devices: tuple
    shard_sizes: tuple


@dataclasses.dataclass(frozen=True)
class IssueEvent(SchedulerEvent):
    """A committed placement started executing: device state (ρ, κ, τ)
    was mutated and the stage now finishes at ``finish``."""
    wid: str
    sid: str
    devices: tuple
    start: float
    finish: float


@dataclasses.dataclass(frozen=True)
class PreemptionEvent(SchedulerEvent):
    """An SLO-tight admission revoked the committed-but-unissued pool
    (``n_revoked`` placements return to the next merged solve)."""
    trigger_wid: str
    n_revoked: int


@dataclasses.dataclass(frozen=True)
class ShardPreemptionEvent(SchedulerEvent):
    """An ISSUED-and-running stage attempt was killed to reclaim its
    devices for a higher-class admission (kill/replay semantics).

    The attempt's run token was revoked — its pending finish/fail/
    timeout heap events (including speculative copies, which share the
    token) are now stale — its exclusively-held devices were freed at
    the preemption instant, its warm-prefix state was forfeited
    (partial τ/κ credit-back through the dirty-device protocol; the
    residency ρ it loaded is real and stays), and the stage returns to
    the ready frontier after a short holdoff so the trigger's replan
    claims the freed devices first.  ``devices`` is the killed
    attempt's primary placement; ``klass``/``trigger_klass`` are the
    victim's and the trigger's admission classes."""
    wid: str
    sid: str
    devices: tuple
    trigger_wid: str
    klass: str = "default"
    trigger_klass: str = "default"


@dataclasses.dataclass(frozen=True)
class CompletionEvent(SchedulerEvent):
    """A stage finished; ``workflow_done`` marks its workflow's last
    stage (the workflow retired from the frontier)."""
    wid: str
    sid: str
    workflow_done: bool = False


@dataclasses.dataclass(frozen=True)
class DeviceDownEvent(SchedulerEvent):
    """A device left the live set (``reason``: ``"crash"`` fail-stop —
    its residency/prefix/queue state was wiped — or ``"quarantine"``
    after repeated transient failures, state kept warm).
    ``recover_at`` is the scheduled rejoin time when known;
    ``n_revoked`` counts committed-but-unissued placements on the
    device that were revoked back into the merged solve."""
    device: int
    reason: str = "crash"
    recover_at: Optional[float] = None
    n_revoked: int = 0


@dataclasses.dataclass(frozen=True)
class DeviceRecoveredEvent(SchedulerEvent):
    """A downed device rejoined the live set (cold after a crash,
    warm after a quarantine)."""
    device: int


@dataclasses.dataclass(frozen=True)
class ShardFailedEvent(SchedulerEvent):
    """An issued stage execution failed before completing (``reason``:
    ``"transient"`` injected shard failure, or ``"device_down"`` when
    a device crashed mid-run).  ``attempt`` is the 0-based attempt
    index that failed; the stage re-enters the frontier after
    backoff."""
    wid: str
    sid: str
    devices: tuple
    reason: str = "transient"
    attempt: int = 0


@dataclasses.dataclass(frozen=True)
class RetryEvent(SchedulerEvent):
    """A failed stage's backoff expired: attempt ``attempt`` is now
    eligible for replanning (``backoff`` seconds after the failure)."""
    wid: str
    sid: str
    attempt: int
    backoff: float


@dataclasses.dataclass(frozen=True)
class DegradedEvent(SchedulerEvent):
    """Graceful-degradation marker.  ``kind="straggler"``: an issued
    stage blew past its timeout and (when enabled) a speculative copy
    was re-issued on the best alternate device; ``kind="gave_up"``: a
    stage exhausted its retry budget and its workflow was failed out
    of the frontier."""
    kind: str
    wid: Optional[str] = None
    sid: Optional[str] = None
    device: Optional[int] = None


#: Every concrete event type, in lifecycle order (docs/tests anchor).
EVENT_TYPES = (ArrivalEvent, AdmittedEvent, DeferredEvent,
               RejectedEvent, PlacementEvent, IssueEvent,
               PreemptionEvent, ShardPreemptionEvent, CompletionEvent,
               DeviceDownEvent, DeviceRecoveredEvent, ShardFailedEvent,
               RetryEvent, DegradedEvent)

#: Type registry ``SchedulerEvent.from_dict`` dispatches through —
#: class name -> class, one entry per :data:`EVENT_TYPES` member.
EVENT_REGISTRY: dict[str, type] = {cls.__name__: cls
                                   for cls in EVENT_TYPES}


class EventLog:
    """Append-only event buffer with an optional ring cap.

    List-like for reads: ``len`` / iteration / indexing cover the
    RETAINED window (everything, when ``maxlen`` is ``None``), and
    equality compares against any iterable of events.  With a cap, the
    oldest events are dropped as new ones arrive; ``n_total`` counts
    every event ever appended and ``n_dropped`` how many fell off the
    ring, so :meth:`Scheduler.stream` can keep yielding from absolute
    positions while the window slides.
    """

    def __init__(self, maxlen: Optional[int] = None):
        if maxlen is not None and maxlen < 1:
            raise ValueError(f"event_buffer must be >= 1, got {maxlen}")
        self.maxlen = maxlen
        self.n_total = 0
        self.n_dropped = 0
        self._items: list[SchedulerEvent] = []

    def append(self, ev: SchedulerEvent) -> None:
        """Append one event, evicting the oldest past the cap."""
        self._items.append(ev)
        self.n_total += 1
        if self.maxlen is not None and len(self._items) > self.maxlen:
            drop = len(self._items) - self.maxlen
            del self._items[:drop]
            self.n_dropped += drop

    def since(self, n: int) -> list:
        """Retained events with absolute index ``>= n``, oldest first.

        ``n`` is an ABSOLUTE stream position in ``[0, n_total]``:
        ``since(0)`` is the whole retained window, ``since(n_total)``
        is empty (the next event lands there).  Positions the ring has
        already evicted (``n < n_dropped``) are legal — the evicted
        prefix is silently absent, which is the wraparound contract
        :meth:`Scheduler.stream` relies on across window slides.
        Out-of-range positions raise ``ValueError``: a negative ``n``
        or one beyond ``n_total`` is a cursor-bookkeeping bug at the
        caller, not a readable position.
        """
        if n < 0:
            raise ValueError(
                f"absolute event index must be >= 0, got {n}")
        if n > self.n_total:
            raise ValueError(
                f"absolute event index {n} is past the end of the "
                f"stream (n_total={self.n_total})")
        return self._items[max(0, n - self.n_dropped):]

    def __len__(self) -> int:
        return len(self._items)

    def __iter__(self):
        return iter(self._items)

    def __getitem__(self, i):
        return self._items[i]

    def __eq__(self, other) -> bool:
        if isinstance(other, EventLog):
            return self._items == other._items
        try:
            return self._items == list(other)
        except TypeError:
            return NotImplemented

    def __repr__(self) -> str:
        cap = "" if self.maxlen is None else f", maxlen={self.maxlen}"
        return (f"EventLog(n={len(self._items)}, "
                f"total={self.n_total}{cap})")


# ---------------------------------------------------------------------------
# snapshot serialization helpers (plain-JSON codecs for the run-state
# structures Scheduler.snapshot()/restore() round-trip)
# ---------------------------------------------------------------------------


def _placement_doc(p: Placement) -> dict:
    doc = {"wid": p.wid, "sid": p.sid, "devices": list(p.devices),
           "shard_sizes": list(p.shard_sizes), "score": p.score,
           "planned_at": p.planned_at}
    if p.model is not None:
        # only routed placements carry the key, so unrouted snapshots
        # stay byte-identical to pre-routing documents
        doc["model"] = p.model
    return doc


def _placement_from_doc(doc: Mapping) -> Placement:
    return Placement(doc["wid"], doc["sid"], tuple(doc["devices"]),
                     tuple(doc["shard_sizes"]),
                     score=doc.get("score", 0.0),
                     planned_at=doc.get("planned_at", 0.0),
                     model=doc.get("model"))


def _stagerun_doc(run: "StageRun") -> dict:
    return {"placement": _placement_doc(run.placement),
            "start": run.start, "finish": run.finish,
            "shard_finish": list(run.shard_finish),
            "switched": list(run.switched)}


def _stagerun_from_doc(doc: Mapping) -> "StageRun":
    return StageRun(_placement_from_doc(doc["placement"]),
                    doc["start"], doc["finish"],
                    tuple(doc["shard_finish"]),
                    tuple(bool(s) for s in doc["switched"]))


def _heap_entry_doc(entry: tuple) -> dict:
    """Serialize one pending heap entry ``(t, prio, seq, kind,
    payload)``; arrival payloads are stored by wid (the workflow
    itself lives in the snapshot's workflow registry)."""
    t, prio, seq, kind, payload = entry
    doc = {"t": t, "prio": prio, "seq": seq, "kind": kind}
    if kind == "arrive":
        doc["wid"] = payload.wid
    elif kind in ("finish", "fail"):
        key, token, run = payload
        doc.update(key=list(key), token=token,
                   run=_stagerun_doc(run))
    elif kind == "retry":
        key, attempt, backoff = payload
        doc.update(key=list(key), attempt=attempt, backoff=backoff)
    elif kind == "timeout":
        key, token = payload
        doc.update(key=list(key), token=token)
    elif kind == "release":
        doc["key"] = list(payload)
    elif kind == "crash":
        doc["crash"] = dataclasses.asdict(payload)
    elif kind == "recover":
        doc["device"] = payload
    else:                                # pragma: no cover
        raise ValueError(f"unknown heap event kind {kind!r}")
    return doc


def _heap_entry_from_doc(doc: Mapping,
                         workflows: Mapping[str, "Workflow"]) -> tuple:
    """Inverse of :func:`_heap_entry_doc` (arrival workflows resolved
    through the snapshot's registry)."""
    from repro.core.faults import DeviceCrash
    kind = doc["kind"]
    if kind == "arrive":
        payload = workflows[doc["wid"]]
    elif kind in ("finish", "fail"):
        payload = (tuple(doc["key"]), doc["token"],
                   _stagerun_from_doc(doc["run"]))
    elif kind == "retry":
        payload = (tuple(doc["key"]), doc["attempt"], doc["backoff"])
    elif kind == "timeout":
        payload = (tuple(doc["key"]), doc["token"])
    elif kind == "release":
        payload = tuple(doc["key"])
    elif kind == "crash":
        payload = DeviceCrash(**doc["crash"])
    elif kind == "recover":
        payload = doc["device"]
    else:
        raise ValueError(f"unknown heap event kind {kind!r}")
    return (doc["t"], doc["prio"], doc["seq"], kind, payload)


def _keyed_dict_doc(d: Mapping) -> list:
    """``{(wid, sid): value}`` -> ``[[wid, sid, value], ...]`` in
    insertion order (JSON objects cannot key on tuples)."""
    return [[wid, sid, v] for (wid, sid), v in d.items()]


def _keyed_dict_from_doc(rows, value=lambda v: v) -> dict:
    return {(wid, sid): value(v) for wid, sid, v in rows}


# ---------------------------------------------------------------------------
# shared issue/completion machinery
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StageRun:
    """One issued stage execution: its placement and timing record."""
    placement: Placement
    start: float
    finish: float                       # max over shards
    shard_finish: tuple[float, ...]
    switched: tuple[bool, ...]


@dataclasses.dataclass
class RunResult:
    """Outcome of one single-workflow batch run (paper Table 1 row)."""
    wid: str
    makespan: float
    query_completion: list[float]       # per query
    stage_runs: dict[str, StageRun]
    # mechanism proxies (Appendix C.2), per workflow
    cross_device_edges: int
    prefix_hits_est: float
    same_model_continuations: float
    total_tasks: int
    model_switches: int

    @property
    def p95(self) -> float:
        """95th-percentile per-query completion time (nearest-rank)."""
        return nearest_rank_p95(self.query_completion,
                                default=self.makespan)


def _greedy_fallback(state: ExecutionState, cm: CostModel, wf: Workflow,
                     sid: str) -> Optional[Placement]:
    """Liveness fallback shared by both runtimes: place one ready stage
    on the LIVE device minimizing state-corrected cost plus queueing
    (``None`` when every eligible device is down — the caller waits on
    a pending recovery event instead)."""
    st = wf.stages[sid]
    devs = list(st.eligible) if st.eligible else state.cluster.ids()
    if state.down:
        devs = [d for d in devs if d not in state.down]
        if not devs:
            return None
    best = min(devs, key=lambda d: (
        cm.effective_cost(wf, st, d, wf.num_queries)
        + state.wait_time(d)))
    return Placement(wf.wid, sid, (best,), (wf.num_queries,))


def _issue_shards(state: ExecutionState, cm: CostModel, wf: Workflow,
                  st: Stage, p: Placement,
                  slow: Optional[dict] = None,
                  fail_frac: Optional[float] = None
                  ) -> tuple[list[float], list[bool], list[float]]:
    """Start one placement's shards: per-device state-corrected duration
    (base + switch + transfer − prefix − locality, plus coordination
    overhead when sharded), applied to (ρ, κ, τ) through the dirty-set
    mutators.  The single duration model shared by both runtimes.

    Fault hooks (both ``None`` on the fault-free path, which is then
    bit-identical to the historical behavior): ``slow`` maps devices to
    slowdown factors the ACTUAL execution suffers (the scheduler's
    belief — the third returned list — stays unslowed, which is what
    straggler detection keys off); ``fail_frac`` truncates the attempt
    at that fraction of its actual duration (the failure instant) and
    suppresses prefix warming — a failed attempt produces no reusable
    cache state.
    """
    shard_fin: list[float] = []
    switched: list[bool] = []
    believed: list[float] = []
    for d, nq in zip(p.devices, p.shard_sizes):
        was_resident = state.is_resident(st.model, d)
        t0 = max(state.now, state.device_free(d))
        dur = cm.base_cost(st, d, nq)
        dur += cm.switch_cost(st, d)
        dur += cm.transfer_cost(wf, st, d, nq)
        dur -= cm.prefix_benefit(st, d, nq)
        dur -= cm.locality_benefit(wf, st, d, nq)
        if len(p.devices) > 1:
            dur += (cm.base_cost(st, d, wf.num_queries)
                    * cm.p.shard_overhead)
        dur = max(dur, 1e-6)
        believed.append(t0 + dur)
        if slow is not None:
            dur *= slow.get(d, 1.0)
        if fail_frac is not None:
            dur = max(dur * fail_frac, 1e-6)
        fin = t0 + dur
        state.set_free_at(d, fin)
        state.set_resident(d, st.model)
        if st.keep_cache and fail_frac is None:
            state.warm_prefix(d, st.prefix_group, st.model, nq, fin)
        shard_fin.append(fin)
        switched.append(not was_resident)
    return shard_fin, switched, believed


# ---------------------------------------------------------------------------
# multi-workflow frontier + serving stats
# ---------------------------------------------------------------------------


class SharedFrontier:
    """Merged ready frontier across in-flight workflow DAGs.

    Tracks, per admitted workflow, which stages have completed and
    exposes one ``(wid, sid)``-keyed ready list spanning every active
    DAG — the planning unit of the serving setting.  Workflows are
    iterated in admission order and stages in topological order, so the
    merged list is deterministic; the planner (not this container)
    decides how cross-workflow contention is resolved.  A workflow is
    retired automatically once its last stage completes.

    The ready set is INDEXED: per workflow, a topo-sorted list of
    dependency-ready stages plus unmet-parent counters, maintained
    incrementally on admit/complete/retire, so :meth:`ready` costs
    O(ready + in-flight) instead of re-walking every DAG
    (O(total stages)) per call — the dominant scan at 1k-workflow
    scale.  ``version`` increments on every mutation; admission-probe
    memos key on it.  :meth:`ready_reference` keeps the brute-force
    walk for audits and tests.
    """

    def __init__(self) -> None:
        self.workflows: dict[str, Workflow] = {}
        self.completed: dict[str, set[str]] = {}
        #: mutation counter (admit/complete/retire); cache key for
        #: derived views (admission-probe memos, planner partitions)
        self.version = 0
        # per-wid ready index: sorted (topo_pos, sid) pairs of
        # dependency-ready not-yet-completed stages, the unmet-parent
        # counts behind them, the topo position map, and the workflow
        # generation the index was built against (topology mutation
        # via Workflow.invalidate_topology forces a rebuild)
        self._ready: dict[str, list[tuple[int, str]]] = {}
        self._unmet: dict[str, dict[str, int]] = {}
        self._topo_pos: dict[str, dict[str, int]] = {}
        self._gen: dict[str, int] = {}

    @property
    def _order(self) -> list[str]:
        """Admission-ordered workflow ids (the dict insertion order is
        the admission order — kept as a view so retiring a workflow is
        O(1) instead of a list scan)."""
        return list(self.workflows)

    def _index_workflow(self, wid: str) -> None:
        """(Re)build one workflow's ready index from scratch."""
        wf = self.workflows[wid]
        done = self.completed[wid]
        pos = {sid: i for i, sid in enumerate(wf.topo_order)}
        unmet: dict[str, int] = {}
        ready: list[tuple[int, str]] = []
        for sid in wf.topo_order:
            if sid in done:
                continue
            n = sum(1 for p in wf.stages[sid].parents if p not in done)
            unmet[sid] = n
            if n == 0:
                ready.append((pos[sid], sid))
        self._topo_pos[wid] = pos
        self._unmet[wid] = unmet
        self._ready[wid] = ready
        self._gen[wid] = wf.generation

    def reindex(self) -> None:
        """Rebuild every workflow's ready index (snapshot restore)."""
        for wid in self.workflows:
            self._index_workflow(wid)

    def admit(self, wf: Workflow) -> None:
        """Add an in-flight workflow; its sources become ready."""
        if wf.wid in self.workflows:
            raise ValueError(f"duplicate workflow id {wf.wid}")
        wf.validate()
        self.workflows[wf.wid] = wf
        self.completed[wf.wid] = set()
        self._index_workflow(wf.wid)
        self.version += 1

    def complete(self, wid: str, sid: str) -> bool:
        """Record a stage completion; True if the workflow finished."""
        done = self.completed[wid]
        done.add(sid)
        self.version += 1
        wf = self.workflows[wid]
        if len(done) == len(wf.stages):
            self.retire(wid)
            return True
        if self._gen.get(wid) != wf.generation:
            self._index_workflow(wid)       # topology mutated: rebuild
            return False
        pos = self._topo_pos[wid]
        ready = self._ready[wid]
        unmet = self._unmet[wid]
        if unmet.pop(sid, 1) == 0:          # drop the completed stage
            i = bisect.bisect_left(ready, (pos[sid], sid))
            if i < len(ready) and ready[i] == (pos[sid], sid):
                del ready[i]
        for c in wf.stages[sid].children:
            n = unmet.get(c)
            if n is None:
                continue                    # child already completed
            unmet[c] = n - 1
            if n == 1:                      # became dependency-ready
                bisect.insort(ready, (pos[c], c))
        return False

    def retire(self, wid: str) -> None:
        """Drop a workflow (finished or evicted) from the frontier."""
        self.workflows.pop(wid, None)
        self.completed.pop(wid, None)
        self._ready.pop(wid, None)
        self._unmet.pop(wid, None)
        self._topo_pos.pop(wid, None)
        self._gen.pop(wid, None)
        self.version += 1

    def ready(self, exclude: set[StageKey]) -> list[StageKey]:
        """Merged dependency-ready, not-yet-claimed stage keys.

        Indexed: reads the per-workflow ready lists (admission order,
        topo order within a workflow — identical output to
        :meth:`ready_reference`, which the invariant audit asserts).
        """
        out: list[StageKey] = []
        for wid, wf in self.workflows.items():
            if self._gen.get(wid) != wf.generation:
                self._index_workflow(wid)
            for _pos, sid in self._ready[wid]:
                if (wid, sid) not in exclude:
                    out.append((wid, sid))
        return out

    def ready_reference(self, exclude: set[StageKey]) -> list[StageKey]:
        """Brute-force ready walk (the pre-index implementation),
        kept as the ground truth the indexed :meth:`ready` is audited
        against."""
        out: list[StageKey] = []
        for wid in self.workflows:
            wf = self.workflows[wid]
            done = self.completed[wid]
            for sid in wf.topo_order:
                if sid in done or (wid, sid) in exclude:
                    continue
                if all(p in done for p in wf.stages[sid].parents):
                    out.append((wid, sid))
        return out

    def __len__(self) -> int:
        return len(self.workflows)


@dataclasses.dataclass
class WorkflowServeStats:
    """Per-workflow serving outcome (times are absolute sim seconds).

    ``arrival`` is the ORIGINAL trace arrival even for workflows that
    the control plane deferred, so latency (and SLO attainment)
    includes time spent in the admission backlog.  ``deadline`` is set
    only when the scheduler runs with an :class:`SLOConfig` (or the
    workflow was submitted with an explicit deadline); ``klass`` is
    the admission class named at submission.
    """
    wid: str
    arrival: float
    finish: float
    query_completion: list[float]      # absolute per-query finish times
    n_stages: int
    deadline: Optional[float] = None   # absolute SLO deadline, if any
    klass: str = "default"

    @property
    def makespan(self) -> float:
        """End-to-end latency: completion minus original arrival."""
        return self.finish - self.arrival

    @property
    def latencies(self) -> list[float]:
        """Per-query latencies relative to the original arrival."""
        return [t - self.arrival for t in self.query_completion]

    @property
    def p95(self) -> float:
        """95th-percentile per-query latency (nearest-rank)."""
        return nearest_rank_p95(self.latencies, default=self.makespan)

    @property
    def slo_met(self) -> bool:
        """True when the workflow finished within its deadline (always
        True when no SLO was configured)."""
        return self.deadline is None or self.finish <= self.deadline + 1e-9


@dataclasses.dataclass
class ServingResult:
    """Outcome of one serving trace under one policy.

    ``rejected`` lists workflows the admission controller shed (never
    executed); ``deferrals``/``preemptions`` count control-plane
    interventions.  All three stay empty/zero without an SLO config.
    ``failed`` lists admitted workflows that exhausted their retry
    budget under fault injection; the fault counters
    (``device_downs``/``shard_failures``/``retries``/``stragglers``/
    ``speculations``) stay zero without a
    :class:`~repro.core.faults.FaultPlan`.  ``shard_preemptions``
    counts kill/replay preemptions of issued-and-running shards
    (multi-class runs with ``preempt_running`` only) and ``classes``
    maps every offered workflow id to its admission class, so
    per-class attainment is computable for rejected/failed workflows
    too (:func:`repro.workflowbench.metrics.class_summary`).
    """
    stats: dict[str, WorkflowServeStats]
    horizon: float                     # first arrival -> last completion
    max_in_flight: int
    replans: int
    model_switches: int
    rejected: list[str] = dataclasses.field(default_factory=list)
    deferrals: int = 0
    preemptions: int = 0
    failed: list[str] = dataclasses.field(default_factory=list)
    device_downs: int = 0
    shard_failures: int = 0
    retries: int = 0
    stragglers: int = 0
    speculations: int = 0
    shard_preemptions: int = 0
    classes: dict[str, str] = dataclasses.field(default_factory=dict)

    @property
    def n_offered(self) -> int:
        """Workflows offered by the trace: completed + rejected +
        failed-under-faults (failures count against attainment)."""
        return len(self.stats) + len(self.rejected) + len(self.failed)

    @property
    def slo_attainment(self) -> float:
        """Fraction of OFFERED workflows that completed within their
        deadline (rejected arrivals count against attainment)."""
        if self.n_offered == 0:
            return float("nan")
        met = sum(1 for s in self.stats.values() if s.slo_met)
        return met / self.n_offered

    @property
    def goodput_wps(self) -> float:
        """Completed workflows per second over the busy horizon."""
        return len(self.stats) / self.horizon if self.horizon > 0 else 0.0

    @property
    def goodput_slo_wps(self) -> float:
        """SLO-met workflows per second over the busy horizon — the
        serving objective the control plane optimizes."""
        if self.horizon <= 0:
            return 0.0
        met = sum(1 for s in self.stats.values() if s.slo_met)
        return met / self.horizon

    @property
    def goodput_qps(self) -> float:
        """Completed queries per second over the busy horizon."""
        n_q = sum(len(s.query_completion) for s in self.stats.values())
        return n_q / self.horizon if self.horizon > 0 else 0.0


# ---------------------------------------------------------------------------
# the scheduler core
# ---------------------------------------------------------------------------


class Scheduler:
    """Event-driven scheduling runtime with an explicit lifecycle.

    Construction::

        sched = Scheduler(cluster, SchedulerConfig(policy="FATE"))
        sched.submit(wf_a)                 # arrives now
        sched.submit(wf_b, at=0.7)         # arrives at t=0.7
        for ev in sched.stream():          # lazily advances the clock
            ...
        result = sched.drain()             # ServingResult

    ``submit`` enqueues an arrival; ``step()`` advances the clock by
    exactly one event batch (performing any planning/issuing the batch
    unlocks); ``run_until(t)`` steps through every event at or before
    ``t``; ``drain()`` runs to quiescence and returns the
    :class:`ServingResult`.  With ``config.slo`` set, every arrival
    passes the :class:`~repro.core.admission.AdmissionController`
    future-state probe and is admitted, deferred into the bounded
    backlog (re-probed oldest-feasible-first on completions), or
    rejected; SLO-tight admissions preempt the committed-but-unissued
    pool.  Revocation never touches execution state (only issuing
    mutates ρ/κ/τ), so delta rescoring stays bit-identical to full
    rebuilds across preemptions.

    Every transition is appended to :attr:`events` and dispatched to
    :meth:`on` subscribers — the replayable trace that feeds the
    calibration loop and any external observer.

    Advanced injection hooks (used by the back-compat executor
    adapters): pass a pre-built ``state`` and/or ``policy`` to bypass
    the config's construction of them, ``world_profiles`` to emulate
    hardware whose constants diverge from the scheduler's belief (the
    calibration mis-belief harness), ``probe_corrector`` to share a
    long-lived online probe-margin corrector across runs, and
    ``batch=True`` for the single-workflow batch semantics of
    :class:`~repro.core.executor.WorkflowExecutor` (per-workflow
    ``plan()`` dispatch, unconditional greedy fallback, persistent
    commit pool, one completion per clock advance, no admission).
    """

    def __init__(self, cluster=None,
                 config: Optional[SchedulerConfig] = None, *,
                 state: Optional[ExecutionState] = None,
                 policy=None, world_profiles: Optional[dict] = None,
                 world_cost_params: Optional[CostParams] = None,
                 probe_corrector=None, batch: bool = False,
                 journal: Optional[EventJournal] = None,
                 audit_every: Optional[int] = None):
        self.config = config or SchedulerConfig()
        # snapshot() refuses schedulers built through the injection
        # hooks below: injected objects are not reconstructable from
        # the config, so a snapshot of them could not restore
        self._injected = (state is not None or policy is not None
                          or world_profiles is not None
                          or world_cost_params is not None
                          or probe_corrector is not None)
        if state is None:
            if cluster is None:
                raise ValueError("Scheduler needs a cluster or a "
                                 "pre-built ExecutionState")
            state = fresh_state(cluster,
                                profiles=self.config.model_profiles())
        self.state = state
        self.cost_params = self.config.effective_cost_params()
        # world_profiles / world_cost_params: ground-truth constants the
        # emulated hardware follows when they diverge from what the
        # scheduler believes (state.profiles / config cost params) —
        # the calibration benchmark's mis-belief harness; None means
        # world == belief
        self.cm = CostModel(state,
                            (world_cost_params
                             if world_cost_params is not None
                             else self.cost_params),
                            profiles=world_profiles)
        self.policy = policy if policy is not None \
            else self.config.build_policy()
        self.batch = batch
        self.slo = None if batch else self.config.slo
        self.replan_on_completion = (not batch
                                     and self.config.replan_on_completion)
        self.admission: Optional[AdmissionController] = (
            AdmissionController(self.slo, corrector=probe_corrector)
            if self.slo is not None else None)
        if self.admission is not None:
            # late-bound view: self.issued is REBOUND by _load_snapshot,
            # so the controller must read the attribute, not the set
            self.admission.bind_issued(lambda: self.issued)

        # event stream ---------------------------------------------------
        self.events = EventLog(self.config.event_buffer)
        self._handlers: list[tuple[type, Callable]] = []

        # durability ------------------------------------------------------
        # lifecycle: "open" accepts submissions; "drained" is a
        # finalized run; "restored" resumes pre-crash work only
        self._lifecycle = "open"
        self.journal: Optional[EventJournal] = None
        self._journaled = 0                 # next stream index to journal
        self.audit_every = audit_every
        self._n_steps = 0
        if journal is not None:
            self.attach_journal(journal)

        # run state ------------------------------------------------------
        self.frontier = SharedFrontier()
        # (t, prio, seq, kind, payload); prio is seq in serving mode,
        # the stage id in batch mode (historical tie-break contracts).
        # Future arrivals live on their own heap (_SPLIT_ARRIVALS) so
        # in-flight heap scans don't degrade under deep arrival queues;
        # _peek/_pop_next merge the two in exact single-heap order.
        self._heap: list[tuple] = []
        self._arrivals_q: list[tuple] = []
        self._seq = 0
        # routed stage resolution at issue time (Placement.model);
        # None whenever routing is off — every resolver then returns
        # the workflow's own stage object untouched
        self._router: Optional[StageRouter] = (
            StageRouter(self.config.routing)
            if getattr(self.config, "routing", None) is not None
            else None)
        self._n_total_stages = 0
        self.committed: list[Placement] = []
        self.issued: set[StageKey] = set()
        self.runs: dict[StageKey, StageRun] = {}
        # indexed views of the commit pool and issued set, kept in
        # lockstep by _commit/_drop_commit_index/_drop_issued (the
        # invariant audit cross-checks them against the authoritative
        # list/set).  They replace the per-tick O(committed × parents)
        # feasibility scan and the O(issued) by-device/by-workflow
        # scans in the crash/failure paths.
        self._committed_keys: set[StageKey] = set()
        self._commit_unmet: dict[StageKey, int] = {}
        self._commit_feasible: set[StageKey] = set()
        # parent stage key -> commit keys waiting on it, plus the
        # reverse map so drops clean up without a workflow lookup
        self._commit_waiting: dict[StageKey, set[StageKey]] = {}
        self._commit_parents: dict[StageKey, list[StageKey]] = {}
        self._committed_by_dev: dict[int, set[StageKey]] = {}
        self._issued_by_dev: dict[int, set[StageKey]] = {}
        self._issued_by_wid: dict[str, set[StageKey]] = {}
        # devices recorded at ISSUE time — runs[key] can be replaced
        # by a winning speculative copy on different devices before
        # the drop, so index removal must not read runs[key]
        self._issued_devices: dict[StageKey, tuple] = {}
        self._wf_finish: dict[str, float] = {}
        self._arrivals: dict[str, float] = {}
        self._deadlines: dict[str, float] = {}
        self._klass: dict[str, str] = {}
        self._workflows_all: dict[str, Workflow] = {}
        self.stats: dict[str, WorkflowServeStats] = {}
        self._query_done: dict[str, dict[int, float]] = {}
        self._first_arrival: Optional[float] = None
        self._last_finish: Optional[float] = None
        self.max_in_flight = 0
        self.replans = 0
        self.preemptions = 0
        self.shard_preemptions = 0
        self._switches_before = state.model_switches
        self._guard = 0
        self._n_rejected_seen = 0
        # mechanism proxies (Appendix C.2), accumulated per workflow
        self._edge_cross: dict[str, int] = {}
        self._prefix_hits: dict[str, float] = {}
        self._same_model: dict[str, float] = {}
        self.result: Optional[ServingResult] = None

        # fault machinery (serving mode only; None everywhere on the
        # fault-free path, whose behavior is bit-identical to pre-fault
        # schedulers) -----------------------------------------------------
        self.faults: Optional[FaultPlan] = (None if batch
                                            else self.config.faults)
        self.injector: Optional[FaultInjector] = None
        self.health: Optional[DeviceHealth] = None
        self.failed: list[str] = []
        self.device_downs = 0
        self.shard_failures = 0
        self.retries = 0
        self.stragglers = 0
        self.speculations = 0
        # per-stage execution generation: pending heap events carry the
        # token they were issued under, so a failure (token bump)
        # invalidates the stale finish/timeout events still in flight
        self._run_token: dict[StageKey, int] = {}
        self._attempts: dict[StageKey, int] = {}
        # kill/replay anti-livelock: stages preempted this many times
        # (slo.preempt_kill_cap) become immune to further preemption
        self._preempt_counts: dict[StageKey, int] = {}
        # retry backoff holds: stage key -> earliest replan time
        self._hold: dict[StageKey, float] = {}
        self._submitted: set[str] = set()
        if self.faults is not None:
            self.injector = FaultInjector(self.faults)
            self.health = DeviceHealth(self.faults)
            for crash in self.faults.crashes:
                heapq.heappush(self._heap, (crash.at, self._seq,
                                            self._seq, "crash", crash))
                self._seq += 1
                if crash.recover_at is not None:
                    heapq.heappush(self._heap,
                                   (crash.recover_at, self._seq,
                                    self._seq, "recover", crash.device))
                    self._seq += 1

    # -- clock -----------------------------------------------------------
    @property
    def now(self) -> float:
        """Current simulation time (monotone across steps)."""
        return self.state.now

    def next_event_time(self) -> Optional[float]:
        """Timestamp of the next pending event (``None`` when idle)."""
        head = self._peek()
        return head[0] if head is not None else None

    def _peek(self) -> Optional[tuple]:
        """Earliest pending entry across the event and arrival heaps
        (``None`` when both are empty).  Full-tuple comparison on the
        shared ``(t, prio, seq)`` prefix reproduces the single-heap
        order exactly."""
        a = self._heap[0] if self._heap else None
        b = self._arrivals_q[0] if self._arrivals_q else None
        if a is None:
            return b
        if b is None or a <= b:
            return a
        return b

    def _pop_next(self) -> tuple:
        """Pop the entry :meth:`_peek` points at."""
        a = self._heap[0] if self._heap else None
        b = self._arrivals_q[0] if self._arrivals_q else None
        if b is None or (a is not None and a <= b):
            return heapq.heappop(self._heap)
        return heapq.heappop(self._arrivals_q)

    # -- event stream ----------------------------------------------------
    def on(self, event_type: type, handler: Callable) -> None:
        """Subscribe ``handler(event)`` to every emitted event that is
        an instance of ``event_type`` (use :class:`SchedulerEvent` to
        observe the whole stream)."""
        self._handlers.append((event_type, handler))

    def _emit(self, ev: SchedulerEvent) -> None:
        self.events.append(ev)
        for etype, handler in self._handlers:
            if isinstance(ev, etype):
                handler(ev)

    def __iter__(self) -> Iterator[SchedulerEvent]:
        """Iterate over the events emitted so far (a snapshot; use
        :meth:`stream` to lazily drive the clock instead)."""
        return iter(list(self.events))

    def stream(self, strict: bool = False) -> Iterator[SchedulerEvent]:
        """Drive the scheduler to quiescence lazily, yielding each
        event as it is emitted (one :meth:`step` per batch).

        Positions are absolute (ring-buffer safe): with a configured
        ``event_buffer`` cap, events evicted between steps are skipped
        rather than re-yielded or crashed on — unless ``strict`` is
        set, in which case an eviction the consumer has not seen
        raises ``RuntimeError`` instead of silently gapping the
        stream (the contract the gateway's NDJSON endpoint rides:
        a dropped event must surface as an error, never as silence).
        """
        seen = self.events.n_total
        while True:
            progressed = self.step()
            if strict and seen < self.events.n_dropped:
                raise RuntimeError(
                    f"event stream gap: {self.events.n_dropped - seen}"
                    f" event(s) were evicted from the ring "
                    f"(event_buffer={self.events.maxlen}) before this "
                    f"consumer read them; raise event_buffer or "
                    f"consume faster")
            if self.events.n_total > seen:
                for ev in self.events.since(seen):
                    yield ev
                seen = self.events.n_total
            if not progressed:
                return

    # -- lifecycle -------------------------------------------------------
    def submit(self, wf: Workflow, *, at: Optional[float] = None,
               deadline: Optional[float] = None,
               klass: str = "default") -> str:
        """Enqueue a workflow arrival.

        ``at`` is the absolute arrival time (default: now); arrivals
        in the past fire at the next step.  ``deadline`` optionally
        pins an absolute completion deadline for stats/events even
        without an SLO config (with one, the SLO-derived deadline
        governs admission and this override only annotates the
        outcome).  ``klass`` names the admission class; with
        ``SLOConfig.classes`` configured it must be one of the
        registered class names (``ValueError`` otherwise, mirroring
        ``make_policy``'s unknown-name behavior) and selects the
        per-class weight/deadline scale.  Without class config any
        label is accepted and merely annotates stats.  Returns the
        workflow id.

        Raises ``ValueError`` on a duplicate ``wf.wid`` (stats and
        arrivals are keyed by wid for the whole run, so a reused id
        would silently clobber them) and on negative ``at`` or
        ``deadline`` (the simulated clock starts at zero).  Raises
        ``RuntimeError`` when the scheduler is no longer ``"open"``:
        a drained run is finalized (its :class:`ServingResult` is
        built) and a crash-restored scheduler only resumes pre-crash
        work — pushing fresh arrivals into either would corrupt the
        finalized stats / the deterministic replay contract, so build
        a fresh :class:`Scheduler` instead.
        """
        if self._lifecycle != "open":
            raise RuntimeError(
                f"cannot submit {wf.wid!r}: scheduler lifecycle state "
                f"is {self._lifecycle!r} (submissions are only "
                f"accepted while 'open' — drained runs are finalized "
                f"and restored runs only resume pre-crash work; "
                f"create a fresh Scheduler for new arrivals)")
        if wf.wid in self._submitted:
            raise ValueError(
                f"duplicate workflow id submitted: {wf.wid!r}")
        if at is not None and float(at) < 0.0:
            raise ValueError(
                f"negative arrival time at={at!r} for {wf.wid!r}; "
                f"the simulated clock starts at 0.0")
        if deadline is not None and float(deadline) < 0.0:
            raise ValueError(
                f"negative deadline {deadline!r} for {wf.wid!r}; "
                f"deadlines are absolute times on a clock that "
                f"starts at 0.0")
        if (self.slo is not None and self.slo.classes
                and klass not in self.slo.classes):
            raise ValueError(
                f"unknown admission class {klass!r} for {wf.wid!r}; "
                f"configured classes: {sorted(self.slo.classes)}")
        self._submitted.add(wf.wid)
        t = self.state.now if at is None else float(at)
        # batch mode replicates the historical batch executor's heap
        # ordering: ties between simultaneous completions break by
        # stage id, not issue order (arrivals sort first via "")
        prio = "" if self.batch else self._seq
        q = self._arrivals_q if _SPLIT_ARRIVALS else self._heap
        heapq.heappush(q, (t, prio, self._seq, "arrive", wf))
        self._seq += 1
        self._n_total_stages += len(wf.stages)
        self._first_arrival = (t if self._first_arrival is None
                               else min(self._first_arrival, t))
        if deadline is not None:
            self._deadlines[wf.wid] = deadline
        self._klass[wf.wid] = klass
        return wf.wid

    def step(self) -> bool:
        """Advance through exactly one event batch.

        Consumes the next batch of simultaneous events (arrivals and
        completions) with the re-admission sweep and replan trigger,
        then SETTLES the new instant: every planning/issuing action
        the batch unlocked runs before ``step`` returns, so the heap
        already holds the follow-on events (this is what lets
        :meth:`run_until` honor its contract).  Returns ``False`` when
        the scheduler is quiescent (no pending events, commitments, or
        in-flight workflows) — at which point :meth:`drain` finalizes
        the result.

        With an attached :class:`~repro.core.journal.EventJournal`,
        the batch's events are appended (write-ahead) before ``step``
        returns — the step's commit point for crash recovery.  With
        ``audit_every=N``, every Nth step additionally runs
        :func:`audit_invariants` and raises :class:`RecoveryError` on
        any violation (the debug hook the recovery gate uses).
        """
        progressed = self._step_core()
        # flush even on a quiescent step: the final tick may still have
        # emitted events (e.g. expired-backlog rejections) that must
        # reach the journal before the run is considered settled
        self._flush_journal()
        if progressed:
            self._n_steps += 1
            if (self.audit_every is not None
                    and self._n_steps % self.audit_every == 0):
                violations = audit_invariants(self)
                if violations:
                    raise RecoveryError(
                        "invariant audit failed at step "
                        f"{self._n_steps} (t={self.now:.3f}): "
                        + "; ".join(violations))
        return progressed

    def _step_core(self) -> bool:
        while True:
            outcome = self._tick()
            if outcome == "advanced":
                # settle: run the work ticks the batch unlocked
                while self._tick(advance=False) == "work":
                    pass
                return True
            if outcome == "done":
                # quiescent: an idle, long-lived scheduler may be
                # polled indefinitely — liveness-guard counts must not
                # accumulate across idle polls
                self._guard = 0
                return False

    def run_until(self, t: float) -> None:
        """Process every pending event with timestamp ``<= t`` and
        advance the clock to at least ``t``.

        Each consumed batch is settled before the next is considered
        (see :meth:`step`), so follow-on events the planning creates
        at or before ``t`` are processed too, and work unlocked by the
        last batch is issued at its own timestamp — never back-dated
        to ``t``.
        """
        while True:
            head = self._peek()
            if head is None or head[0] > t + 1e-12:
                break
            self.step()
        self.state.now = max(self.state.now, t)

    def drain(self) -> ServingResult:
        """Run to quiescence and return the :class:`ServingResult`
        (also kept on :attr:`result`).  Finalizes the lifecycle:
        further :meth:`submit` calls raise ``RuntimeError``."""
        while self.step():
            pass
        self._lifecycle = "drained"
        self.result = self.peek_result()
        return self.result

    def peek_result(self) -> ServingResult:
        """Provisional :class:`ServingResult` over the work completed
        SO FAR, without advancing the clock or finalizing the
        lifecycle — the live-metrics view the serving gateway's
        ``/v1/metrics`` endpoint reads mid-run.  :meth:`drain` builds
        its final result through this same constructor, so a drained
        run's ``peek_result()`` equals its :attr:`result`."""
        adm = self.admission
        fa = self._first_arrival if self._first_arrival is not None \
            else 0.0
        lf = self._last_finish if self._last_finish is not None else fa
        return ServingResult(
            stats=self.stats, horizon=max(lf - fa, 0.0),
            max_in_flight=self.max_in_flight, replans=self.replans,
            model_switches=(self.state.model_switches
                            - self._switches_before),
            rejected=list(adm.rejected) if adm is not None else [],
            deferrals=adm.n_deferrals if adm is not None else 0,
            preemptions=self.preemptions,
            failed=list(self.failed),
            device_downs=self.device_downs,
            shard_failures=self.shard_failures,
            retries=self.retries, stragglers=self.stragglers,
            speculations=self.speculations,
            shard_preemptions=self.shard_preemptions,
            classes=dict(self._klass))

    def batch_result(self, wid: str) -> RunResult:
        """Single-workflow :class:`RunResult` view of a drained run
        (the batch adapter's output): per-stage runs, per-query
        completions, and the mechanism proxies of ``wid``."""
        runs = {sid: r for (w, sid), r in self.runs.items() if w == wid}
        makespan = max((r.finish for r in runs.values()), default=0.0)
        wf = self._workflows_all[wid]
        qd = self._query_done.get(wid, {})
        qdone = [qd.get(i, makespan) for i in range(wf.num_queries)]
        return RunResult(
            wid=wid, makespan=makespan, query_completion=qdone,
            stage_runs=runs,
            cross_device_edges=self._edge_cross.get(wid, 0),
            prefix_hits_est=self._prefix_hits.get(wid, 0.0),
            same_model_continuations=self._same_model.get(wid, 0.0),
            total_tasks=len(wf.stages),
            model_switches=(self.state.model_switches
                            - self._switches_before))

    # -- durability ------------------------------------------------------
    def attach_journal(self, journal: EventJournal) -> None:
        """Adopt ``journal`` as this run's write-ahead log: every
        subsequent :meth:`step` appends its event batch before
        returning.

        The journal's position must match the event stream — a fresh
        journal on a fresh scheduler, or the journal a restored
        scheduler was replayed against.  Anything else raises
        :class:`~repro.core.journal.JournalError` (the journal would
        silently stop being a contiguous prefix of the stream).
        """
        if journal.next_index != self.events.n_total:
            raise JournalError(
                f"journal is at index {journal.next_index} but the "
                f"event stream is at {self.events.n_total}; attach "
                f"the journal this stream was logged to (or a fresh "
                f"one before the first step)")
        self.journal = journal
        self._journaled = journal.next_index

    def _flush_journal(self) -> None:
        """Write-ahead append of every event emitted since the last
        flush (the per-step commit point)."""
        if self.journal is None:
            return
        n = self.events.n_total
        if n <= self._journaled:
            return
        new = self.events.since(self._journaled)
        if len(new) != n - self._journaled:
            raise JournalError(
                f"{n - self._journaled - len(new)} un-journaled "
                f"event(s) were evicted from the event ring before "
                f"the journal flush — journaled runs need an "
                f"event_buffer at least one step-batch large")
        self.journal.append_batch(new, self._journaled)
        self._journaled = n

    def snapshot(self) -> dict:
        """Serialize the complete run state into one versioned
        plain-JSON document (the checkpoint half of the durable
        control plane).

        Captures the clock and execution state (ρ/κ/ℓ/τ, down set),
        every in-flight structure (pending event heap with run tokens,
        frontier, commitments, issued runs), the admission
        controller's backlog/deadline/probe state including the
        :class:`~repro.core.calibration.ProbeCorrector` EWMAs, the
        fault machinery's RNG cursor / health counters / retry
        backoffs, the retained event window with its ring cursors, and
        the embedded :class:`SchedulerConfig` — everything
        :meth:`restore` needs to resume deterministically.

        Only config-driven serving schedulers snapshot: batch-mode
        adapters and schedulers built through the injection hooks
        (``state=``/``policy=``/``world_*``/``probe_corrector=``)
        raise ``ValueError``, since injected objects cannot be
        reconstructed from the document.
        """
        if self.batch:
            raise ValueError(
                "snapshot() supports serving mode only (batch-mode "
                "adapters are single-shot and need no durability)")
        if self._injected:
            raise ValueError(
                "snapshot() requires a config-driven Scheduler; "
                "injected state/policy/world/probe_corrector hooks "
                "cannot be reconstructed from a snapshot")
        wfs = dict(self._workflows_all)
        for entry in list(self._heap) + list(self._arrivals_q):
            if entry[3] == "arrive":
                wfs[entry[4].wid] = entry[4]
        if self.admission is not None:
            for _arr, wf in self.admission.backlog:
                wfs[wf.wid] = wf
        cluster = self.state.cluster
        return {
            "snapshot_version": SNAPSHOT_VERSION,
            "config": json.loads(self.config.to_json()),
            "cluster": {
                "transfer_coef": cluster.transfer_coef,
                "devices": [dataclasses.asdict(d)
                            for d in cluster.devices]},
            "lifecycle": self._lifecycle,
            "state": self.state.to_dict(),
            "workflows": {wid: wf.to_dict() for wid, wf in wfs.items()},
            "workflows_all": list(self._workflows_all),
            "frontier": {
                "order": list(self.frontier._order),
                "completed": {wid: sorted(done) for wid, done
                              in self.frontier.completed.items()}},
            # one wire key for both heaps (the arrival split is an
            # in-memory layout, not a snapshot format change)
            "heap": ([_heap_entry_doc(e) for e in self._heap]
                     + [_heap_entry_doc(e) for e in self._arrivals_q]),
            "committed": [_placement_doc(p) for p in self.committed],
            "issued": sorted(list(k) for k in self.issued),
            "runs": _keyed_dict_doc({k: _stagerun_doc(r)
                                     for k, r in self.runs.items()}),
            "wf_finish": dict(self._wf_finish),
            "arrivals": dict(self._arrivals),
            "deadlines": dict(self._deadlines),
            "klass": dict(self._klass),
            "stats": {wid: dataclasses.asdict(s)
                      for wid, s in self.stats.items()},
            "query_done": {wid: {str(q): t for q, t in qd.items()}
                           for wid, qd in self._query_done.items()},
            "submitted": sorted(self._submitted),
            "counters": {
                "seq": self._seq,
                "n_total_stages": self._n_total_stages,
                "first_arrival": self._first_arrival,
                "last_finish": self._last_finish,
                "max_in_flight": self.max_in_flight,
                "replans": self.replans,
                "preemptions": self.preemptions,
                "switches_before": self._switches_before,
                "guard": self._guard,
                "n_rejected_seen": self._n_rejected_seen,
                "n_steps": self._n_steps,
                "device_downs": self.device_downs,
                "shard_failures": self.shard_failures,
                "retries": self.retries,
                "stragglers": self.stragglers,
                "speculations": self.speculations,
                "shard_preemptions": self.shard_preemptions},
            "failed": list(self.failed),
            "run_token": _keyed_dict_doc(self._run_token),
            "attempts": _keyed_dict_doc(self._attempts),
            "hold": _keyed_dict_doc(self._hold),
            "preempt_counts": _keyed_dict_doc(self._preempt_counts),
            "faults": (None if self.injector is None else {
                "injector": self.injector.state_dict(),
                "health": self.health.state_dict()}),
            "admission": (self.admission.state_dict()
                          if self.admission is not None else None),
            "events": {
                "maxlen": self.events.maxlen,
                "n_total": self.events.n_total,
                "n_dropped": self.events.n_dropped,
                "retained": [ev.to_dict() for ev in self.events]},
        }

    def save_snapshot(self, path) -> Path:
        """Write :meth:`snapshot` as JSON to ``path``; returns it."""
        path = Path(path)
        path.write_text(json.dumps(self.snapshot(), sort_keys=True))
        return path

    @classmethod
    def restore(cls, snapshot, journal: Optional[EventJournal] = None
                ) -> "Scheduler":
        """Rebuild a scheduler from a :meth:`snapshot` document (or a
        path to one) and, when ``journal`` is given, deterministically
        replay the journal tail past the snapshot.

        Replay is *regeneration*: the scheduler is a deterministic
        state machine, so :meth:`restore` re-steps it from the
        snapshot and verifies every regenerated event against the
        journal's record, raising :class:`RecoveryError` on the first
        divergence.  Work that was in flight at the crash is re-armed
        through the snapshotted pending-event heap under its recorded
        run tokens, so stale completions from the pre-crash epoch are
        discarded by the same token machinery that handles speculative
        duplicates.  The journal is then re-attached (write-ahead
        logging resumes seamlessly), and the restored scheduler's
        lifecycle is ``"restored"``: it drains pre-crash work but
        refuses fresh :meth:`submit` calls.
        """
        doc = snapshot
        if not isinstance(doc, Mapping):
            doc = json.loads(Path(doc).read_text())
        version = int(doc.get("snapshot_version", -1))
        if version != SNAPSHOT_VERSION:
            raise ValueError(
                f"unsupported snapshot version {version} "
                f"(expected {SNAPSHOT_VERSION})")
        config = SchedulerConfig.from_json(json.dumps(doc["config"]))
        cl = doc["cluster"]
        cluster = Cluster(tuple(Device(**d) for d in cl["devices"]),
                          transfer_coef=cl["transfer_coef"])
        sched = cls(cluster, config)
        sched._load_snapshot(doc)
        if journal is not None:
            sched._replay_journal(journal)
        return sched

    def _load_snapshot(self, doc: Mapping) -> None:
        """Overwrite this (freshly constructed) scheduler's run state
        with a snapshot document's contents."""
        from repro.core.workflow import DEFAULT_PROFILES
        wfs = {wid: Workflow.from_dict(w)
               for wid, w in doc["workflows"].items()}
        profiles = self.config.model_profiles() or DEFAULT_PROFILES
        self.state = ExecutionState.from_dict(
            doc["state"], self.state.cluster, profiles)
        # the cost model prices off the state object — rebind it (the
        # config-driven path never injects world profiles/params)
        self.cm = CostModel(self.state, self.cost_params)
        fr = SharedFrontier()
        for wid in doc["frontier"]["order"]:
            fr.workflows[wid] = wfs[wid]
            fr.completed[wid] = set(doc["frontier"]["completed"][wid])
        fr.reindex()
        self.frontier = fr
        # replaces the scripted crash/recover events the constructor
        # pre-pushed — the snapshot heap carries the pending ones
        entries = [_heap_entry_from_doc(h, wfs) for h in doc["heap"]]
        if _SPLIT_ARRIVALS:
            self._heap = [e for e in entries if e[3] != "arrive"]
            self._arrivals_q = [e for e in entries if e[3] == "arrive"]
        else:
            self._heap = entries
            self._arrivals_q = []
        heapq.heapify(self._heap)
        heapq.heapify(self._arrivals_q)
        self.committed = [_placement_from_doc(p)
                          for p in doc["committed"]]
        self.issued = {tuple(k) for k in doc["issued"]}
        self.runs = _keyed_dict_from_doc(doc["runs"],
                                         _stagerun_from_doc)
        self._rebuild_indexes()
        self._wf_finish = dict(doc["wf_finish"])
        self._arrivals = dict(doc["arrivals"])
        self._deadlines = dict(doc["deadlines"])
        self._klass = dict(doc["klass"])
        self._workflows_all = {wid: wfs[wid]
                               for wid in doc["workflows_all"]}
        self.stats = {wid: WorkflowServeStats(**s)
                      for wid, s in doc["stats"].items()}
        self._query_done = {wid: {int(q): t for q, t in qd.items()}
                            for wid, qd in doc["query_done"].items()}
        self._submitted = set(doc["submitted"])
        c = doc["counters"]
        self._seq = c["seq"]
        self._n_total_stages = c["n_total_stages"]
        self._first_arrival = c["first_arrival"]
        self._last_finish = c["last_finish"]
        self.max_in_flight = c["max_in_flight"]
        self.replans = c["replans"]
        self.preemptions = c["preemptions"]
        self._switches_before = c["switches_before"]
        self._guard = c["guard"]
        self._n_rejected_seen = c["n_rejected_seen"]
        self._n_steps = c["n_steps"]
        self.device_downs = c["device_downs"]
        self.shard_failures = c["shard_failures"]
        self.retries = c["retries"]
        self.stragglers = c["stragglers"]
        self.speculations = c["speculations"]
        self.shard_preemptions = c.get("shard_preemptions", 0)
        self.failed = list(doc["failed"])
        self._run_token = _keyed_dict_from_doc(doc["run_token"])
        self._attempts = _keyed_dict_from_doc(doc["attempts"])
        self._hold = _keyed_dict_from_doc(doc["hold"])
        self._preempt_counts = _keyed_dict_from_doc(
            doc.get("preempt_counts") or {})
        f = doc.get("faults")
        if f is not None:
            self.injector.load_state(f["injector"])
            self.health.load_state(f["health"])
        adm_doc = doc.get("admission")
        if adm_doc is not None and self.admission is not None:
            self.admission.load_state(adm_doc, wfs)
        ev_doc = doc["events"]
        log = EventLog(ev_doc["maxlen"])
        log._items = [SchedulerEvent.from_dict(e)
                      for e in ev_doc["retained"]]
        log.n_total = ev_doc["n_total"]
        log.n_dropped = ev_doc["n_dropped"]
        self.events = log
        self._journaled = log.n_total
        self._lifecycle = "restored"

    def _replay_journal(self, journal: EventJournal) -> None:
        """Re-step from the snapshot through the journal tail,
        verifying each regenerated event against the journal's record
        (see :meth:`restore`); then adopt the journal for continued
        write-ahead logging."""
        cursor = self.events.n_total
        tail = [ev for _i, ev in journal.read(cursor)]
        if journal.next_index < cursor:
            raise JournalError(
                f"journal ends at event {journal.next_index} but the "
                f"snapshot is already at {cursor} — this journal does "
                f"not extend this snapshot")
        consumed = 0
        while consumed < len(tail):
            before = self.events.n_total
            if not self._step_core():
                raise RecoveryError(
                    f"journal holds {len(tail) - consumed} more "
                    f"event(s) past the restored run's quiescence")
            new = self.events.since(before)
            if len(new) != self.events.n_total - before:
                raise RecoveryError(
                    "event ring evicted events mid-replay — "
                    "journaled runs need an event_buffer at least "
                    "one step-batch large")
            for ev in new:
                if consumed >= len(tail):
                    break       # regenerated past the logged tail
                if ev != tail[consumed]:
                    raise RecoveryError(
                        f"replay divergence at event "
                        f"{cursor + consumed}: regenerated {ev!r}, "
                        f"journal holds {tail[consumed]!r}")
                consumed += 1
        # resume write-ahead logging: adopt the journal and flush any
        # events the final replayed batch generated past its record
        self.journal = journal
        self._journaled = journal.next_index
        self._flush_journal()

    # -- internals -------------------------------------------------------
    def _guard_limit(self) -> int:
        factor = 40 if self.batch else 60
        limit = factor * max(self._n_total_stages, 1) + 1000
        if self.injector is not None:
            # retries, speculation, and crash replans legitimately
            # multiply the per-stage tick count under fault injection
            limit += 20 * max(self._n_total_stages, 1) + 2000
        return limit

    def _claimed_keys(self) -> set[StageKey]:
        return self.issued | self._committed_keys

    # -- commit-pool / issued-set indexes ---------------------------------
    def _commit(self, p: Placement) -> None:
        """Append one placement to the commit pool, indexing it: key
        set, unmet-parent count (feeding the O(1) pool-feasibility
        check), waiting-on maps, and the by-device view."""
        key = (p.wid, p.sid)
        self.committed.append(p)
        self._committed_keys.add(key)
        wf = self.frontier.workflows.get(p.wid)
        done = self.frontier.completed.get(p.wid, ())
        unmet = ([par for par in wf.stages[p.sid].parents
                  if par not in done] if wf is not None else [])
        self._commit_unmet[key] = len(unmet)
        parents = [(p.wid, par) for par in unmet]
        self._commit_parents[key] = parents
        for pk in parents:
            self._commit_waiting.setdefault(pk, set()).add(key)
        if wf is not None and not unmet:
            self._commit_feasible.add(key)
        for d in p.devices:
            self._committed_by_dev.setdefault(d, set()).add(key)

    def _commit_all(self, ps: Sequence[Placement]) -> None:
        for p in ps:
            self._commit(p)

    def _drop_commit_index(self, p: Placement) -> None:
        """Remove one placement's index entries (the caller removes it
        from the ``committed`` list itself)."""
        key = (p.wid, p.sid)
        self._committed_keys.discard(key)
        self._commit_feasible.discard(key)
        self._commit_unmet.pop(key, None)
        for pk in self._commit_parents.pop(key, ()):
            s = self._commit_waiting.get(pk)
            if s is not None:
                s.discard(key)
                if not s:
                    del self._commit_waiting[pk]
        for d in p.devices:
            s = self._committed_by_dev.get(d)
            if s is not None:
                s.discard(key)
                if not s:
                    del self._committed_by_dev[d]

    def _clear_committed(self) -> None:
        """Empty the commit pool and every index over it (preemption /
        crash / completion-replan revocation)."""
        self.committed.clear()
        self._committed_keys.clear()
        self._commit_unmet.clear()
        self._commit_feasible.clear()
        self._commit_waiting.clear()
        self._commit_parents.clear()
        self._committed_by_dev.clear()

    def _drop_issued(self, key: StageKey) -> None:
        """Remove ``key`` from the issued set and its indexes, using
        the devices recorded at issue time (``runs[key]`` may already
        hold a winning speculative copy on other devices)."""
        self.issued.discard(key)
        for d in self._issued_devices.pop(key, ()):
            s = self._issued_by_dev.get(d)
            if s is not None:
                s.discard(key)
                if not s:
                    del self._issued_by_dev[d]
        s = self._issued_by_wid.get(key[0])
        if s is not None:
            s.discard(key)
            if not s:
                del self._issued_by_wid[key[0]]

    def _rebuild_indexes(self) -> None:
        """Recompute every derived committed/issued index from the
        authoritative structures (snapshot-restore path).  Unmet
        counts come from the restored completion sets, so the rebuilt
        indexes are exactly what incremental maintenance would have
        produced."""
        pool = self.committed
        self.committed = []
        self._clear_committed()
        for p in pool:
            self._commit(p)
        self._issued_by_dev = {}
        self._issued_by_wid = {}
        self._issued_devices = {}
        for key in self.issued:
            devs = self.runs[key].placement.devices
            self._issued_devices[key] = devs
            self._issued_by_wid.setdefault(key[0], set()).add(key)
            for d in devs:
                self._issued_by_dev.setdefault(d, set()).add(key)

    def _stall_name(self) -> str:
        if self.batch:
            wid = next(iter(self._workflows_all), "batch")
            return f"{wid}: executor stalled ({self.policy.name})"
        return f"serving executor stalled ({self.policy.name})"

    def _issuable(self, p: Placement) -> bool:
        done = self.frontier.completed.get(p.wid)
        if done is None:
            return False
        st = self.frontier.workflows[p.wid].stages[p.sid]
        if any(par not in done for par in st.parents):
            return False
        if self.state.down and any(d in self.state.down
                                   for d in p.devices):
            return False
        return all(self.state.device_free(d) <= self.state.now + 1e-12
                   for d in p.devices)

    def _effective_stage(self, wf: Workflow, sid: str,
                         model: Optional[str]) -> Stage:
        """The stage object an attempt actually runs as: the routed
        twin when the placement carries an alternate family
        (``Placement.model``, set by the routing-enabled planner),
        the workflow's own stage otherwise — so issue durations,
        residency, prefix warmth, and kill/replay credit-back all
        price the family that really executed."""
        st = wf.stages[sid]
        if (model is None or model == st.model
                or self._router is None):
            return st
        var = self._router.variant(wf.wid, st, model,
                                   self.state.profiles)
        return var if var is not None else st

    def _issue(self, p: Placement) -> None:
        state = self.state
        wf = self.frontier.workflows[p.wid]
        st = self._effective_stage(wf, p.sid, p.model)
        if self.batch:
            # mechanism proxies (Appendix C.2), measured at issue
            # before the state update — batch-only: ServingResult
            # never reports them, so the serving hot path (replanned
            # on every completion) skips the per-issue scans
            primary = p.devices[0]
            for par in st.parents:
                locs = state.output_loc.get((p.wid, par), ())
                if locs and primary not in locs:
                    self._edge_cross[p.wid] = \
                        self._edge_cross.get(p.wid, 0) + 1
            ov = state.prefix_overlap(st, primary, wf.num_queries)
            self._prefix_hits[p.wid] = \
                self._prefix_hits.get(p.wid, 0.0) + ov
            res_frac = sum(
                1 for d in p.devices if state.is_resident(st.model, d)
            ) / len(p.devices)
            self._same_model[p.wid] = \
                self._same_model.get(p.wid, 0.0) + res_frac

        key = (p.wid, p.sid)
        slow = fail_frac = None
        attempt = 0
        if self.injector is not None:
            attempt = self._attempts.get(key, 0)
            slow = self.injector.slow_map(p.devices, state.now)
            fail_frac = self.injector.failure_fraction(
                p.wid, p.sid, p.devices, attempt)
        shard_fin, switched, believed = _issue_shards(
            state, self.cm, wf, st, p, slow=slow, fail_frac=fail_frac)
        fin_all = max(shard_fin)
        token = self._run_token.get(key, 0)
        run = StageRun(p, state.now, fin_all,
                       tuple(shard_fin), tuple(switched))
        self.runs[key] = run
        self.issued.add(key)
        self._issued_devices[key] = p.devices
        self._issued_by_wid.setdefault(p.wid, set()).add(key)
        for d in p.devices:
            self._issued_by_dev.setdefault(d, set()).add(key)
        prio = p.sid if self.batch else self._seq
        kind = "finish" if fail_frac is None else "fail"
        heapq.heappush(self._heap, (fin_all, prio, self._seq, kind,
                                    (key, token, run)))
        self._seq += 1
        if (self.injector is not None and not self.batch
                and self.faults.straggler_threshold > 0.0):
            # schedule a straggler probe at threshold x the believed
            # (fault-free) duration; elide it when the actual finish
            # provably beats it (healthy stage — no timeout can fire)
            horizon = max(believed) - state.now
            if horizon > 1e-9:
                t_out = (state.now
                         + self.faults.straggler_threshold * horizon)
                if t_out < fin_all - 1e-9:
                    heapq.heappush(
                        self._heap,
                        (t_out, self._seq, self._seq, "timeout",
                         (key, token)))
                    self._seq += 1
        self._emit(IssueEvent(t=state.now, wid=p.wid, sid=p.sid,
                              devices=p.devices, start=state.now,
                              finish=fin_all))

    def _issue_all(self) -> None:
        """Issue every committed placement that is dependency-ready on
        free live devices, purging stale commitments (already issued,
        retired workflow, completed stage).

        Single pass: issuing a placement never makes another committed
        placement MORE issuable at the same instant — parents only
        complete in event handlers, and issuing only raises device
        free times — so one in-order sweep reaches the same fixpoint
        the historical issue-until-no-progress loop did, without
        re-scanning the pool once per issued placement.
        """
        if not self.committed:
            return
        keep: list[Placement] = []
        for p in self.committed:
            key = (p.wid, p.sid)
            if key in self.issued \
                    or p.wid not in self.frontier.workflows \
                    or p.sid in self.frontier.completed[p.wid]:
                self._drop_commit_index(p)
                continue
            if self._issuable(p):
                self._drop_commit_index(p)
                self._issue(p)
            else:
                keep.append(p)
        self.committed = keep

    def _admit(self, wf: Workflow, arrival: float,
               deadline: Optional[float] = None) -> None:
        self.frontier.admit(wf)
        self._workflows_all[wf.wid] = wf
        self._arrivals[wf.wid] = arrival
        if deadline is not None:
            # an explicit submit() deadline annotation wins over the
            # SLO-derived one for reporting (admission already decided)
            self._deadlines.setdefault(wf.wid, deadline)
        self.max_in_flight = max(self.max_in_flight, len(self.frontier))
        hook = getattr(self.policy, "on_arrival", None)
        if hook is not None:
            hook(wf, self.state)
        self._emit(AdmittedEvent(
            t=self.state.now, wid=wf.wid, arrival=arrival,
            deadline=self._deadlines.get(wf.wid),
            klass=self._klass.get(wf.wid, "default")))

    def _preempt_commitments(self, trigger_wid: str) -> None:
        """Revoke committed-but-unissued placements for an SLO-tight
        admission.  No execution state was mutated for them (only
        issuing writes ρ/κ/τ), so the planner's delta-rescoring caches
        need no repair — the revoked rows simply reappear in the next
        merged solve, warm-started on their previous devices via the
        solution hint."""
        if self.committed:
            revoked = list(self.committed)
            self._clear_committed()
            self.preemptions += 1
            hook = getattr(self.policy, "on_preempt", None)
            if hook is not None:
                hook(revoked, self.state)
            self._emit(PreemptionEvent(t=self.state.now,
                                       trigger_wid=trigger_wid,
                                       n_revoked=len(revoked)))

    def _preempt_running(self, trigger_wid: str) -> int:
        """Kill/replay preemption of ISSUED-and-running shards on
        behalf of a higher-class arrival (multi-class configs with
        ``preempt_running`` only; a no-op — returning 0 — otherwise,
        keeping single-class runs bit-identical).

        Victims are issued stages of strictly lower class weight than
        the trigger, excluding stages already killed
        ``preempt_kill_cap`` times (anti-livelock immunity) and stages
        about to finish at the current instant (killing them gains no
        capacity and loses finished work).  Up to
        ``preempt_running_max`` victims are killed per trigger,
        furthest-from-finishing first.  Returns the kill count.
        """
        slo = self.slo
        if (slo is None or not slo.classes or not slo.preempt_running
                or not self.issued):
            return 0
        w_t = slo.class_weight(self._klass.get(trigger_wid, "default"))
        now = self.state.now
        victims = []
        for key in self.issued:
            wid = key[0]
            if wid == trigger_wid:
                continue
            w_v = slo.class_weight(self._klass.get(wid, "default"))
            if not (w_v < w_t - 1e-12):
                continue
            if (self._preempt_counts.get(key, 0)
                    >= slo.preempt_kill_cap):
                continue
            run = self.runs[key]
            if run.finish <= now + 1e-9:
                continue
            victims.append((-run.finish, key))
        victims.sort()
        n = 0
        for _neg_fin, key in victims[:max(slo.preempt_running_max, 0)]:
            self._kill_run(key, trigger_wid)
            n += 1
        return n

    def _kill_run(self, key: StageKey, trigger_wid: str) -> None:
        """Kill one issued run and credit its partial state back.

        The run token is bumped so the in-flight finish/fail heap
        events go stale (the same machinery that retires speculative
        losers), the stage leaves the issued set (it re-enters the
        ready frontier, so the next settle loop replans it), and the
        devices the run held are credited back through the dirty-device
        mutators: τ is released to now and the κ prefix warm this
        attempt wrote is revoked (a killed attempt produces no reusable
        cache — mirroring ``fail_frac``'s no-warm rule).  Residency ρ
        is NOT rolled back: the model weights really were loaded.

        A device is only freed when no OTHER issued stage has a
        token-valid heap event running on it — speculative copies queue
        on busy devices, so blindly freeing would corrupt their τ.

        A short ``preempt_holdoff`` is recorded against the stage (with
        a "release" heap event guaranteeing the clock reaches its
        expiry) so the very next solve cannot re-place the victim ahead
        of the trigger it was killed for.
        """
        state = self.state
        wid, sid = key
        run = self.runs[key]
        token = self._run_token.get(key, 0)
        mine: set[int] = set()
        busy_others: set[int] = set()
        for (_t, _prio, _seq, kind, payload) in self._heap:
            if kind not in ("finish", "fail"):
                continue
            k2, tok2, run2 = payload
            if tok2 != self._run_token.get(k2, 0):
                continue
            if k2 == key:
                mine.update(run2.placement.devices)
            elif k2 in self.issued:
                busy_others.update(run2.placement.devices)
        st = self._effective_stage(self.frontier.workflows[wid], sid,
                                   run.placement.model)
        for d in sorted(mine - busy_others):
            if d in state.down:
                continue
            state.set_free_at(d, state.now)
            if st.keep_cache:
                state.revoke_prefix(d, st.prefix_group, st.model)
        self._run_token[key] = token + 1
        self._drop_issued(key)
        self.shard_preemptions += 1
        self._preempt_counts[key] = \
            self._preempt_counts.get(key, 0) + 1
        holdoff = max(self.slo.preempt_holdoff, 0.0)
        if holdoff > 0.0:
            t_r = state.now + holdoff
            self._hold[key] = t_r
            heapq.heappush(self._heap, (t_r, self._seq, self._seq,
                                        "release", key))
            self._seq += 1
        self._emit(ShardPreemptionEvent(
            t=state.now, wid=wid, sid=sid,
            devices=run.placement.devices, trigger_wid=trigger_wid,
            klass=self._klass.get(wid, "default"),
            trigger_klass=self._klass.get(trigger_wid, "default")))

    def _emit_new_rejections(self, reason: str) -> None:
        adm = self.admission
        if adm is None:
            return
        for wid in adm.rejected[self._n_rejected_seen:]:
            self._emit(RejectedEvent(t=self.state.now, wid=wid,
                                     reason=reason))
        self._n_rejected_seen = len(adm.rejected)

    def _finish(self, key: StageKey) -> None:
        state = self.state
        wid, sid = key
        wf = self.frontier.workflows[wid]
        st = wf.stages[sid]
        run = self.runs[key]
        # workflow finish tracks only SUCCESSFUL attempts (a failed
        # attempt's projected finish never materialises)
        self._wf_finish[wid] = max(self._wf_finish.get(wid, 0.0),
                                   run.finish)
        if self.health is not None:
            for d in run.placement.devices:
                self.health.record_success(d)
        self._attempts.pop(key, None)
        state.output_loc[(wid, sid)] = run.placement.devices
        state.completed.add((wid, sid))
        if not st.children:          # sink: per-query completion
            qd = self._query_done.setdefault(wid, {})
            qid = 0
            for dfin, nq in zip(run.shard_finish,
                                run.placement.shard_sizes):
                for _ in range(nq):
                    qd[qid] = max(qd.get(qid, 0.0), dfin)
                    qid += 1
        self._drop_issued(key)
        done = self.frontier.complete(wid, sid)
        # committed placements waiting on this stage move one parent
        # closer to issuable; zero unmet parents = pool-feasible
        for ck in self._commit_waiting.pop(key, ()):
            n = self._commit_unmet.get(ck)
            if n is not None:
                self._commit_unmet[ck] = n - 1
                if n == 1:
                    self._commit_feasible.add(ck)
        hook = getattr(self.policy, "on_completion", None)
        if hook is not None:
            hook(wid, sid, state)
        if done:
            wf_all = self._workflows_all[wid]
            qd = self._query_done.get(wid, {})
            fin_t = self._wf_finish.get(wid, state.now)
            qdone = [qd.get(i, fin_t)
                     for i in range(wf_all.num_queries)]
            self.stats[wid] = WorkflowServeStats(
                wid=wid, arrival=self._arrivals[wid], finish=fin_t,
                query_completion=qdone, n_stages=len(wf_all.stages),
                deadline=self._deadlines.get(wid),
                klass=self._klass.get(wid, "default"))
            self._last_finish = (fin_t if self._last_finish is None
                                 else max(self._last_finish, fin_t))
            if not self.batch and hasattr(self.policy,
                                          "forget_workflow"):
                self.policy.forget_workflow(wid)
            if self.admission is not None:
                # close the probe loop (predicted vs observed latency
                # -> EWMA margin corrector) before the controller
                # drops its per-workflow records
                self.admission.record_completion(wid, fin_t)
                self.admission.forget(wid)
        self._emit(CompletionEvent(t=state.now, wid=wid, sid=sid,
                                   workflow_done=done))

    def _plan(self, ready: list[StageKey]) -> list[Placement]:
        policy = self.policy
        if not self.batch and hasattr(policy, "plan_shared"):
            if (self.slo is not None and self.slo.classes
                    and getattr(policy, "supports_priorities", False)):
                # class weights bias the shared solve toward
                # higher-class rows (uniform weights are skipped in
                # the planner, keeping single-class solves identical)
                prios = {wid: self.slo.class_weight(
                             self._klass.get(wid, "default"))
                         for wid in self.frontier.workflows}
                return policy.plan_shared(self.frontier.workflows,
                                          self.state, ready,
                                          priorities=prios)
            return policy.plan_shared(self.frontier.workflows,
                                      self.state, ready)
        out: list[Placement] = []
        by_wid: dict[str, list[str]] = {}
        for wid, sid in ready:
            by_wid.setdefault(wid, []).append(sid)
        for wid, sids in by_wid.items():
            out.extend(policy.plan(self.frontier.workflows[wid],
                                   self.state, sids))
        return out

    def _process_arrivals(self, wfs: list[Workflow]) -> None:
        """Process one same-instant run of arrival events (pop order).

        With ``config.batch_probes`` and 2+ simultaneous arrivals, the
        admission probes are batched: one shared delta-rescored
        lookahead wave covers every candidate
        (:meth:`~repro.core.admission.AdmissionController.probe_batch`)
        and the per-arrival decisions are applied in pop order with
        the congestion floor evaluated at decision time — each
        decision still sees its predecessors' admissions.  Otherwise
        the arrivals are processed sequentially, byte-identically to
        the unbatched scheduler.
        """
        adm = self.admission
        if len(wfs) < 2 or adm is None or not self.config.batch_probes:
            for wf in wfs:
                self._process_arrival(wf)
            return
        if self.slo is not None and self.slo.classes:
            # the shared probe's deadline shortcut reads the class map
            for wf in wfs:
                adm.note_class(wf.wid,
                               self._klass.get(wf.wid, "default"))
        probes = adm.probe_batch(wfs, self.state, self.frontier,
                                 self.policy, self._claimed_keys())
        for wf in wfs:
            self._process_arrival(wf, probe=probes.get(wf.wid))

    def _process_arrival(self, wf: Workflow,
                         probe: Optional[tuple] = None) -> None:
        state = self.state
        if wf.wid in self._workflows_all:
            # stats/arrivals are keyed by wid for the whole run, so a
            # reused wid (even after the first instance retired) would
            # silently clobber them
            raise ValueError(
                f"duplicate workflow id in trace: {wf.wid}")
        self._emit(ArrivalEvent(t=state.now, wid=wf.wid))
        adm = self.admission
        if adm is None:
            self._admit(wf, state.now)
            return
        if self.slo is not None and self.slo.classes:
            # class-aware path: register the class before the first
            # decision; a deferral may first reclaim devices from
            # running lower-class shards (kill/replay) and re-decide
            # against the reclaimed state before backlog bookkeeping
            adm.note_class(wf.wid, self._klass.get(wf.wid, "default"))
            dec = adm.decide(wf, state, self.frontier, self.policy,
                             self._claimed_keys(), arrival=state.now,
                             probe=probe)
            if (dec.action == "defer"
                    and self._preempt_running(wf.wid) > 0):
                dec = adm.decide(wf, state, self.frontier,
                                 self.policy, self._claimed_keys(),
                                 arrival=state.now)
            dec = adm.on_arrival(wf, state, self.frontier,
                                 self.policy, self._claimed_keys(),
                                 dec=dec)
        else:
            dec = adm.on_arrival(wf, state, self.frontier, self.policy,
                                 self._claimed_keys(), probe=probe)
        if dec.action == "admit":
            self._admit(wf, state.now, dec.deadline)
            if dec.preempt:
                # SLO-tight arrival: revoke unissued commitments so it
                # competes immediately
                self._preempt_commitments(wf.wid)
                self._preempt_running(wf.wid)
        elif dec.action == "defer":
            self._emit(DeferredEvent(t=state.now, wid=wf.wid,
                                     predicted_latency=dec.predicted_latency,
                                     deadline=dec.deadline))
        self._emit_new_rejections("admission")

    # -- fault handling ---------------------------------------------------
    def _held(self, key: StageKey, now: float) -> bool:
        """True while ``key`` sits in retry backoff (lazily clears
        expired holds)."""
        t = self._hold.get(key)
        if t is None:
            return False
        if t <= now + 1e-12:
            del self._hold[key]
            return False
        return True

    def _on_shard_failed(self, key: StageKey, token: int, run: StageRun,
                         reason: str) -> None:
        """A stage attempt failed (transient shard fault or device
        crash): invalidate the in-flight run, count the attempt, trip
        quarantine, and schedule a backed-off retry or give up."""
        if key not in self.issued or token != self._run_token.get(key, 0):
            return                      # stale event (already handled)
        wid, sid = key
        self._drop_issued(key)
        self._run_token[key] = token + 1
        attempt = self._attempts.get(key, 0) + 1
        self._attempts[key] = attempt
        self.shard_failures += 1
        self._emit(ShardFailedEvent(t=self.state.now, wid=wid, sid=sid,
                                    devices=run.placement.devices,
                                    reason=reason, attempt=attempt - 1))
        if reason == "transient" and self.health is not None:
            for d in run.placement.devices:
                if self.health.record_failure(d):
                    self._quarantine(d)
        if attempt > self.faults.max_retries:
            self._fail_workflow(wid, sid)
            return
        backoff = self.faults.backoff(attempt)
        t_r = self.state.now + backoff
        self._hold[key] = t_r
        heapq.heappush(self._heap, (t_r, self._seq, self._seq, "retry",
                                    (key, attempt, backoff)))
        self._seq += 1

    def _on_retry(self, key: StageKey, attempt: int,
                  backoff: float) -> None:
        """Backoff expired: release the hold so the stage re-enters
        the ready frontier (the settle loop replans it)."""
        self._hold.pop(key, None)
        wid, sid = key
        if wid not in self.frontier.workflows:
            return                      # workflow failed/retired since
        self.retries += 1
        self._emit(RetryEvent(t=self.state.now, wid=wid, sid=sid,
                              attempt=attempt, backoff=backoff))

    def _on_timeout(self, key: StageKey, token: int) -> None:
        """Straggler probe fired before the stage finished: emit a
        degraded-mode event and (optionally) speculatively re-issue a
        single-device copy on the best live alternate.  First valid
        finish wins — both copies share the run token, and
        :meth:`_finish` discards ``key`` from ``issued``, making the
        loser's event stale."""
        if key not in self.issued or token != self._run_token.get(key, 0):
            return                      # finished or failed already
        state = self.state
        run = self.runs[key]
        wid, sid = key
        self.stragglers += 1
        self._emit(DegradedEvent(t=state.now, kind="straggler",
                                 wid=wid, sid=sid,
                                 device=run.placement.devices[0]))
        if not self.faults.speculate:
            return
        wf = self.frontier.workflows.get(wid)
        if wf is None:
            return
        # a speculative copy re-runs the SAME family the straggling
        # attempt was routed to (the quality decision is the planner's)
        st = self._effective_stage(wf, sid, run.placement.model)
        cand = [d for d in (st.eligible or state.cluster.ids())
                if d not in state.down
                and d not in run.placement.devices]
        if not cand:
            return
        best = min(cand, key=lambda d: (
            self.cm.effective_cost(wf, st, d, wf.num_queries)
            + state.wait_time(d), d))
        p2 = Placement(wid=wid, sid=sid, devices=(best,),
                       shard_sizes=(wf.num_queries,),
                       model=run.placement.model)
        slow = self.injector.slow_map((best,), state.now)
        shard_fin, switched, _ = _issue_shards(state, self.cm, wf, st,
                                               p2, slow=slow)
        fin2 = max(shard_fin)
        run2 = StageRun(p2, state.now, fin2, tuple(shard_fin),
                        tuple(switched))
        heapq.heappush(self._heap, (fin2, self._seq, self._seq,
                                    "finish", (key, token, run2)))
        self._seq += 1
        self.speculations += 1
        self._emit(IssueEvent(t=state.now, wid=wid, sid=sid,
                              devices=p2.devices, start=state.now,
                              finish=fin2))

    def _on_device_crash(self, crash) -> None:
        """Planned device crash fired: fail every in-flight stage
        touching the device (freeing surviving shard devices), evict
        the device from the live set (wiping its residency/prefix
        state), revoke committed placements on it, and force a full
        replan of the merged frontier."""
        state = self.state
        d = crash.device
        if d in state.down:
            return
        for key in sorted(self._issued_by_dev.get(d, ())):
            run = self.runs[key]
            for sd in run.placement.devices:
                if sd != d:
                    state.set_free_at(sd, state.now)
            self._on_shard_failed(key, self._run_token.get(key, 0),
                                  run, "device_down")
        state.mark_down(d, wipe=True)
        self.device_downs += 1
        n = self._revoke_on_device(d)
        hook = getattr(self.policy, "on_device_down", None)
        if hook is not None:
            hook(d, state)
        self._emit(DeviceDownEvent(t=state.now, device=d,
                                   reason="crash",
                                   recover_at=crash.recover_at,
                                   n_revoked=n))
        self._clear_committed()         # failure-aware replan

    def _on_device_recover(self, d: int) -> None:
        """Device rejoined (crash recovery or quarantine expiry):
        restore it to the live set and replan to use it."""
        state = self.state
        if d not in state.down:
            return
        state.mark_up(d)
        if self.health is not None:
            self.health.reset(d)
        hook = getattr(self.policy, "on_device_up", None)
        if hook is not None:
            hook(d, state)
        self._emit(DeviceRecoveredEvent(t=state.now, device=d))
        self._clear_committed()         # replan onto the wider set

    def _quarantine(self, d: int) -> None:
        """Health tracker tripped on ``d``: temporarily evict it
        (keeping its caches — the device is sick, not gone) and
        schedule its automatic recovery."""
        state = self.state
        if d in state.down:
            return
        state.mark_down(d, wipe=False)
        self.device_downs += 1
        recover_at = state.now + self.faults.quarantine_s
        heapq.heappush(self._heap, (recover_at, self._seq, self._seq,
                                    "recover", d))
        self._seq += 1
        n = self._revoke_on_device(d)
        hook = getattr(self.policy, "on_device_down", None)
        if hook is not None:
            hook(d, state)
        self._emit(DeviceDownEvent(t=state.now, device=d,
                                   reason="quarantine",
                                   recover_at=recover_at, n_revoked=n))

    def _revoke_on_device(self, d: int) -> int:
        """Withdraw committed-but-unissued placements touching ``d``
        (no execution state was mutated for them) and notify the
        policy's preemption hook.  Returns the revoked count."""
        keys = self._committed_by_dev.get(d)
        if not keys:
            return 0
        keys = set(keys)
        revoked = [p for p in self.committed if (p.wid, p.sid) in keys]
        self.committed = [p for p in self.committed
                          if (p.wid, p.sid) not in keys]
        for p in revoked:
            self._drop_commit_index(p)
        hook = getattr(self.policy, "on_preempt", None)
        if hook is not None:
            hook(revoked, self.state)
        return len(revoked)

    def _fail_workflow(self, wid: str, sid: str) -> None:
        """Retry budget exhausted on ``(wid, sid)``: give the whole
        workflow up.  Invalidates its in-flight runs, scrubs its
        commitments/holds, retires it from the frontier, and records
        it on :attr:`failed` (reported by :meth:`drain`)."""
        for key in sorted(self._issued_by_wid.get(wid, ())):
            self._drop_issued(key)
            self._run_token[key] = self._run_token.get(key, 0) + 1
        dropped = [p for p in self.committed if p.wid == wid]
        if dropped:
            self.committed = [p for p in self.committed
                              if p.wid != wid]
            for p in dropped:
                self._drop_commit_index(p)
        for key in [k for k in self._hold if k[0] == wid]:
            del self._hold[key]
        for key in [k for k in self._attempts if k[0] == wid]:
            del self._attempts[key]
        if wid in self.frontier.workflows:
            self.frontier.retire(wid)
        self.failed.append(wid)
        if hasattr(self.policy, "forget_workflow"):
            self.policy.forget_workflow(wid)
        if self.admission is not None:
            self.admission.forget(wid)
        self._emit(DegradedEvent(t=self.state.now, kind="gave_up",
                                 wid=wid, sid=sid))

    def _tick(self, advance: bool = True) -> str:
        """One pass of the commit-and-advance loop.

        Returns ``"work"`` (made planning/issuing progress without
        touching the clock), ``"advanced"`` (consumed one event
        batch), ``"done"`` (quiescent), or — with ``advance=False``,
        the settle mode :meth:`step` uses to flush planning at the
        current instant — ``"idle"`` (no work possible now; the clock
        was deliberately left alone).
        """
        state = self.state
        adm = self.admission
        self._guard += 1
        if self._guard > self._guard_limit():
            raise RuntimeError(self._stall_name())
        # 1. issue everything issuable at the current time
        self._issue_all()
        # 2. plan when claimed actions cannot cover the frontier
        ready = self.frontier.ready(self._claimed_keys())
        if self._hold:
            # stages in retry backoff stay out of the plan; a "retry"
            # heap event guarantees the clock reaches their release
            ready = [k for k in ready
                     if not self._held(k, state.now)]
        # O(1) via the unmet-parent index: _issue_all just purged every
        # commitment whose workflow left the frontier, so a key with
        # zero unmet parents is exactly what the historical
        # all-parents-completed scan over the pool found
        pool_feasible = bool(self._commit_feasible)
        if ready and not pool_feasible:
            new = self._plan(ready)
            self.replans += 1
            if not new and (self.batch or not self.issued):
                # liveness fallback: greedily place the single best
                # ready stage by state-corrected cost
                wid, sid = ready[0]
                fb = _greedy_fallback(
                    state, self.cm, self.frontier.workflows[wid], sid)
                new = [fb] if fb is not None else []
            if new:
                for p in new:
                    self._emit(PlacementEvent(
                        t=state.now, wid=p.wid, sid=p.sid,
                        devices=p.devices, shard_sizes=p.shard_sizes))
                self._commit_all(new)
                self._issue_all()  # start the fresh plan NOW, before
                return "work"      # the clock advances to next event
        if not advance:
            return "idle"
        # 3. advance the clock to the next event batch
        if not self._heap and not self._arrivals_q:
            if adm is not None and adm.backlog:
                # no further events will trigger re-admission: drain
                # the backlog (shed expired entries, force the oldest
                # reachable one in) and keep planning
                for arr, wfp, dec in adm.readmit(
                        state, self.frontier, self.policy,
                        self._claimed_keys(), force=True):
                    self._admit(wfp, arr, dec.deadline)
                    if dec.preempt:
                        self._preempt_commitments(wfp.wid)
                        self._preempt_running(wfp.wid)
                self._emit_new_rejections("expired")
                return "work"
            if self.batch:
                if self.committed:
                    return "work"      # unfeasible pool: guard trips
                if len(self.frontier):
                    wid = next(iter(self.frontier.workflows))
                    raise RuntimeError(
                        f"{wid}: deadlock ({self.policy.name})")
                return "done"
            if self.committed or len(self.frontier):
                raise RuntimeError(
                    f"serving executor deadlock ({self.policy.name})")
            return "done"
        t = self._peek()[0]
        state.now = max(state.now, t)
        completed_any = False
        if self.batch:
            # batch semantics: one completion per clock advance (plan
            # between same-instant completions, as Algorithm 2 does);
            # fault injection is serving-only, so the only kinds are
            # "arrive" and always-valid "finish"
            _, _, _, kind, payload = self._pop_next()
            if kind == "arrive":
                self._process_arrival(payload)
            else:
                key, _token, run = payload
                self.runs[key] = run
                self._finish(key)
                completed_any = True
        else:
            # consecutive same-instant arrivals are collected into one
            # batch so their admission probes can share a lookahead
            # wave; the flush before any other event kind (and at loop
            # end) preserves the exact pop-order semantics
            arrivals: list[Workflow] = []
            while True:
                head = self._peek()
                if head is None or head[0] > t + 1e-12:
                    break
                _, _, _, kind, payload = self._pop_next()
                if kind == "arrive":
                    arrivals.append(payload)
                    continue
                if arrivals:
                    self._process_arrivals(arrivals)
                    arrivals = []
                if kind == "finish":
                    key, token, run = payload
                    if key in self.issued \
                            and token == self._run_token.get(key, 0):
                        # first valid finish wins (speculative copies
                        # share the token; the discard below makes
                        # the loser's event stale)
                        self.runs[key] = run
                        self._finish(key)
                        completed_any = True
                elif kind == "fail":
                    key, token, run = payload
                    self._on_shard_failed(key, token, run, "transient")
                elif kind == "retry":
                    key, attempt, backoff = payload
                    self._on_retry(key, attempt, backoff)
                elif kind == "timeout":
                    key, token = payload
                    self._on_timeout(key, token)
                elif kind == "release":
                    # preemption holdoff expired: the victim stage may
                    # re-enter the plan (no event is emitted — the
                    # hold's lazy clear makes this a pure clock driver)
                    self._hold.pop(payload, None)
                elif kind == "crash":
                    self._on_device_crash(payload)
                else:               # "recover"
                    self._on_device_recover(payload)
            if arrivals:
                self._process_arrivals(arrivals)
        if completed_any and adm is not None:
            # re-admission sweep: freed capacity may now fit the
            # oldest deferred arrivals (one per sweep so each
            # admission's frontier update feeds the next probe)
            while True:
                batch = adm.readmit(state, self.frontier, self.policy,
                                    self._claimed_keys())
                self._emit_new_rejections("expired")
                if not batch:
                    break
                for arr, wfp, dec in batch:
                    self._admit(wfp, arr, dec.deadline)
                    if dec.preempt:
                        self._preempt_commitments(wfp.wid)
                        self._preempt_running(wfp.wid)
        if completed_any and self.replan_on_completion and self.committed:
            # revoke unissued commitments: the completed stage changed
            # ρ/κ/ℓ/τ, so the merged frontier is re-solved
            self._clear_committed()
        return "advanced"


# ---------------------------------------------------------------------------
# cross-structure invariant auditor
# ---------------------------------------------------------------------------


def audit_invariants(sched: Scheduler) -> list[str]:
    """Check the scheduler's cross-structure invariants; returns a
    list of human-readable violation strings (empty = consistent).

    Runs against any live, snapshotted-and-restored, or replayed
    scheduler — ``tools/invariant_audit.py`` wraps it as a CLI over
    archived snapshots, the ``--recovery`` bench gate asserts it on
    every restored state, and ``Scheduler(audit_every=N)`` runs it as
    an in-``step()`` debug hook.  Invariants:

    * no stage is simultaneously issued and completed, committed and
      issued, or committed twice;
    * every issued stage has a :class:`StageRun` record AND a pending
      token-valid finish/fail heap event (no lost work);
    * committed placements reference live frontier workflows with
      satisfied completions only, and never target a downed
      (crashed/quarantined) device;
    * stages in retry backoff are not concurrently issued, and every
      live hold (retry backoff or preemption holdoff) has a pending
      retry/release heap event that lifts it;
    * frontier bookkeeping is closed: order list <-> workflow map <->
      completion sets <-> registry/arrival tables, completed sids
      exist in their DAG, and no in-flight workflow already has final
      stats;
    * the indexed structures match their brute-force references: the
      frontier's incremental ready index reproduces the full DAG walk,
      the commit-pool key/unmet/feasibility indexes match the pool,
      and the issued by-device/by-workflow indexes match the issued
      set;
    * event ring accounting: ``n_total == n_dropped + retained``, the
      cap is respected, and nothing is dropped while uncapped.
    """
    v: list[str] = []
    state = sched.state
    fr = sched.frontier
    # issued set ----------------------------------------------------------
    pending: set[StageKey] = set()
    for (_t, _prio, _seq, kind, payload) in sched._heap:
        if kind in ("finish", "fail"):
            key, token, _run = payload
            if token == sched._run_token.get(key, 0):
                pending.add(key)
    for key in sorted(sched.issued):
        wid, sid = key
        if sid in fr.completed.get(wid, ()):
            v.append(f"stage {key} is both issued and completed")
        if key not in sched.runs:
            v.append(f"issued stage {key} has no StageRun record")
        if key not in pending:
            v.append(f"issued stage {key} has no pending token-valid "
                     f"completion event (lost work)")
        if key in sched._hold:
            v.append(f"stage {key} is in retry backoff but issued")
    # holds ---------------------------------------------------------------
    # every live hold needs a heap event that reaches its release time
    # (a "retry" from the failure path or a "release" from running-shard
    # preemption) — otherwise the stage could sit held forever
    releasable: set[StageKey] = set()
    for (_t, _prio, _seq, kind, payload) in sched._heap:
        if kind == "retry":
            releasable.add(payload[0])
        elif kind == "release":
            releasable.add(payload)
    for key, t_r in sorted(sched._hold.items()):
        if t_r > sched.state.now + 1e-9 and key not in releasable:
            v.append(f"held stage {key} (until {t_r:.6f}) has no "
                     f"pending retry/release event to lift the hold")
    # committed pool ------------------------------------------------------
    seen: set[StageKey] = set()
    for p in sched.committed:
        key = (p.wid, p.sid)
        if key in seen:
            v.append(f"duplicate commitment for {key}")
        seen.add(key)
        if key in sched.issued:
            v.append(f"stage {key} is both committed and issued")
        if p.wid in fr.completed and p.sid in fr.completed[p.wid]:
            v.append(f"committed stage {key} is already completed")
        for d in p.devices:
            if d in state.down:
                v.append(f"committed placement {key} targets downed "
                         f"device {d}")
    # frontier bookkeeping ------------------------------------------------
    if sorted(fr.completed) != sorted(fr.workflows):
        v.append("frontier completion sets out of sync with "
                 "workflow map")
    for name, idx in (("ready", fr._ready), ("unmet", fr._unmet),
                      ("topo-pos", fr._topo_pos)):
        if sorted(idx) != sorted(fr.workflows):
            v.append(f"frontier {name} index keys out of sync with "
                     f"workflow map")
    for wid, wf in fr.workflows.items():
        if wid not in sched._workflows_all:
            v.append(f"frontier workflow {wid} missing from the "
                     f"workflow registry")
        if wid not in sched._arrivals:
            v.append(f"frontier workflow {wid} has no recorded "
                     f"arrival")
        unknown = fr.completed.get(wid, set()) - set(wf.stages)
        if unknown:
            v.append(f"workflow {wid} completed unknown stage(s) "
                     f"{sorted(unknown)}")
        if wid in sched.stats:
            v.append(f"workflow {wid} is both in flight and "
                     f"finalized in stats")
    # indexed structures vs brute-force references ------------------------
    if fr.ready(set()) != fr.ready_reference(set()):
        v.append("frontier ready index diverges from the brute-force "
                 "DAG walk")
    c_keys = {(p.wid, p.sid) for p in sched.committed}
    if c_keys != sched._committed_keys:
        v.append("committed key index out of sync with the pool")
    feas: set[StageKey] = set()
    for p in sched.committed:
        key = (p.wid, p.sid)
        wf = fr.workflows.get(p.wid)
        if wf is None:
            continue
        done = fr.completed[p.wid]
        brute = sum(1 for par in wf.stages[p.sid].parents
                    if par not in done)
        if sched._commit_unmet.get(key) != brute:
            v.append(f"commit unmet-parent count for {key} is "
                     f"{sched._commit_unmet.get(key)}, expected "
                     f"{brute}")
        if brute == 0:
            feas.add(key)
    if feas != {k for k in sched._commit_feasible
                if k in c_keys and k[0] in fr.workflows}:
        v.append("commit feasibility index out of sync with the pool")
    by_dev: dict[int, set[StageKey]] = {}
    for p in sched.committed:
        for d in p.devices:
            by_dev.setdefault(d, set()).add((p.wid, p.sid))
    if by_dev != sched._committed_by_dev:
        v.append("committed by-device index out of sync with the pool")
    if set(sched._issued_devices) != sched.issued:
        v.append("issued device record out of sync with the issued "
                 "set")
    i_dev: dict[int, set[StageKey]] = {}
    i_wid: dict[str, set[StageKey]] = {}
    for key, devs in sched._issued_devices.items():
        i_wid.setdefault(key[0], set()).add(key)
        for d in devs:
            i_dev.setdefault(d, set()).add(key)
    if i_dev != sched._issued_by_dev:
        v.append("issued by-device index out of sync")
    if i_wid != sched._issued_by_wid:
        v.append("issued by-workflow index out of sync")
    # event ring accounting ----------------------------------------------
    ev = sched.events
    if ev.n_total != ev.n_dropped + len(ev):
        v.append(f"event ring accounting broken: n_total="
                 f"{ev.n_total} != n_dropped={ev.n_dropped} + "
                 f"retained={len(ev)}")
    if ev.maxlen is None and ev.n_dropped:
        v.append(f"uncapped event log dropped {ev.n_dropped} "
                 f"event(s)")
    if ev.maxlen is not None and len(ev) > ev.maxlen:
        v.append(f"event ring holds {len(ev)} > maxlen={ev.maxlen}")
    return v
