"""Baseline scheduling policies in the common runtime (Appendix B).

Signal access follows Table 7:

| policy       | residency | transfer | prefix | lookahead              |
|--------------|-----------|----------|--------|------------------------|
| RoundRobin   | no        | no       | no     | none                   |
| HEFT         | yes       | yes      | no     | upward-rank priority   |
| Helix-style  | yes       | yes      | no     | heterogeneity-aware EFT|
| KVFlow-style | yes       | partial  | yes    | cache/reuse priority   |
| Halo-style   | coarse    | no       | no     | beam search over DAG   |
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

from repro.core.costs import CostModel
from repro.core.planner import Placement
from repro.core.policies.base import BasePolicy, register_policy
from repro.core.state import ExecutionState
from repro.core.workflow import Stage, Workflow


# ---------------------------------------------------------------------------
# RoundRobin
# ---------------------------------------------------------------------------


@register_policy("RoundRobin")
class RoundRobinPolicy(BasePolicy):
    """State-blind round-robin placement over eligible devices."""

    name = "RoundRobin"

    def __init__(self) -> None:
        self._next = 0

    def plan(self, wf: Workflow, state: ExecutionState,
             ready: list[str]) -> list[Placement]:
        """Place each ready stage on the next eligible device."""
        out = []
        devices = state.cluster.ids()
        for sid in ready:
            st = wf.stages[sid]
            eligible = list(st.eligible) if st.eligible else devices
            d = eligible[self._next % len(eligible)]
            self._next += 1
            out.append(Placement(wf.wid, sid, (d,), (wf.num_queries,)))
        return out


# ---------------------------------------------------------------------------
# HEFT: upward rank + earliest finish time (with residency/transfer costs)
# ---------------------------------------------------------------------------


@register_policy("HEFT")
class HEFTPolicy(BasePolicy):
    """Upward-rank list scheduling with residency/transfer-aware
    earliest-finish placement (classic HEFT in the common runtime)."""

    name = "HEFT"

    def __init__(self) -> None:
        self._ranks: dict[str, dict[str, float]] = {}

    def _upward_ranks(self, wf: Workflow,
                      state: ExecutionState) -> dict[str, float]:
        if wf.wid in self._ranks:
            return self._ranks[wf.wid]
        devices = state.cluster.ids()
        q = wf.num_queries
        mean_cost = {
            sid: sum(wf.stages[sid].cost_on(d) for d in devices)
            / len(devices) * q
            for sid in wf.stages}
        # mean communication cost proxy
        beta = state.cluster.transfer_coef
        rank: dict[str, float] = {}
        for sid in reversed(wf.topo_order):
            st = wf.stages[sid]
            best_child = 0.0
            for ch in st.children:
                comm = beta * st.output_tokens * q / 1000.0
                best_child = max(best_child, comm + rank[ch])
            rank[sid] = mean_cost[sid] + best_child
        self._ranks[wf.wid] = rank
        return rank

    def plan(self, wf: Workflow, state: ExecutionState,
             ready: list[str]) -> list[Placement]:
        """Place ready stages in decreasing upward rank at EFT."""
        cm = CostModel(state)
        rank = self._upward_ranks(wf, state)
        q = wf.num_queries
        out = []
        free = dict(state.free_at)
        resident = dict(state.residency)
        for sid in sorted(ready, key=lambda s: -rank[s]):
            st = wf.stages[sid]
            devices = list(st.eligible) if st.eligible else \
                state.cluster.ids()

            def eft(d: int) -> float:
                dur = cm.base_cost(st, d, q)
                if resident.get(d) != st.model:
                    dur += state.profiles[st.model].switch_cost
                dur += cm.transfer_cost(wf, st, d, q)
                return max(free.get(d, 0.0), state.now) + dur

            best = min(devices, key=eft)
            free[best] = eft(best)
            resident[best] = st.model
            out.append(Placement(wf.wid, sid, (best,), (q,)))
        return out


# ---------------------------------------------------------------------------
# Helix-style: heterogeneity-aware earliest-finish placement
# ---------------------------------------------------------------------------


@register_policy("Helix")
class HelixPolicy(BasePolicy):
    """Heterogeneity-aware earliest-finish placement, heaviest
    stages first (Helix-style baseline)."""

    name = "Helix"

    def plan(self, wf: Workflow, state: ExecutionState,
             ready: list[str]) -> list[Placement]:
        """Place ready stages heaviest-first at earliest finish."""
        cm = CostModel(state)
        q = wf.num_queries
        out = []
        free = dict(state.free_at)
        resident = dict(state.residency)
        # heaviest stages first so slow devices don't capture them
        order = sorted(ready,
                       key=lambda s: -wf.stages[s].cost_on(-1))
        for sid in order:
            st = wf.stages[sid]
            devices = list(st.eligible) if st.eligible else \
                state.cluster.ids()

            def finish(d: int) -> float:
                dur = cm.base_cost(st, d, q)     # heterogeneity: /speed
                if resident.get(d) != st.model:
                    dur += state.profiles[st.model].switch_cost
                dur += cm.transfer_cost(wf, st, d, q)
                return max(free.get(d, 0.0), state.now) + dur

            best = min(devices, key=finish)
            free[best] = finish(best)
            resident[best] = st.model
            out.append(Placement(wf.wid, sid, (best,), (q,)))
        return out


# ---------------------------------------------------------------------------
# KVFlow-style: future-reuse-aware cache priority + greedy scheduling
# ---------------------------------------------------------------------------


@register_policy("KVFlow")
class KVFlowPolicy(BasePolicy):
    """Future-reuse-aware cache priority + greedy device scoring
    (KVFlow-style baseline)."""

    name = "KVFlow"

    def plan(self, wf: Workflow, state: ExecutionState,
             ready: list[str]) -> list[Placement]:
        """Place ready stages by cache-reuse priority and score."""
        cm = CostModel(state)
        q = wf.num_queries
        out = []
        free = dict(state.free_at)
        resident = dict(state.residency)

        def reuse_priority(sid: str) -> float:
            st = wf.stages[sid]
            pr = 0.0
            if st.prefix_group is not None and st.cache_reuse:
                pr += max(state.prefix_overlap(st, d, q)
                          for d in state.cluster.ids())
            # near-future steps of the same group raise retention value
            for ch in st.children:
                if wf.stages[ch].prefix_group == st.prefix_group \
                        and st.prefix_group is not None:
                    pr += 0.5
            return pr

        for sid in sorted(ready, key=lambda s: -reuse_priority(s)):
            st = wf.stages[sid]
            devices = list(st.eligible) if st.eligible else \
                state.cluster.ids()

            def kv_score(d: int) -> float:
                s = 0.0
                s += 2.0 * state.prefix_overlap(st, d, q) \
                    * cm.base_cost(st, d, q)
                if resident.get(d) == st.model:
                    s += state.profiles[st.model].switch_cost
                # partial transfer signal: parent colocation preference
                # only (no β-weighted cost)
                if st.parents:
                    s += 0.3 * state.parent_on_device(wf.wid, st, d)
                s -= max(free.get(d, 0.0), state.now) - state.now
                s -= cm.base_cost(st, d, q) * 0.1
                return s

            best = max(devices, key=kv_score)
            dur = cm.base_cost(st, best, q)
            if resident.get(best) != st.model:
                dur += state.profiles[st.model].switch_cost
            free[best] = max(free.get(best, 0.0), state.now) + dur
            resident[best] = st.model
            out.append(Placement(wf.wid, sid, (best,), (q,)))
        return out


# ---------------------------------------------------------------------------
# Halo-style: beam search over DAG assignments (coarse residency)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _BeamState:
    free: tuple[float, ...]
    resident: tuple[Optional[str], ...]
    assign: tuple[tuple[str, int], ...]
    fins: tuple[float, ...]          # finish time per assigned stage
    cost: float


@register_policy("Halo")
class HaloPolicy(BasePolicy):
    """Beam search over stage→device assignments in topological order.

    Residency is "coarse": a single average switch penalty, applied when
    the device's last model differs (Table 7 / Appendix B.1).  No
    transfer or prefix signals.
    """
    name = "Halo"

    def __init__(self, beam_width: int = 8):
        self.beam_width = beam_width
        self._plan_cache: dict[str, dict[str, int]] = {}

    def _search(self, wf: Workflow, state: ExecutionState) -> dict[str, int]:
        if wf.wid in self._plan_cache:
            return self._plan_cache[wf.wid]
        devices = state.cluster.ids()
        q = wf.num_queries
        avg_switch = (sum(p.switch_cost
                          for p in state.profiles.values())
                      / len(state.profiles))
        beam = [_BeamState(tuple(state.free_at[d] for d in devices),
                           tuple(state.residency[d] for d in devices),
                           (), (), 0.0)]
        stage_index = {sid: i for i, sid in enumerate(wf.topo_order)}
        for sid in wf.topo_order:
            st = wf.stages[sid]
            eligible = [devices.index(d) for d in
                        (st.eligible if st.eligible else devices)]
            nxt: list[_BeamState] = []
            for bs in beam:
                for j in eligible:
                    dur = st.cost_on(devices[j]) * q \
                        / state.cluster.devices[devices[j]].speed
                    if bs.resident[j] != st.model:
                        dur += avg_switch
                    # start after the device frees AND parents finish
                    start = bs.free[j]
                    for par in st.parents:
                        start = max(start, bs.fins[stage_index[par]])
                    fin = start + dur
                    free = list(bs.free)
                    free[j] = fin
                    res = list(bs.resident)
                    res[j] = st.model
                    nxt.append(_BeamState(
                        tuple(free), tuple(res),
                        bs.assign + ((sid, j),), bs.fins + (fin,),
                        max(bs.cost, fin)))
            nxt.sort(key=lambda b: (b.cost, sum(b.free)))
            beam = nxt[: self.beam_width]
        best = beam[0]
        plan = {sid: devices[j] for sid, j in best.assign}
        self._plan_cache[wf.wid] = plan
        return plan

    def plan(self, wf: Workflow, state: ExecutionState,
             ready: list[str]) -> list[Placement]:
        """Place ready stages per the cached beam-search plan."""
        plan = self._search(wf, state)
        return [Placement(wf.wid, sid, (plan[sid],), (wf.num_queries,))
                for sid in ready]
