"""Scheduling policies: the protocol, lifecycle mixin, registry, and
every in-repo implementation.

The :class:`Policy` protocol and the ``@register_policy`` registry
live in :mod:`repro.core.policies.base`; importing this package
imports the FATE policy and the five baselines, which registers them
as a side effect.  ``ALL_POLICIES`` is the registry itself, kept under
its historical name for back-compat with callers that treated it as a
plain dict.
"""
from repro.core.policies.base import (POLICY_REGISTRY, BasePolicy,
                                      Policy, make_policy,
                                      register_policy,
                                      registered_policies)
from repro.core.policies.fate import FATEPolicy
from repro.core.policies.baselines import (HEFTPolicy, HaloPolicy,
                                           HelixPolicy, KVFlowPolicy,
                                           RoundRobinPolicy)

#: Back-compat alias of the live registry (was a hand-written literal).
ALL_POLICIES = POLICY_REGISTRY

__all__ = [
    "ALL_POLICIES", "BasePolicy", "FATEPolicy", "HEFTPolicy",
    "HaloPolicy", "HelixPolicy", "KVFlowPolicy", "POLICY_REGISTRY",
    "Policy", "RoundRobinPolicy", "make_policy", "register_policy",
    "registered_policies",
]
