from repro.core.policies.fate import FATEPolicy
from repro.core.policies.baselines import (HEFTPolicy, HaloPolicy,
                                           HelixPolicy, KVFlowPolicy,
                                           RoundRobinPolicy)

ALL_POLICIES = {
    "FATE": FATEPolicy,
    "KVFlow": KVFlowPolicy,
    "Helix": HelixPolicy,
    "Halo": HaloPolicy,
    "HEFT": HEFTPolicy,
    "RoundRobin": RoundRobinPolicy,
}


def make_policy(name: str, **kwargs):
    return ALL_POLICIES[name](**kwargs)
