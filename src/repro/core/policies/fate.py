"""FATE scheduling policy: CP-SAT-backed frontier planning with
horizon-aware state-conditional scoring (the paper's method)."""
from __future__ import annotations

from typing import Optional

from repro.core.planner import FrontierPlanner, Placement
from repro.core.scoring import ScoreParams
from repro.core.state import ExecutionState
from repro.core.workflow import Workflow


class FATEPolicy:
    name = "FATE"

    def __init__(self, params: Optional[ScoreParams] = None,
                 time_limit: float = 5.0, use_matrix: bool = True):
        self.planner = FrontierPlanner(params, time_limit,
                                       use_matrix=use_matrix)
        self.params = self.planner.params

    def plan(self, wf: Workflow, state: ExecutionState,
             ready: list[str]) -> list[Placement]:
        return self.planner.plan(wf, state, ready)

    @property
    def solve_log(self):
        return self.planner.solve_log
