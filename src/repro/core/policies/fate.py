"""FATE scheduling policy: CP-SAT-backed frontier planning with
horizon-aware state-conditional scoring (the paper's method)."""
from __future__ import annotations

from typing import TYPE_CHECKING, Mapping, Optional, Sequence

from repro.core.costs import CostParams
from repro.core.planner import FrontierPlanner, Placement
from repro.core.policies.base import BasePolicy, register_policy
from repro.core.scoring import ScoreParams
from repro.core.state import ExecutionState
from repro.core.workflow import StageKey, Workflow

if TYPE_CHECKING:                                   # pragma: no cover
    from repro.core.scheduler import SchedulerConfig


@register_policy("FATE")
class FATEPolicy(BasePolicy):
    """The paper's future-state-aware policy: a thin lifecycle shell
    around :class:`~repro.core.planner.FrontierPlanner` (scoring
    engine + exact frontier solver)."""

    name = "FATE"
    # the scheduler may bias the shared solve with per-workflow class
    # weights (multi-class SLO configs); policies without this flag
    # are planned unweighted
    supports_priorities = True

    def __init__(self, params: Optional[ScoreParams] = None,
                 time_limit: float = 5.0, use_matrix: bool = True,
                 use_delta: bool = True, warm_start: bool = True,
                 cost_params: Optional[CostParams] = None,
                 max_waves: Optional[int] = None, pools=1,
                 routing=None):
        self.planner = FrontierPlanner(params, time_limit,
                                       use_matrix=use_matrix,
                                       use_delta=use_delta,
                                       warm_start=warm_start,
                                       cost_params=cost_params,
                                       max_waves=max_waves,
                                       pools=pools,
                                       routing=routing)
        self.params = self.planner.params

    @classmethod
    def from_config(cls, config: "SchedulerConfig",
                    cost_params: Optional[CostParams] = None
                    ) -> "FATEPolicy":
        """Thread the typed ``SchedulerConfig`` knobs (score params,
        planner switches, calibration-lowered cost params) into the
        planner; ``policy_kwargs`` entries override config fields so
        the deprecated kwarg path keeps its old meaning."""
        kwargs = dict(
            params=config.score, time_limit=config.time_limit,
            use_matrix=config.use_matrix, use_delta=config.use_delta,
            warm_start=config.warm_start, max_waves=config.max_waves,
            cost_params=cost_params,
            pools=getattr(config, "pools", 1),
            routing=getattr(config, "routing", None))
        kwargs.update(config.policy_kwargs)
        return cls(**kwargs)

    def plan(self, wf: Workflow, state: ExecutionState,
             ready: list[str]) -> list[Placement]:
        """Plan one workflow's ready frontier (batch setting)."""
        return self.planner.plan(wf, state, ready)

    def plan_shared(self, workflows: dict[str, Workflow],
                    state: ExecutionState,
                    ready: Sequence[StageKey],
                    priorities: Optional[Mapping[str, float]] = None
                    ) -> list[Placement]:
        """Serving mode: one merged frontier problem across DAGs
        (``priorities`` weights per-workflow objective rows)."""
        return self.planner.plan_shared(workflows, state, ready,
                                        priorities=priorities)

    def forget_workflow(self, wid: str) -> None:
        """Release per-workflow planner caches (workflow retired)."""
        self.planner.forget_workflow(wid)

    def on_device_down(self, device: int, state: ExecutionState) -> None:
        """Scrub warm-start hints targeting the downed device."""
        self.planner.drop_device_hints(device)

    @property
    def phase_ms(self):
        """Planner per-phase wall-time accumulators (benchmarks)."""
        return self.planner.phase_ms

    @property
    def solve_log(self):
        """Per-solve :class:`~repro.core.planner.SolveRecord` list."""
        return self.planner.solve_log
