"""FATE scheduling policy: CP-SAT-backed frontier planning with
horizon-aware state-conditional scoring (the paper's method)."""
from __future__ import annotations

from typing import Optional, Sequence

from repro.core.costs import CostParams
from repro.core.planner import FrontierPlanner, Placement
from repro.core.scoring import ScoreParams
from repro.core.state import ExecutionState
from repro.core.workflow import StageKey, Workflow


class FATEPolicy:
    name = "FATE"

    def __init__(self, params: Optional[ScoreParams] = None,
                 time_limit: float = 5.0, use_matrix: bool = True,
                 use_delta: bool = True, warm_start: bool = True,
                 cost_params: Optional[CostParams] = None):
        self.planner = FrontierPlanner(params, time_limit,
                                       use_matrix=use_matrix,
                                       use_delta=use_delta,
                                       warm_start=warm_start,
                                       cost_params=cost_params)
        self.params = self.planner.params

    def plan(self, wf: Workflow, state: ExecutionState,
             ready: list[str]) -> list[Placement]:
        """Plan one workflow's ready frontier (batch setting)."""
        return self.planner.plan(wf, state, ready)

    def plan_shared(self, workflows: dict[str, Workflow],
                    state: ExecutionState,
                    ready: Sequence[StageKey]) -> list[Placement]:
        """Serving mode: one merged frontier problem across DAGs."""
        return self.planner.plan_shared(workflows, state, ready)

    def forget_workflow(self, wid: str) -> None:
        """Release per-workflow planner caches (workflow retired)."""
        self.planner.forget_workflow(wid)

    @property
    def phase_ms(self):
        return self.planner.phase_ms

    @property
    def solve_log(self):
        return self.planner.solve_log
