"""Policy protocol, lifecycle mixin, and the pluggable policy registry.

The scheduler core (:mod:`repro.core.scheduler`) talks to policies
through one interface with an explicit lifecycle:

* :meth:`Policy.plan` — map a ready frontier to committed placements
  (the only REQUIRED method; everything else has no-op defaults);
* ``plan_shared(workflows, state, ready)`` — OPTIONAL merged
  multi-workflow planning; the serving runtime dispatches on its
  presence (``hasattr``), so policies without it are planned one DAG
  at a time.  It is deliberately absent from :class:`BasePolicy`: a
  no-op default would silently shadow the per-workflow fallback;
* :meth:`BasePolicy.on_arrival` / :meth:`BasePolicy.on_completion` /
  :meth:`BasePolicy.on_preempt` — event hooks the scheduler core
  invokes as workflows are admitted, stages complete, and committed
  placements are revoked, so stateful policies can maintain their own
  bookkeeping without subscribing to the event stream;
* :meth:`BasePolicy.forget_workflow` — cache release on retirement;
* :meth:`BasePolicy.from_config` — construct the policy from a
  :class:`~repro.core.scheduler.SchedulerConfig` (policies that expose
  tunables override it to thread the config's knobs into their
  constructor).

Registration: decorate a class with ``@register_policy("Name")`` and
it becomes constructible via :func:`make_policy` and usable as
``SchedulerConfig(policy="Name")``.  The registry replaces the old
hand-maintained ``ALL_POLICIES`` dict literal (which is now an alias
of the registry, kept for back-compat).
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Optional, Protocol, runtime_checkable

from repro.core.planner import Placement
from repro.core.state import ExecutionState
from repro.core.workflow import Workflow

if TYPE_CHECKING:                                   # pragma: no cover
    from repro.core.scheduler import SchedulerConfig


@runtime_checkable
class Policy(Protocol):
    """Scheduling policy interface: map a ready frontier to placements.

    Policies may additionally implement ``plan_shared(workflows,
    state, ready)`` (merged multi-workflow planning) and
    ``forget_workflow(wid)`` (cache release on retirement); the serving
    runtime dispatches on their presence.  Lifecycle hooks
    (``on_arrival`` / ``on_completion`` / ``on_preempt``) are invoked
    by the scheduler core when present — inherit :class:`BasePolicy`
    for no-op defaults.
    """

    name: str

    def plan(self, wf: Workflow, state: ExecutionState,
             ready: list[str]) -> list[Placement]:
        """Return committed placements for (a subset of) ``ready``."""
        ...


#: name -> policy class; populated by :func:`register_policy`.
POLICY_REGISTRY: dict[str, type] = {}


def register_policy(name: str):
    """Class decorator registering a policy under ``name``.

    The registered class must satisfy the :class:`Policy` protocol
    (a ``plan`` method and a ``name`` attribute).  Registration makes
    the class reachable through :func:`make_policy` and through
    ``SchedulerConfig(policy=name)``.  Re-registering a name replaces
    the previous entry (deliberate: downstream experiments may swap a
    variant in under the canonical name).
    """
    def deco(cls):
        if not hasattr(cls, "plan"):
            raise TypeError(
                f"@register_policy({name!r}): {cls.__name__} has no "
                f"plan() method and cannot satisfy the Policy protocol")
        cls.name = name
        POLICY_REGISTRY[name] = cls
        return cls
    return deco


def registered_policies() -> list[str]:
    """Sorted names of every registered policy."""
    return sorted(POLICY_REGISTRY)


def make_policy(name: str, **kwargs):
    """Construct a registered policy by name.

    Unknown names raise a ``KeyError`` that lists the registered
    alternatives (the old failure mode was an opaque dict
    ``KeyError``).  Keyword arguments go to the policy constructor
    unchanged.
    """
    try:
        cls = POLICY_REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown policy {name!r}; registered policies: "
            f"{', '.join(registered_policies())}") from None
    return cls(**kwargs)


class BasePolicy:
    """No-op lifecycle defaults every in-repo policy mixes in.

    Subclasses implement :meth:`plan`; the hook defaults keep
    simple policies one-method classes while the scheduler core can
    unconditionally drive the full lifecycle on any of them.
    ``plan_shared`` is intentionally NOT defined here — the serving
    runtime treats its presence as "this policy can solve a merged
    multi-workflow frontier", and a no-op default would disable the
    per-workflow fallback.
    """

    name = "base"

    def plan(self, wf: Workflow, state: ExecutionState,
             ready: list[str]) -> list[Placement]:
        """Return committed placements for (a subset of) ``ready``."""
        raise NotImplementedError(
            f"{type(self).__name__} must implement plan()")

    # -- lifecycle hooks (no-op defaults) --------------------------------
    def on_arrival(self, wf: Workflow, state: ExecutionState) -> None:
        """Hook: ``wf`` was admitted into the (shared) frontier."""

    def on_completion(self, wid: str, sid: str,
                      state: ExecutionState) -> None:
        """Hook: stage ``(wid, sid)`` completed on the runtime."""

    def on_preempt(self, revoked: list[Placement],
                   state: ExecutionState) -> None:
        """Hook: committed-but-unissued ``revoked`` placements were
        withdrawn (SLO-tight admission preempted the pool)."""

    def forget_workflow(self, wid: str) -> None:
        """Hook: release per-workflow caches (workflow retired)."""

    def on_device_down(self, device: int, state: ExecutionState) -> None:
        """Hook: ``device`` left the live set (crash or quarantine)."""

    def on_device_up(self, device: int, state: ExecutionState) -> None:
        """Hook: ``device`` rejoined the live set (recovery)."""

    # -- config-driven construction --------------------------------------
    @classmethod
    def from_config(cls, config: "SchedulerConfig",
                    cost_params=None) -> "BasePolicy":
        """Build the policy from a ``SchedulerConfig``.

        The default forwards ``config.policy_kwargs`` to the
        constructor; policies with richer tunables (FATE) override
        this to thread typed config fields (score params, planner
        switches, calibrated cost params) into their constructor.
        ``cost_params`` carries the calibration-lowered
        :class:`~repro.core.costs.CostParams` for policies that price
        placements themselves; the default ignores it.
        """
        return cls(**dict(config.policy_kwargs))
