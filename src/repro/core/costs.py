"""State-conditional cost estimation (paper §3.5, Appendix A.4).

    ĉ(v,d,s) = c_base(v,d) + Δ_switch + Δ_transfer
               − Δ_prefix − Δ_locality − Δ_parallel

This estimator is the single measurement layer feeding both the planner
score Ψ and the runtime scheduling score S — it is not a third
objective (paper §3.5).

Constants come from two places, both replaceable by a fitted
:class:`~repro.core.calibration.CalibrationProfile` (see
``docs/COSTMODEL.md``): per-model switch/prefill/decode coefficients
live on ``ExecutionState.profiles`` (:class:`ModelProfile`), and the
global correction-term scales live on :class:`CostParams` — a loaded
profile supplies both via ``model_profiles()`` / ``cost_params()``
instead of the hand-set defaults.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import numpy as np

from repro.core.devices import Cluster
from repro.core.state import ExecutionState
from repro.core.workflow import Stage, Workflow


@functools.lru_cache(maxsize=64)
def cluster_arrays(cluster: Cluster) -> tuple[np.ndarray, np.ndarray]:
    """Per-cluster (speed, transfer_scale) vectors indexed by device id.

    ``Cluster`` is a frozen dataclass, so the arrays are immutable facts
    of the topology; they are computed once and shared by every wave of
    the vectorized scoring engine.
    """
    speeds = np.array([d.speed for d in cluster.devices], dtype=float)
    tscale = np.array([d.transfer_scale for d in cluster.devices],
                      dtype=float)
    speeds.flags.writeable = False
    tscale.flags.writeable = False
    return speeds, tscale


@dataclasses.dataclass(frozen=True)
class CostParams:
    """Global scales of the correction terms (perturbed in Table 11).

    Hand-set defaults; a fitted
    :class:`~repro.core.calibration.CalibrationProfile` lowers its
    observation-weighted transfer and prefix-saving fits onto this
    object via ``cost_params()``.  Pass the result everywhere a
    ``CostParams`` is accepted (executors, :class:`CostModel`,
    ``FrontierPlanner``) so planner and runtime price with one set of
    constants.
    """
    switch_scale: float = 1.0
    transfer_scale: float = 1.0
    prefix_scale: float = 1.0
    prefix_saving: float = 0.9       # fraction of the prefill part saved
    locality_saving: float = 0.05    # activation-locality side benefit
    shard_overhead: float = 0.08     # per-extra-shard coordination overhead


@dataclasses.dataclass
class CostBreakdown:
    """Additive decomposition of one ĉ(v,d,s) estimate — the paper's
    §3.5 terms, kept separate so Ψ/EFT assembly can weight them
    individually."""
    base: float
    switch: float
    transfer: float
    prefix_benefit: float
    locality_benefit: float
    parallel_benefit: float

    @property
    def total(self) -> float:
        """ĉ(v,d,s): base + penalties − benefits."""
        return (self.base + self.switch + self.transfer
                - self.prefix_benefit - self.locality_benefit
                - self.parallel_benefit)


class CostModel:
    """State-conditional cost estimator ĉ(v,d,s) over one
    :class:`ExecutionState` view.

    Reads per-model constants from ``state.profiles`` and the global
    correction scales from ``params`` — so loading a calibration
    profile into both (see :mod:`repro.core.calibration`) recalibrates
    every consumer (scorer, planner waves, executor durations,
    admission floors) at once.  Stateless apart from those references:
    rebinding ``state`` repoints all component methods.

    ``profiles`` overrides the per-model constants WITHOUT touching the
    shared state — the calibration benchmark uses this to emulate
    ground-truth hardware whose real coefficients diverge from what the
    scheduler believes (executor durations priced from the override,
    planner/probes from ``state.profiles``).
    """

    def __init__(self, state: ExecutionState,
                 params: Optional[CostParams] = None,
                 profiles: Optional[dict] = None):
        self.state = state
        self.p = params or CostParams()
        self.profiles_override = profiles

    def model_profile(self, model: str):
        """Per-model constants this estimator prices with: the
        explicit override when set, else the shared state's profiles."""
        if self.profiles_override is not None:
            return self.profiles_override[model]
        return self.state.profiles[model]

    # -- components ------------------------------------------------------
    def base_cost(self, stage: Stage, device: int, queries: int) -> float:
        """c_base(v,d): the stage's device-profile cost × queries,
        scaled by the device's speed multiplier."""
        dev = self.state.cluster.devices[device]
        return stage.cost_on(device) * queries / dev.speed

    def switch_cost(self, stage: Stage, device: int) -> float:
        """κ_switch(m(v), d) if m(v) not resident on d, else 0."""
        if self.state.is_resident(stage.model, device):
            return 0.0
        prof = self.model_profile(stage.model)
        return prof.switch_cost * self.p.switch_scale

    def transfer_cost(self, wf: Workflow, stage: Stage, device: int,
                      queries: int) -> float:
        """Σ_parents 1[ℓ(u) != d] · β_{ℓ(u),d} · σ(u,v).

        σ(u,v) = parent-output token proxy × queries × comm weight; β is
        seconds per 1k tokens between distinct devices (Appendix C.1:
        "a constant edge-transfer coefficient").
        """
        total = 0.0
        for p in stage.parents:
            locs = self.state.output_loc.get((wf.wid, p), ())
            if not locs or device in locs:
                continue
            src = locs[0]
            beta = self.state.cluster.beta(src, device)
            parent = wf.stages[p]
            sigma_k_tokens = (parent.output_tokens * queries
                              * stage.comm_weight / 1000.0)
            total += beta * sigma_k_tokens
        return total * self.p.transfer_scale

    def prefix_benefit(self, stage: Stage, device: int,
                       queries: int) -> float:
        """Δ_prefix: prefill time saved by warm shared-prefix state on
        the device (0 when the stage's group/model has no overlap)."""
        ov = self.state.prefix_overlap(stage, device, queries)
        if ov <= 0.0:
            return 0.0
        base = self.base_cost(stage, device, queries)
        # a warm shared prefix saves (most of) the prefill part of the
        # stage; prefill_fraction comes from the runtime proxy profile
        return (base * stage.prefill_fraction * self.p.prefix_saving
                * ov * self.p.prefix_scale)

    def locality_benefit(self, wf: Workflow, stage: Stage, device: int,
                         queries: int) -> float:
        """B_colo: activation-locality side benefit, proportional to
        the fraction of parents whose output already sits on the
        device."""
        if not stage.parents:
            return 0.0
        frac = (self.state.parent_on_device(wf.wid, stage, device)
                / len(stage.parents))
        return (self.base_cost(stage, device, queries)
                * self.p.locality_saving * frac)

    def parallel_benefit(self, stage: Stage, devices: Sequence[int],
                         queries: int) -> float:
        """Completion-time reduction from sharding the query batch over
        k devices vs running on the single best of them."""
        if len(devices) <= 1:
            return 0.0
        solo = min(self.base_cost(stage, d, queries) for d in devices)
        shard = self.shard_completion_time(stage, devices, queries)
        return max(0.0, solo - shard)

    def shard_completion_time(self, stage: Stage, devices: Sequence[int],
                              queries: int) -> float:
        """Balanced query partition: completion = slowest shard, plus a
        per-extra-shard coordination overhead."""
        speeds = [self.state.cluster.devices[d].speed for d in devices]
        tot = sum(speeds)
        per_dev = [self.base_cost(stage, d, 1)
                   * _shard_size(queries, speeds, i, tot)
                   for i, d in enumerate(devices)]
        k = len(devices)
        base = min(self.base_cost(stage, d, queries) for d in devices)
        return max(per_dev) + base * self.p.shard_overhead * (k - 1)

    # -- composite ĉ ------------------------------------------------------
    def breakdown(self, wf: Workflow, stage: Stage, device: int,
                  queries: int) -> CostBreakdown:
        """Full per-term :class:`CostBreakdown` of placing the stage's
        query batch on one device (parallel benefit is a multi-device
        property and stays 0 here)."""
        return CostBreakdown(
            base=self.base_cost(stage, device, queries),
            switch=self.switch_cost(stage, device),
            transfer=self.transfer_cost(wf, stage, device, queries),
            prefix_benefit=self.prefix_benefit(stage, device, queries),
            locality_benefit=self.locality_benefit(wf, stage, device,
                                                   queries),
            parallel_benefit=0.0,
        )

    def effective_cost(self, wf: Workflow, stage: Stage, device: int,
                       queries: int) -> float:
        """Scalar ĉ(v,d,s) — :meth:`breakdown` collapsed to its total."""
        return self.breakdown(wf, stage, device, queries).total


def _shard_size(queries: int, speeds: list[float], i: int,
                tot: float) -> int:
    """Deterministic speed-proportional integer partition of queries."""
    lo = round(queries * sum(speeds[:i]) / tot)
    hi = round(queries * sum(speeds[: i + 1]) / tot)
    return max(0, hi - lo)


def shard_partition(queries: int, speeds: list[float]) -> list[int]:
    """Speed-proportional shard sizes for a query batch (sums to
    ``queries``; deterministic, so placements are reproducible)."""
    tot = sum(speeds)
    return [_shard_size(queries, speeds, i, tot)
            for i in range(len(speeds))]
