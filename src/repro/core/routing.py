"""Cost/quality model routing: planner-chosen model families per stage.

FATE's frontier solve assigns (stage-slot × device); routing widens the
assignment axis to (stage, **family**, device) — ECCOS-style: a stage
may declare alternate model families (``Stage.candidates`` as
``(alias, quality)`` pairs, quality relative to the default
``Stage.model``'s implicit 1.0), and the planner may serve it with any
candidate whose quality clears :attr:`RoutingConfig.quality_floor`,
priced through the calibrated per-family cost coefficients
(``ModelProfile.prefill_coef`` / ``decode_coef``).  Cheap-but-good
families win rows on score exactly like devices do, making serving
cost a scheduling objective alongside latency.

Mechanics
---------
* :func:`admissible_candidates` filters a stage's declared alternates
  against the floor (and the profile table) — deterministic order.
* :func:`variant_stage` builds the routed twin of a stage: same sid /
  topology / features, ``model`` swapped, ``base_cost`` scaled by
  :func:`family_cost_ratio` (prefill/decode coefficient ratios blended
  by the stage's ``prefill_fraction``).  Switch costs, residency, and
  the future tail all re-price automatically because every consumer
  reads them off ``stage.model`` via ``state.profiles``.
* The planner emits extra solver rows keyed ``(wid, sid, alias)`` next
  to the default ``(wid, sid)`` rows, under a solver-side mutual-
  exclusion constraint (``FrontierProblem.exclusive``): at most one
  family per stage may hold devices in a wave.

Routing **disabled** (``SchedulerConfig.routing is None`` or a stage
with no ``candidates``) adds no rows, no constraint groups, and no
branching — the solve is bit-identical to the unrouted planner by
construction (``tests/test_routing.py`` asserts it).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional

from repro.core.workflow import ModelProfile, Stage


@dataclasses.dataclass(frozen=True)
class RoutingConfig:
    """Cost/quality routing knobs (``SchedulerConfig.routing``).

    ``quality_floor`` is the hard per-stage constraint: a candidate
    family with declared quality below the floor is never offered to
    the solver (the default ``Stage.model`` has quality 1.0 and is
    always admissible).  ``max_candidates`` bounds the per-stage row
    blow-up on wide frontiers.
    """

    quality_floor: float = 0.9
    max_candidates: int = 4

    def to_dict(self) -> dict:
        """Plain-JSON document; inverse of :meth:`from_dict`."""
        return {"quality_floor": self.quality_floor,
                "max_candidates": self.max_candidates}

    @classmethod
    def from_dict(cls, doc: dict) -> "RoutingConfig":
        """Rebuild from :meth:`to_dict` output (tolerates missing
        keys: absent fields keep their defaults)."""
        return cls(
            quality_floor=float(doc.get("quality_floor", 0.9)),
            max_candidates=int(doc.get("max_candidates", 4)))


def family_cost_ratio(profiles: Mapping[str, ModelProfile],
                      base_model: str, alt_model: str,
                      prefill_fraction: float) -> float:
    """Per-query runtime ratio of ``alt_model`` vs ``base_model``.

    Blends the calibrated prefill/decode coefficient ratios by the
    stage's prefill share — the same decomposition the cost model's
    breakdown uses — so a routed stage's ``base_cost`` row scales to
    what the candidate family would actually cost on every device.
    """
    b = profiles[base_model]
    a = profiles[alt_model]
    pf = min(max(prefill_fraction, 0.0), 1.0)
    pre = a.prefill_coef / max(b.prefill_coef, 1e-12)
    dec = a.decode_coef / max(b.decode_coef, 1e-12)
    return pf * pre + (1.0 - pf) * dec


def admissible_candidates(stage: Stage, config: RoutingConfig,
                          profiles: Mapping[str, ModelProfile]
                          ) -> list[tuple[str, float]]:
    """Candidate families of ``stage`` that clear the quality floor.

    Preserves the stage's declaration order (deterministic solves),
    drops aliases without a profile entry or equal to the default
    model, and caps the list at ``config.max_candidates``.  Empty when
    the stage declares no alternates — routing never touches it.
    """
    if not stage.candidates:
        return []
    out: list[tuple[str, float]] = []
    for alias, quality in stage.candidates:
        if alias == stage.model or alias not in profiles:
            continue
        if quality + 1e-12 < config.quality_floor:
            continue
        out.append((alias, quality))
        if len(out) >= config.max_candidates:
            break
    return out


def variant_stage(stage: Stage, alias: str,
                  profiles: Mapping[str, ModelProfile]) -> Stage:
    """Routed twin of ``stage`` served by family ``alias``.

    Same sid / parents / children / features (so topology lookups and
    the scorer's descendant walks keyed by sid stay valid), with
    ``model`` swapped and the ``base_cost`` profile scaled by
    :func:`family_cost_ratio`.  ``candidates`` is cleared — a variant
    is a leaf, never re-routed.
    """
    ratio = family_cost_ratio(profiles, stage.model, alias,
                              stage.prefill_fraction)
    base_cost = {d: c * ratio for d, c in stage.base_cost.items()}
    return dataclasses.replace(stage, model=alias, base_cost=base_cost,
                               candidates=())


class StageRouter:
    """Per-planner cache of routed stage variants.

    Variants are pure functions of (stage object identity, alias,
    profile table), so they are memoized per ``(wid, sid, alias)`` and
    invalidated when the stage object changes (topology mutation builds
    new ``Stage`` objects via ``Workflow.invalidate_topology``'s
    rewiring, and a replaced stage object never matches ``is``).
    """

    def __init__(self, config: RoutingConfig):
        self.config = config
        self._cache: dict[tuple, tuple] = {}

    def candidates(self, wid: str, stage: Stage,
                   profiles: Mapping[str, ModelProfile]
                   ) -> list[tuple[str, float, Stage]]:
        """``(alias, quality, variant)`` triples for one stage."""
        key = (wid, stage.sid)
        hit = self._cache.get(key)
        if hit is not None and hit[0] is stage:
            return hit[1]
        out = [(alias, quality, variant_stage(stage, alias, profiles))
               for alias, quality in admissible_candidates(
                   stage, self.config, profiles)]
        self._cache[key] = (stage, out)
        return out

    def variant(self, wid: str, stage: Stage, alias: str,
                profiles: Mapping[str, ModelProfile]
                ) -> Optional[Stage]:
        """The cached routed twin for ``alias`` (None if not
        admissible) — the issue path resolves ``Placement.model``
        through this so planning and execution price one stage."""
        for a, _q, var in self.candidates(wid, stage, profiles):
            if a == alias:
                return var
        return None

    def forget_workflow(self, wid: str) -> None:
        """Drop a retired workflow's cached variants."""
        for key in [k for k in self._cache if k[0] == wid]:
            del self._cache[key]
