"""Write-ahead event journal + snapshot store for the durable
control plane.

The :class:`~repro.core.scheduler.Scheduler` is a deterministic state
machine: given the same config, cluster, submissions, and fault plan,
every ``step()`` regenerates the same typed event batch.  Durability
therefore needs only two artifacts, both owned by this module:

* **The journal** — an :class:`EventJournal` directory of JSONL
  segment files (``events-00000.jsonl``, ...).  After each ``step()``
  the scheduler appends the batch's events (one
  ``SchedulerEvent.to_dict()`` document per line, tagged with its
  absolute stream index ``"i"``) before the step is considered
  committed.  Appends are contiguity-checked, optionally
  ``fsync``-ed per batch, and rotate to a fresh segment past
  ``rotate_bytes``.
* **Snapshots** — versioned JSON checkpoints of the full scheduler
  state (:meth:`~repro.core.scheduler.Scheduler.snapshot`), stored
  alongside the segments as ``snapshot-<n_total>.json`` and pruned to
  the most recent few.

Crash recovery (:meth:`~repro.core.scheduler.Scheduler.restore`) loads
the latest snapshot and *re-steps* the scheduler, verifying each
regenerated event against the journal tail — replay is regeneration
plus an equality audit, not blind event application.  A torn final
line (the process died mid-append) is expected: it is detected,
logged, and truncated when the journal is reopened for writing, and
reads simply stop before it.  Corruption anywhere *else* raises
:class:`JournalError` — a torn tail is the only damage a crash can
legally inflict.

See ``docs/RECOVERY.md`` for the on-disk format and the recovery
semantics contract.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Iterator, Optional


class JournalError(RuntimeError):
    """A journal structural violation: non-contiguous append, mid-file
    corruption, or a journal that is behind the snapshot it should
    extend."""


def _segment_index(path: Path) -> int:
    """Numeric index of an ``events-NNNNN.jsonl`` segment path."""
    return int(path.stem.split("-", 1)[1])


class EventJournal:
    """Append-only write-ahead log of scheduler events, plus the
    snapshot store, in one directory.

    Layout::

        <dir>/events-00000.jsonl     # one event per line, oldest first
        <dir>/events-00001.jsonl     # opened when the previous segment
        ...                          #   passed ``rotate_bytes``
        <dir>/snapshot-00000042.json # Scheduler.snapshot() at event 42

    Every line is ``SchedulerEvent.to_dict()`` plus ``"i"``, the
    event's absolute position on the scheduler's event stream
    (``EventLog.n_total`` order).  :attr:`next_index` is the position
    the next appended event must carry — :meth:`append_batch` refuses
    gaps, so the journal is always a contiguous prefix of the stream.

    Opening an existing directory scans it, truncates a torn final
    line if the previous writer died mid-append (recorded on
    :attr:`recovered_torn_tail`), and resumes at the right index.
    ``fsync=True`` flushes every batch to stable storage before
    :meth:`append_batch` returns (the durable-by-default mode;
    leaving it off trades the last batch for speed).
    """

    def __init__(self, path, *, fsync: bool = False,
                 rotate_bytes: Optional[int] = None):
        self.dir = Path(path)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.fsync = fsync
        self.rotate_bytes = rotate_bytes
        self.next_index = 0
        self.recovered_torn_tail = False
        segs = self._segments()
        if segs:
            self._truncate_torn_tail(segs[-1])
            for _ in self.read():
                pass                     # validates + sets next_index
        else:
            (self.dir / "events-00000.jsonl").touch()

    # -- segments --------------------------------------------------------
    def _segments(self) -> list[Path]:
        """Existing segment paths, oldest first."""
        return sorted(self.dir.glob("events-*.jsonl"),
                      key=_segment_index)

    def _truncate_torn_tail(self, seg: Path) -> None:
        """Drop a torn (non-JSON or unterminated) final line from the
        last segment so appends resume on a clean boundary."""
        raw = seg.read_bytes()
        if not raw:
            return
        cut = len(raw)
        if not raw.endswith(b"\n"):
            cut = raw.rfind(b"\n") + 1   # 0 when no newline at all
        else:
            last = raw.rstrip(b"\n").rsplit(b"\n", 1)[-1]
            try:
                doc = json.loads(last)
                if not isinstance(doc, dict) or "i" not in doc:
                    raise ValueError("not an event record")
            except ValueError:
                cut = len(raw.rstrip(b"\n")) - len(last)
        if cut < len(raw):
            seg.write_bytes(raw[:cut])
            self.recovered_torn_tail = True

    # -- writes ----------------------------------------------------------
    def append_batch(self, events, start_index: int) -> None:
        """Append ``events`` (a sequence of ``SchedulerEvent``) whose
        first element has absolute stream index ``start_index``.

        Raises :class:`JournalError` when ``start_index`` does not
        equal :attr:`next_index` — the caller lost events (e.g. a ring
        buffer evicted un-journaled entries) and the journal would no
        longer be a contiguous prefix of the stream.
        """
        if start_index != self.next_index:
            raise JournalError(
                f"non-contiguous append: journal expects index "
                f"{self.next_index}, got {start_index}")
        if not events:
            return
        seg = self._segments()[-1]
        if (self.rotate_bytes is not None
                and seg.stat().st_size >= self.rotate_bytes):
            seg = self.dir / f"events-{_segment_index(seg) + 1:05d}.jsonl"
        lines = []
        for off, ev in enumerate(events):
            doc = ev.to_dict()
            doc["i"] = start_index + off
            lines.append(json.dumps(doc, sort_keys=True))
        with seg.open("a", encoding="utf-8") as fh:
            fh.write("\n".join(lines) + "\n")
            fh.flush()
            if self.fsync:
                os.fsync(fh.fileno())
        self.next_index = start_index + len(events)

    # -- reads -----------------------------------------------------------
    def read(self, start: int = 0) -> Iterator[tuple]:
        """Yield ``(index, event)`` for every journaled event with
        absolute index ``>= start``, oldest first.

        Validates contiguity as it goes and leaves :attr:`next_index`
        at one past the last valid entry.  A torn final line in the
        final segment ends iteration silently (the crash case);
        damage anywhere else raises :class:`JournalError`.
        """
        from repro.core.scheduler import SchedulerEvent
        segs = self._segments()
        expect: Optional[int] = None
        for si, seg in enumerate(segs):
            last_seg = si == len(segs) - 1
            lines = seg.read_text(encoding="utf-8").splitlines()
            for li, line in enumerate(lines):
                if not line.strip():
                    continue
                try:
                    doc = json.loads(line)
                    idx = int(doc["i"])
                    ev = SchedulerEvent.from_dict(doc)
                except (ValueError, KeyError, TypeError) as exc:
                    if last_seg and li == len(lines) - 1:
                        return           # torn tail: stop cleanly
                    raise JournalError(
                        f"{seg.name}:{li + 1}: corrupt journal entry "
                        f"({exc})") from exc
                if expect is not None and idx != expect:
                    raise JournalError(
                        f"{seg.name}:{li + 1}: event index {idx} "
                        f"breaks contiguity (expected {expect})")
                expect = idx + 1
                self.next_index = expect
                if idx >= start:
                    yield idx, ev

    def entries(self, start: int = 0) -> list:
        """Materialized :meth:`read` — ``[(index, event), ...]``."""
        return list(self.read(start))

    def __len__(self) -> int:
        return self.next_index

    # -- snapshots -------------------------------------------------------
    def write_snapshot(self, doc: dict, *, keep: int = 2) -> Path:
        """Persist one ``Scheduler.snapshot()`` document, pruning all
        but the newest ``keep`` snapshots; returns the written path.

        The filename embeds the snapshot's event-stream position so
        :meth:`latest_snapshot` can pick the newest without parsing,
        and so a snapshot is only meaningful alongside the journal
        that extends it.
        """
        n = int(doc.get("events", {}).get("n_total", 0))
        path = self.dir / f"snapshot-{n:08d}.json"
        tmp = path.with_suffix(".json.tmp")
        tmp.write_text(json.dumps(doc, sort_keys=True), encoding="utf-8")
        os.replace(tmp, path)            # atomic publish
        snaps = self._snapshots()
        for old in snaps[:-keep] if keep > 0 else []:
            old.unlink()
        return path

    def _snapshots(self) -> list[Path]:
        return sorted(self.dir.glob("snapshot-*.json"))

    def latest_snapshot(self) -> Optional[dict]:
        """The most recent snapshot document (``None`` when no
        snapshot has been written yet)."""
        snaps = self._snapshots()
        if not snaps:
            return None
        return json.loads(snaps[-1].read_text(encoding="utf-8"))

    def __repr__(self) -> str:
        return (f"EventJournal({str(self.dir)!r}, "
                f"next_index={self.next_index})")
