"""Asyncio HTTP serving gateway over one or more Scheduler replicas.

A stdlib-only front door for the control plane: a minimal HTTP/1.1
server (``asyncio`` streams, ``Connection: close`` per request — no
web framework, no new runtime dependencies) that turns the in-process
:class:`~repro.core.scheduler.Scheduler` API into a service:

* ``POST /v1/workflows`` — submit a workflow DAG as JSON (the
  :meth:`~repro.core.workflow.Workflow.to_dict` document under
  ``"workflow"``, plus optional ``"at"`` / ``"deadline"`` /
  ``"klass"``).  Returns ``202`` with the chosen replica.
* ``GET /v1/workflows/{wid}/events`` — stream that workflow's typed
  :class:`~repro.core.scheduler.SchedulerEvent` records as NDJSON
  (one versioned ``to_dict`` document per line), driving the clock
  lazily until the workflow reaches a terminal event.
* ``GET /v1/metrics`` — live ``serving_summary`` / ``slo_summary`` /
  ``class_summary`` counters over the merged provisional results of
  all replicas (read-only: never advances any replica's clock).
* ``POST /v1/drain`` — run every replica to quiescence, finalize, and
  return per-replica event fingerprints plus the merged summary.

Replica tier: ``replicas=N`` load-balances submissions by
least-backlog (queued arrivals + live frontier + admission backlog),
with admission-probe feedback — a replica that has been rejecting
recent arrivals is penalized so load drifts toward replicas whose
probes still admit.  With a single replica the gateway adds no
scheduling decisions of its own: a POST-then-drain run is
bit-identical (events, placements, fingerprint) to driving the same
:class:`Scheduler` directly, which ``sched_bench --gateway`` gates.

Determinism note: the gateway never steps a replica on submission.
The clock only advances while a client drains it (``/v1/drain``) or
follows an event stream, so explicit-``at`` submissions reproduce a
trace-driven run exactly.  Submissions without ``"at"`` are stamped
with wall-clock seconds since the first such arrival (see
:func:`repro.workflowbench.metrics.rebase_result` for how summaries
normalize that offset away).
"""
from __future__ import annotations

import argparse
import asyncio
import hashlib
import json
import math
import threading
from typing import Callable, Optional

from repro.core.scheduler import (CompletionEvent, DegradedEvent,
                                  RejectedEvent, Scheduler,
                                  SchedulerConfig, ServingResult)
from repro.core.workflow import Workflow
from repro.workflowbench.metrics import (class_summary, rebase_result,
                                         serving_summary, slo_summary)

__all__ = ["Gateway", "GatewayServer", "scheduler_fingerprint", "main"]


def scheduler_fingerprint(sched: Scheduler) -> str:
    """Deterministic digest of a run's observable outcome.

    SHA-256 over every retained event's versioned ``to_dict`` document
    (in emission order) plus the sorted issued-run records (stage key,
    devices, shard sizes, routed model, start, finish).  Two runs with
    equal fingerprints made the same decisions at the same times —
    the equality the single-replica gateway parity gate asserts
    against a direct :class:`Scheduler` run.
    """
    h = hashlib.sha256()
    for ev in sched.events:
        h.update(json.dumps(ev.to_dict(), sort_keys=True).encode())
    for key in sorted(sched.runs):
        r = sched.runs[key]
        doc = [list(key), list(r.placement.devices),
               list(r.placement.shard_sizes), r.placement.model,
               round(r.start, 9), round(r.finish, 9)]
        h.update(json.dumps(doc).encode())
    return h.hexdigest()


def _json_safe(obj):
    """Recursively replace NaN/inf floats with None (strict JSON)."""
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, dict):
        return {k: _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    return obj


def _is_terminal(ev, wid: str) -> bool:
    """True when ``ev`` ends workflow ``wid``'s lifecycle."""
    if isinstance(ev, CompletionEvent):
        return ev.wid == wid and ev.workflow_done
    if isinstance(ev, RejectedEvent):
        return ev.wid == wid
    if isinstance(ev, DegradedEvent):
        return ev.kind == "gave_up" and ev.wid == wid
    return False


class _Replica:
    """One backend scheduler plus the gateway's routing bookkeeping."""

    def __init__(self, index: int, sched: Scheduler):
        self.index = index
        self.sched = sched
        self.n_submitted = 0
        # rejections already charged by the feedback penalty, so only
        # the DELTA since the last probe counts against the replica
        self.seen_rejects = 0

    def backlog(self) -> float:
        """Live load estimate: queued arrivals + frontier + admission
        backlog, plus a rejection-delta penalty (admission-probe
        feedback — a replica shedding recent load is overcommitted
        regardless of its queue length)."""
        s = self.sched
        load = (len(s._arrivals_q) + len(s._heap)
                + len(s.frontier.workflows))
        adm = s.admission
        if adm is not None:
            load += len(getattr(adm, "backlog", ()) or ())
            fresh = len(adm.rejected) - self.seen_rejects
            if fresh > 0:
                load += 4 * fresh
                self.seen_rejects = len(adm.rejected)
        return load


class Gateway:
    """N scheduler replicas behind one HTTP front door.

    ``make_scheduler`` is a zero-argument factory producing identically
    configured :class:`Scheduler` instances (one per replica); replicas
    share nothing, so per-replica runs stay independently
    deterministic.  All request handling runs on a single asyncio
    event loop — replicas are only ever touched from that loop, so no
    locking is needed.
    """

    def __init__(self, make_scheduler: Callable[[], Scheduler],
                 replicas: int = 1):
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        self.replicas = [_Replica(i, make_scheduler())
                         for i in range(replicas)]
        self._owner: dict[str, _Replica] = {}
        self._epoch: Optional[float] = None
        self._drained = False

    @classmethod
    def from_config(cls, cluster, config: SchedulerConfig) -> "Gateway":
        """Build a gateway from a cluster and a
        :class:`SchedulerConfig` whose ``gateway`` mapping supplies
        the tier options (currently ``{"replicas": N}``)."""
        gw = dict(config.gateway or {})
        replicas = int(gw.get("replicas", 1))
        return cls(lambda: Scheduler(cluster, config), replicas)

    # -- submission ------------------------------------------------------
    def _pick_replica(self) -> _Replica:
        """Least-backlog replica (stable index order breaks ties, so a
        single replica — or an all-idle tier — routes like a direct
        scheduler run)."""
        return min(self.replicas, key=lambda r: (r.backlog(), r.index))

    def submit(self, doc: dict) -> dict:
        """Handle one ``POST /v1/workflows`` body (already parsed).

        Never steps any replica: the submission lands on the chosen
        replica's arrival queue and the clock stays put, preserving
        bit-parity with a trace-driven run.  Raises ``ValueError`` on
        a malformed document and ``RuntimeError`` after drain.
        """
        if self._drained:
            raise RuntimeError("gateway is drained; submissions closed")
        if not isinstance(doc, dict) or "workflow" not in doc:
            raise ValueError('body must be {"workflow": {...}, ...}')
        wf = Workflow.from_dict(doc["workflow"])
        at = doc.get("at")
        if at is None:
            # wall-clock arrival: seconds since the first such arrival
            import time
            if self._epoch is None:
                self._epoch = time.monotonic()
            at = time.monotonic() - self._epoch
        rep = self._pick_replica()
        wid = rep.sched.submit(
            wf, at=float(at), deadline=doc.get("deadline"),
            klass=doc.get("klass", "default"))
        rep.n_submitted += 1
        self._owner[wid] = rep
        return {"wid": wid, "replica": rep.index, "at": float(at)}

    # -- results ---------------------------------------------------------
    def merged_result(self) -> ServingResult:
        """Union of every replica's provisional
        :meth:`~repro.core.scheduler.Scheduler.peek_result`, rebased
        onto the scheduler clock (wall-clock arrivals normalized in
        one place via :func:`rebase_result`)."""
        parts = [r.sched.peek_result() for r in self.replicas]
        merged = parts[0]
        if len(parts) > 1:
            import dataclasses
            stats = {}
            classes = {}
            rejected, failed = [], []
            for p in parts:
                stats.update(p.stats)
                classes.update(p.classes)
                rejected += list(p.rejected)
                failed += list(p.failed)
            merged = dataclasses.replace(
                parts[0], stats=stats, classes=classes,
                rejected=rejected, failed=failed,
                horizon=max(p.horizon for p in parts),
                max_in_flight=sum(p.max_in_flight for p in parts),
                replans=sum(p.replans for p in parts),
                model_switches=sum(p.model_switches for p in parts),
                deferrals=sum(p.deferrals for p in parts),
                preemptions=sum(p.preemptions for p in parts),
                device_downs=sum(p.device_downs for p in parts),
                shard_failures=sum(p.shard_failures for p in parts),
                retries=sum(p.retries for p in parts),
                stragglers=sum(p.stragglers for p in parts),
                speculations=sum(p.speculations for p in parts),
                shard_preemptions=sum(p.shard_preemptions
                                      for p in parts))
        return rebase_result(merged)

    def metrics(self) -> dict:
        """Handle ``GET /v1/metrics``: live counters without advancing
        any replica's clock."""
        res = self.merged_result()
        doc = {
            "replicas": [{
                "index": r.index, "now": r.sched.now,
                "submitted": r.n_submitted,
                "backlog": r.backlog(),
                "in_frontier": len(r.sched.frontier.workflows),
                "completed": len(r.sched.stats),
                "rejected": (len(r.sched.admission.rejected)
                             if r.sched.admission is not None else 0),
                "events": r.sched.events.n_total,
                "events_dropped": r.sched.events.n_dropped,
            } for r in self.replicas],
            "serving": serving_summary({"gateway": res})["gateway"],
            "slo": slo_summary({"gateway": res})["gateway"],
            "classes": class_summary(res),
        }
        return _json_safe(doc)

    def drain(self) -> dict:
        """Handle ``POST /v1/drain``: run every replica to quiescence,
        finalize (subsequent submissions get ``409``), and report
        per-replica fingerprints plus the merged summary."""
        for r in self.replicas:
            r.sched.drain()
        self._drained = True
        doc = {
            "replicas": [{
                "index": r.index,
                "fingerprint": scheduler_fingerprint(r.sched),
                "n_events": r.sched.events.n_total,
                "n_events_dropped": r.sched.events.n_dropped,
                "completed": len(r.sched.stats),
            } for r in self.replicas],
            "metrics": self.metrics(),
        }
        return _json_safe(doc)

    # -- HTTP plumbing ---------------------------------------------------
    async def handle(self, reader: asyncio.StreamReader,
                     writer: asyncio.StreamWriter) -> None:
        """Serve one HTTP/1.1 request on an accepted connection
        (``Connection: close``; the asyncio server passes this as its
        client callback)."""
        try:
            request = await reader.readline()
            parts = request.decode("latin-1").split()
            if len(parts) < 2:
                return
            method, target = parts[0], parts[1]
            headers = {}
            while True:
                line = await reader.readline()
                if line in (b"\r\n", b"\n", b""):
                    break
                k, _, v = line.decode("latin-1").partition(":")
                headers[k.strip().lower()] = v.strip()
            n = int(headers.get("content-length", 0) or 0)
            body = await reader.readexactly(n) if n else b""
            await self._route(method, target, body, writer)
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        except Exception as exc:  # survive bad requests, keep serving
            try:
                _respond(writer, 500, {"error": f"{type(exc).__name__}:"
                                                f" {exc}"})
            except Exception:
                pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except Exception:
                pass

    async def _route(self, method: str, target: str, body: bytes,
                     writer: asyncio.StreamWriter) -> None:
        target = target.split("?", 1)[0]
        if method == "POST" and target == "/v1/workflows":
            try:
                out = self.submit(json.loads(body.decode() or "null"))
            except (ValueError, KeyError, TypeError) as exc:
                _respond(writer, 400, {"error": str(exc)})
            except RuntimeError as exc:
                _respond(writer, 409, {"error": str(exc)})
            else:
                _respond(writer, 202, out)
        elif method == "GET" and target == "/v1/metrics":
            _respond(writer, 200, self.metrics())
        elif method == "POST" and target == "/v1/drain":
            _respond(writer, 200, self.drain())
        elif (method == "GET" and target.startswith("/v1/workflows/")
                and target.endswith("/events")):
            wid = target[len("/v1/workflows/"):-len("/events")]
            await self._stream_events(wid, writer)
        else:
            _respond(writer, 404, {"error": f"no route for "
                                            f"{method} {target}"})
        await writer.drain()

    async def _stream_events(self, wid: str,
                             writer: asyncio.StreamWriter) -> None:
        """NDJSON event stream for one workflow: replay the retained
        history, then lazily step the owning replica until the
        workflow's terminal event (or quiescence).  A ring-buffer
        eviction the consumer has not seen emits an ``{"error": ...}``
        line and closes — a gap must never pass silently."""
        rep = self._owner.get(wid)
        if rep is None:
            _respond(writer, 404, {"error": f"unknown workflow {wid!r}"})
            return
        sched = rep.sched
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: application/x-ndjson\r\n"
                     b"Connection: close\r\n\r\n")
        cursor = 0
        done = False
        while True:
            if cursor < sched.events.n_dropped:
                gap = sched.events.n_dropped - cursor
                writer.write(json.dumps({
                    "error": f"event stream gap: {gap} event(s) "
                             f"evicted from the ring (event_buffer="
                             f"{sched.events.maxlen}) before this "
                             f"consumer read them"}).encode() + b"\n")
                await writer.drain()
                return
            new = sched.events.since(cursor)
            cursor = sched.events.n_total
            for ev in new:
                if getattr(ev, "wid", None) == wid or \
                        getattr(ev, "trigger_wid", None) == wid:
                    writer.write(json.dumps(ev.to_dict()).encode()
                                 + b"\n")
                    if _is_terminal(ev, wid):
                        done = True
            await writer.drain()
            if done:
                return
            if not sched.step():
                return
            await asyncio.sleep(0)  # yield so other requests interleave


def _respond(writer: asyncio.StreamWriter, status: int,
             doc: dict) -> None:
    """Write one complete JSON response (Connection: close)."""
    reason = {200: "OK", 202: "Accepted", 400: "Bad Request",
              404: "Not Found", 409: "Conflict",
              500: "Internal Server Error"}.get(status, "")
    body = json.dumps(_json_safe(doc)).encode()
    writer.write((f"HTTP/1.1 {status} {reason}\r\n"
                  f"Content-Type: application/json\r\n"
                  f"Content-Length: {len(body)}\r\n"
                  f"Connection: close\r\n\r\n").encode() + body)


class GatewayServer:
    """Run a :class:`Gateway` on a background thread with its own
    asyncio event loop (benchmarks and the smoke target talk to it
    over real sockets from the calling thread).

    Usable as a context manager; :attr:`port` holds the bound port
    after :meth:`start` (pass ``port=0`` for an ephemeral one).
    """

    def __init__(self, gateway: Gateway, host: str = "127.0.0.1",
                 port: int = 0):
        self.gateway = gateway
        self.host = host
        self.port = port
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "GatewayServer":
        """Bind and serve on a daemon thread; returns after the socket
        is listening (``port`` is then the real bound port)."""
        started = threading.Event()

        def run() -> None:
            loop = asyncio.new_event_loop()
            asyncio.set_event_loop(loop)
            self._loop = loop
            server = loop.run_until_complete(asyncio.start_server(
                self.gateway.handle, self.host, self.port))
            self.port = server.sockets[0].getsockname()[1]
            started.set()
            try:
                loop.run_forever()
            finally:
                server.close()
                loop.run_until_complete(server.wait_closed())
                loop.close()

        self._thread = threading.Thread(target=run, daemon=True,
                                        name="gateway-server")
        self._thread.start()
        started.wait()
        return self

    def stop(self) -> None:
        """Stop the event loop and join the server thread."""
        if self._loop is not None:
            self._loop.call_soon_threadsafe(self._loop.stop)
        if self._thread is not None:
            self._thread.join(timeout=10.0)
        self._loop = None
        self._thread = None

    def __enter__(self) -> "GatewayServer":
        """Start on context entry."""
        return self.start()

    def __exit__(self, *exc) -> None:
        """Stop on context exit."""
        self.stop()


# ---------------------------------------------------------------------------
# CLI / smoke
# ---------------------------------------------------------------------------


def _smoke_workflow(wid: str = "smoke-0") -> Workflow:
    """Tiny three-stage chain used by ``--smoke``."""
    from repro.core.workflow import Stage
    stages = {
        "plan": Stage("plan", "qwen-7b", base_cost={-1: 0.4}),
        "exec": Stage("exec", "llama-8b", base_cost={-1: 0.3},
                      parents=("plan",)),
        "judge": Stage("judge", "qwen-7b", base_cost={-1: 0.2},
                       parents=("exec",)),
    }
    return Workflow(wid, stages, num_queries=4)


def _smoke(args) -> int:
    """Boot an ephemeral gateway, push one workflow over real HTTP,
    drain the event stream, and verify nothing was dropped.  Returns
    a process exit code (nonzero on ANY dropped or missing event) —
    the ``make gateway-smoke`` gate."""
    import http.client

    from repro.core.devices import heterogeneous_cluster

    cluster = heterogeneous_cluster(4)
    config = SchedulerConfig()
    gateway = Gateway(lambda: Scheduler(cluster, config),
                      replicas=args.replicas)
    with GatewayServer(gateway, host=args.host, port=args.port) as srv:
        conn = http.client.HTTPConnection(srv.host, srv.port, timeout=30)
        wf = _smoke_workflow()
        conn.request("POST", "/v1/workflows",
                     body=json.dumps({"workflow": wf.to_dict(),
                                      "at": 0.0}),
                     headers={"Content-Type": "application/json"})
        resp = conn.getresponse()
        sub = json.loads(resp.read())
        if resp.status != 202:
            print(f"gateway-smoke: submit failed ({resp.status}): {sub}")
            return 1
        conn.close()

        conn = http.client.HTTPConnection(srv.host, srv.port, timeout=30)
        conn.request("GET", f"/v1/workflows/{sub['wid']}/events")
        resp = conn.getresponse()
        lines = [ln for ln in resp.read().decode().splitlines() if ln]
        conn.close()
        events = [json.loads(ln) for ln in lines]
        errors = [e for e in events if "error" in e]
        done = any(e.get("type") == "CompletionEvent"
                   and e.get("workflow_done") for e in events)

        conn = http.client.HTTPConnection(srv.host, srv.port, timeout=30)
        conn.request("POST", "/v1/drain")
        drain = json.loads(conn.getresponse().read())
        conn.close()
        dropped = sum(r["n_events_dropped"] for r in drain["replicas"])

    print(f"gateway-smoke: wid={sub['wid']} replica={sub['replica']} "
          f"events={len(events)} terminal={done} "
          f"stream_errors={len(errors)} dropped={dropped}")
    if errors or dropped or not done or resp.status != 200:
        print("gateway-smoke: FAIL")
        return 1
    print("gateway-smoke: OK")
    return 0


def main(argv=None) -> int:
    """CLI entry point: ``python -m repro.serving.gateway`` serves
    forever on a default heterogeneous cluster; ``--smoke`` runs the
    self-contained boot/submit/stream/drain check instead and returns
    its exit code."""
    parser = argparse.ArgumentParser(
        description="HTTP serving gateway over scheduler replicas")
    parser.add_argument("--host", default="127.0.0.1")
    parser.add_argument("--port", type=int, default=8080)
    parser.add_argument("--replicas", type=int, default=1)
    parser.add_argument("--devices", type=int, default=8,
                        help="devices per replica cluster")
    parser.add_argument("--smoke", action="store_true",
                        help="boot, submit one workflow over HTTP, "
                             "drain, and exit (nonzero on any "
                             "dropped event)")
    args = parser.parse_args(argv)
    if args.smoke:
        if args.port == 8080:
            args.port = 0  # ephemeral for the smoke check
        return _smoke(args)

    from repro.core.devices import heterogeneous_cluster
    cluster = heterogeneous_cluster(args.devices)
    config = SchedulerConfig()
    gateway = Gateway(lambda: Scheduler(cluster, config),
                      replicas=args.replicas)
    server = GatewayServer(gateway, host=args.host, port=args.port)
    server.start()
    print(f"gateway: serving on {server.host}:{server.port} "
          f"({args.replicas} replica(s))")
    try:
        server._thread.join()
    except KeyboardInterrupt:
        server.stop()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
