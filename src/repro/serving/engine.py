"""Workflow serving engine on real JAX devices.

The benchmark substrate (repro.core.executor) evaluates scheduling
policies on proxy costs — the paper's own methodology.  This engine is
the production path: FATE's placements drive actual model execution on
virtual devices, each holding resident model params and per-group
recurrent/KV prefix state.  Model residency switches move real param
trees; prefix reuse restores a saved cache; stage execution runs real
prefill + decode steps.  Measured wall times feed back into the
execution state, so the scheduler sees real (not proxy) τ.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.calibration import CalibrationProfile, StageObservation
from repro.core.costs import CostParams
from repro.core.faults import FaultInjector, TransientStageFailure
from repro.core.planner import Placement
from repro.core.state import ExecutionState
from repro.core.workflow import (DEFAULT_PROFILES, ModelProfile, Stage,
                                 Workflow)
from repro.models.families import build_model


def calibrated_switch_sleep(profile: ModelProfile,
                            cost_params: Optional[CostParams] = None,
                            time_scale: float = 1.0) -> float:
    """Emulated HBM weight-swap duration for one model switch.

    The scheduler prices a switch at ``profile.switch_cost *
    CostParams.switch_scale`` proxy seconds (see
    :meth:`repro.core.costs.CostModel.switch_cost`); the emulated sleep
    uses the SAME constants, shrunk by ``time_scale`` (tiny test models
    run orders of magnitude faster than the 7–14B profiles the proxy
    costs describe; 1.0 means real-time parity).  With a loaded
    :class:`~repro.core.calibration.CalibrationProfile` both sides read
    one source of truth — the engine derives ``profile`` from the
    calibration's ``model_profiles()`` and asserts at profile-load time
    that the planner's execution state carries identical constants
    (:meth:`ServingEngine.run_workflow`).
    """
    p = cost_params or CostParams()
    return profile.switch_cost * p.switch_scale * time_scale


@dataclasses.dataclass
class ModelBundle:
    """A servable model: config + weights + step functions."""
    name: str
    cfg: Any
    params: Any
    prefill: Callable
    decode: Callable

    @classmethod
    def create(cls, name: str, cfg, seed: int = 0) -> "ModelBundle":
        """Build and initialize the model, jit its prefill/decode step
        functions, and return the servable bundle."""
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(seed))

        @jax.jit
        def prefill_fn(params, tokens, cache):
            return model.prefill(params, tokens, cache)

        @jax.jit
        def decode_fn(params, token, cache, pos):
            return model.decode_step(params, token, cache, pos)

        bundle = cls(name, cfg, params, prefill_fn, decode_fn)
        bundle._model = model
        return bundle


@dataclasses.dataclass
class VirtualDevice:
    """One scheduling unit: holds at most one resident model's params
    plus saved prefix caches keyed by (group, model)."""
    did: int
    resident: Optional[str] = None
    prefix_caches: dict = dataclasses.field(default_factory=dict)

    def ensure_resident(self, bundle: ModelBundle,
                        switch_sleep: float = 0.0) -> bool:
        """Returns True if a switch happened.

        A residency switch drops incompatible prefix caches and — in a
        real deployment — swaps HBM weights; the swap is emulated by
        ``switch_sleep`` seconds so measured τ reflects switch cost.
        The default sleep is 0 (tests must stay fast); calibration and
        measurement runs pass :func:`calibrated_switch_sleep`-derived
        values, which read the same
        :class:`~repro.core.calibration.CalibrationProfile` constants
        the planner prices, so there is no engine/planner constant
        divergence to reconcile.
        """
        if self.resident == bundle.name:
            return False
        self.prefix_caches = {k: v for k, v in self.prefix_caches.items()
                              if k[1] == bundle.name}
        self.resident = bundle.name
        if switch_sleep:
            time.sleep(switch_sleep)
        return True


@dataclasses.dataclass
class StageResult:
    """One executed stage: outputs, wall time, and the calibration
    features the cost-model fitter consumes (tokens in/out, residency
    switches, warm-prefix coverage — see
    :meth:`ServingEngine.observations`)."""
    sid: str
    device_ids: tuple[int, ...]
    tokens_out: jax.Array           # [num_queries, gen_len]
    wall_s: float
    switched: bool
    prefix_hit: bool
    # calibration features (measure -> fit -> profile loop)
    model: str = ""
    queries: int = 0
    prompt_tokens: int = 0          # per query
    output_tokens: int = 0          # per query
    switches: int = 0               # residency switches across shards
    prefix_fraction: float = 0.0    # fraction of queries with warm hit


class ServingEngine:
    """Executes one workflow's stages per a policy's placements.

    ``switch_sleep`` (seconds) emulates the HBM weight swap uniformly;
    alternatively ``switch_time_scale`` derives a per-model sleep from
    the model profiles via :func:`calibrated_switch_sleep`, keeping
    measured τ consistent with the costs the scheduler planned
    against.  Both default to off (fast tests).

    ``calibration`` loads a
    :class:`~repro.core.calibration.CalibrationProfile` as the single
    source of truth for those profiles: the per-model sleeps derive
    from its fitted switch costs, and :meth:`run_workflow` asserts the
    execution state's (planner-side) profiles carry the same constants
    — the engine/planner cost divergence the pre-calibration code
    documented as a TODO is now a load-time error instead.

    Every executed stage is appended to ``log`` with its calibration
    features; :meth:`observations` converts the log into the
    :func:`repro.core.calibration.fit_profile` input format, closing
    the measure → fit → profile loop.

    ``faults`` optionally arms a deterministic
    :class:`~repro.core.faults.FaultInjector`: stage executions the
    injector targets raise
    :class:`~repro.core.faults.TransientStageFailure`, and
    :meth:`run_workflow` retries them (same placement, fresh attempt
    counter) up to the plan's ``max_retries`` — the real-execution
    mirror of the scheduler's simulated retry path.
    """

    def __init__(self, models: dict[str, ModelBundle], n_devices: int,
                 *, gen_len: int = 8, prompt_len: int = 32,
                 switch_sleep: float = 0.0,
                 switch_time_scale: float = 0.0,
                 calibration: Optional[CalibrationProfile] = None,
                 faults: Optional[FaultInjector] = None):
        self.models = models
        self.devices = [VirtualDevice(i) for i in range(n_devices)]
        self.gen_len = gen_len
        self.prompt_len = prompt_len
        self.switch_sleep = switch_sleep
        self.switch_time_scale = switch_time_scale
        self.calibration = calibration
        self.faults = faults
        self.n_fault_retries = 0
        # per-model profiles the emulated sleeps derive from: the
        # loaded calibration's fit, or the hand-set defaults
        self._profiles = (calibration.model_profiles()
                          if calibration is not None
                          else dict(DEFAULT_PROFILES))
        self.log: list[StageResult] = []

    def _switch_sleep_for(self, bundle: ModelBundle) -> float:
        """Per-switch emulation sleep for ``bundle`` (see class doc)."""
        if self.switch_sleep:
            return self.switch_sleep
        if self.switch_time_scale:
            prof = self._profiles.get(bundle.name)
            if prof is not None:
                return calibrated_switch_sleep(
                    prof, time_scale=self.switch_time_scale)
        return 0.0

    def observations(self) -> list[StageObservation]:
        """Calibration observations for every logged stage execution.

        The engine runs each shard on its own virtual device without
        cross-device tensor movement, so ``transfer_ktokens`` is zero —
        the fitter marks the transfer coefficient as defaulted rather
        than fitting it from a feature that never varies.
        """
        out: list[StageObservation] = []
        for r in self.log:
            prof = self._profiles.get(r.model)
            out.append(StageObservation(
                model=r.model,
                family=prof.family if prof is not None else "generic",
                queries=r.queries,
                prompt_tokens=float(r.prompt_tokens),
                output_tokens=float(r.output_tokens),
                switches=r.switches,
                prefix_fraction=r.prefix_fraction,
                transfer_ktokens=0.0,
                wall_s=r.wall_s))
        return out

    def run_stage(self, wf: Workflow, stage: Stage,
                  placement: Placement,
                  prompts: jax.Array, attempt: int = 0) -> StageResult:
        """prompts: [num_queries, prompt_len] int32 token ids.

        ``attempt`` is the retry ordinal the fault injector keys on
        (only attempt 0 is failure-eligible, so retries always
        converge); an injected fault raises
        :class:`~repro.core.faults.TransientStageFailure` before any
        device state is touched.
        """
        if self.faults is not None:
            frac = self.faults.failure_fraction(
                wf.wid, stage.sid, placement.devices, attempt)
            if frac is not None:
                raise TransientStageFailure(
                    f"injected fault: stage {wf.wid}/{stage.sid} on "
                    f"devices {placement.devices} failed at "
                    f"{frac:.0%} of its run (attempt {attempt})")
        bundle = self.models[stage.model]
        t0 = time.perf_counter()
        n_switches = 0
        hit_queries = 0
        outs = []
        q0 = 0
        for did, nq in zip(placement.devices, placement.shard_sizes):
            if nq == 0:
                continue
            dev = self.devices[did]
            if dev.ensure_resident(bundle,
                                   self._switch_sleep_for(bundle)):
                n_switches += 1
            shard = prompts[q0: q0 + nq]
            q0 += nq
            cache_key = (stage.prefix_group, stage.model, nq)
            # prefix reuse is emulated at the bookkeeping level: a
            # saved cache marks the hit (κ state the scheduler scored
            # for), but prefill below always starts fresh — replaying
            # the saved KV would need per-query prefix alignment the
            # tiny-model substrate doesn't model.  (The seed fetched
            # the cache object here and never used it; that dead read
            # is removed.)
            if (stage.cache_reuse and stage.prefix_group is not None
                    and cache_key in dev.prefix_caches):
                hit_queries += nq
            max_len = self.prompt_len + self.gen_len
            model = bundle._model
            fresh = model.init_cache(nq, max_len)
            logits, kv = bundle.prefill(bundle.params, shard, fresh)
            if stage.keep_cache and stage.prefix_group is not None:
                dev.prefix_caches[cache_key] = kv
            tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
            gen = [tok]
            pos = shard.shape[1]
            for step in range(self.gen_len - 1):
                logits, kv = bundle.decode(bundle.params, tok, kv,
                                           jnp.int32(pos + step))
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
                gen.append(tok)
            outs.append(jnp.concatenate(gen, axis=1))
        tokens = jnp.concatenate(outs, axis=0) if outs else \
            jnp.zeros((0, self.gen_len), jnp.int32)
        n_q = int(tokens.shape[0])
        res = StageResult(
            stage.sid, placement.devices, tokens,
            time.perf_counter() - t0, n_switches > 0, hit_queries > 0,
            model=stage.model, queries=n_q,
            prompt_tokens=self.prompt_len, output_tokens=self.gen_len,
            switches=n_switches,
            prefix_fraction=hit_queries / n_q if n_q else 0.0)
        self.log.append(res)
        return res

    def run_workflow(self, wf: Workflow, policy, state: ExecutionState,
                     prompts: jax.Array) -> dict[str, StageResult]:
        """Execute the full DAG: plan with the policy, run stages on
        real devices in dependency order, update real execution state.

        With a loaded calibration profile the execution state the
        policy plans against must carry the SAME constants the engine
        emulates — asserted here, at profile-load time, so engine and
        planner can never silently diverge.
        """
        if self.calibration is not None:
            self.calibration.assert_consistent(state.profiles)
        results: dict[str, StageResult] = {}
        completed: set[str] = set()
        t_start = time.perf_counter()
        while len(completed) < len(wf.stages):
            ready = [sid for sid in wf.topo_order
                     if sid not in completed
                     and all(p in completed for p in wf.stages[sid].parents)]
            placements = policy.plan(wf, state, ready)
            if not placements:
                sid = ready[0]
                placements = [Placement(wf.wid, sid, (0,),
                                        (wf.num_queries,))]
            for p in placements:
                if p.sid in completed:
                    continue
                stage = wf.stages[p.sid]
                max_retries = (self.faults.plan.max_retries
                               if self.faults is not None else 0)
                for attempt in range(max_retries + 1):
                    try:
                        res = self.run_stage(wf, stage, p, prompts,
                                             attempt=attempt)
                        break
                    except TransientStageFailure:
                        if attempt >= max_retries:
                            raise
                        self.n_fault_retries += 1
                results[p.sid] = res
                completed.add(p.sid)
                now = time.perf_counter() - t_start
                state.now = now
                for d in p.devices:
                    state.set_free_at(d, now)
                    state.set_resident(d, stage.model)
                    if stage.keep_cache:
                        state.warm_prefix(d, stage.prefix_group,
                                          stage.model, wf.num_queries, now)
                state.output_loc[(wf.wid, p.sid)] = p.devices
                state.completed.add((wf.wid, p.sid))
        return results
