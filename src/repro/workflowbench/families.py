"""Structurally-faithful raw task-DAG generators for the ten WfCommons
workflow families used in the paper (Table 8).

WfInstances JSON traces are not available offline; these generators
reproduce the documented fan-out/fan-in topology, depth and width
statistics of each family (WfCommons, Coleman et al. 2022).  The paper
itself uses WfCommons "as a source of realistic dependency structure
rather than as a direct trace" (Appendix C.1), which is exactly what
these provide.  Everything is deterministic in (family, instance seed).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Callable


@dataclasses.dataclass
class RawTask:
    tid: str
    name_family: str               # normalized task-name prefix
    parents: list[str]


RawDag = dict[str, RawTask]


def _t(dag: RawDag, family: str, idx: int,
       parents: list[str]) -> str:
    tid = f"{family}_{idx:05d}"
    dag[tid] = RawTask(tid, family, list(parents))
    return tid


def gen_1000genome(rng: random.Random) -> RawDag:
    dag: RawDag = {}
    n_ind = rng.randint(16, 56)
    inds = [_t(dag, "individuals", i, []) for i in range(n_ind)]
    merge = _t(dag, "individuals_merge", 0, inds)
    sift = _t(dag, "sifting", 0, [])
    n_an = rng.randint(8, 24)
    for i in range(n_an):
        _t(dag, "mutation_overlap", i, [merge, sift])
    for i in range(n_an):
        _t(dag, "frequency", i, [merge, sift])
    return dag


def gen_blast(rng: random.Random) -> RawDag:
    dag: RawDag = {}
    split = _t(dag, "split_fasta", 0, [])
    n = rng.randint(24, 96)
    blasts = [_t(dag, "blastall", i, [split]) for i in range(n)]
    cat = _t(dag, "cat_blast", 0, blasts)
    _t(dag, "postprocess", 0, [cat])
    return dag


def gen_bwa(rng: random.Random) -> RawDag:
    dag: RawDag = {}
    idx = _t(dag, "bwa_index", 0, [])
    red = _t(dag, "fastq_reduce", 0, [])
    n = rng.randint(24, 80)
    aligns = [_t(dag, "bwa_align", i, [idx, red]) for i in range(n)]
    cat = _t(dag, "cat_bwa", 0, aligns)
    _t(dag, "cat_all", 0, [cat])
    return dag


def gen_cycles(rng: random.Random) -> RawDag:
    dag: RawDag = {}
    n_params = rng.randint(6, 14)
    outs = []
    for p in range(n_params):
        base = _t(dag, "baseline_cycles", p, [])
        cy = _t(dag, "cycles", p, [base])
        fi = _t(dag, "fertilizer_increase", p, [cy])
        outs.append(_t(dag, "cycles_output_parser", p, [fi]))
    summ = _t(dag, "cycles_output_summary", 0, outs)
    _t(dag, "cycles_plots", 0, [summ])
    return dag


def gen_montage(rng: random.Random) -> RawDag:
    dag: RawDag = {}
    n_img = rng.randint(10, 24)
    proj = [_t(dag, "mProject", i, []) for i in range(n_img)]
    n_diff = min(rng.randint(n_img, 2 * n_img), 48)
    diffs = []
    for i in range(n_diff):
        a, b = rng.sample(range(n_img), 2)
        diffs.append(_t(dag, "mDiffFit", i, [proj[a], proj[b]]))
    concat = _t(dag, "mConcatFit", 0, diffs)
    bg_model = _t(dag, "mBgModel", 0, [concat])
    bgs = [_t(dag, "mBackground", i, [proj[i], bg_model])
           for i in range(n_img)]
    imgtbl = _t(dag, "mImgtbl", 0, bgs)
    add = _t(dag, "mAdd", 0, [imgtbl])
    shrink = _t(dag, "mShrink", 0, [add])
    _t(dag, "mJPEG", 0, [shrink])
    return dag


def gen_nextflow(rng: random.Random) -> RawDag:
    dag: RawDag = {}
    n_samp = rng.randint(4, 9)
    merged = []
    for s in range(n_samp):
        qc = _t(dag, "fastqc", s, [])
        trim = _t(dag, "trimgalore", s, [qc])
        al = _t(dag, "star_align", s, [trim])
        dd = _t(dag, "markduplicates", s, [al])
        q2 = _t(dag, "qualimap", s, [dd])
        merged.append(q2)
    mq = _t(dag, "multiqc", 0, merged)
    _t(dag, "report", 0, [mq])
    return dag


def gen_rnaseq(rng: random.Random) -> RawDag:
    dag: RawDag = {}
    n = rng.randint(6, 12)
    counts = []
    for s in range(n):
        fq = _t(dag, "fastq_dump", s, [])
        al = _t(dag, "hisat2", s, [fq])
        counts.append(_t(dag, "htseq_count", s, [al]))
    m = _t(dag, "merge_counts", 0, counts)
    _t(dag, "deseq2", 0, [m])
    return dag


def gen_seismic(rng: random.Random) -> RawDag:
    dag: RawDag = {}
    n_st = rng.randint(16, 48)
    pre = [_t(dag, "sG1IterDecon", i, []) for i in range(n_st)]
    merge = _t(dag, "wrapper_siftSTFByMisfit", 0, pre)
    return dag


def gen_soykb(rng: random.Random) -> RawDag:
    dag: RawDag = {}
    n_samp = rng.randint(5, 10)
    gvcfs = []
    for s in range(n_samp):
        al = _t(dag, "alignment_to_reference", s, [])
        so = _t(dag, "sort_sam", s, [al])
        dd = _t(dag, "dedup", s, [so])
        ar = _t(dag, "add_replace", s, [dd])
        rt = _t(dag, "realign_target_creator", s, [ar])
        ir = _t(dag, "indel_realign", s, [rt])
        hc = _t(dag, "haplotype_caller", s, [ir])
        gvcfs.append(hc)
    cg = _t(dag, "combine_variants", 0, gvcfs)
    gt = _t(dag, "genotype_gvcfs", 0, [cg])
    sv = _t(dag, "select_variants_snp", 0, [gt])
    _t(dag, "filtering_snp", 0, [sv])
    return dag


def gen_srasearch(rng: random.Random) -> RawDag:
    dag: RawDag = {}
    n = rng.randint(16, 60)
    fetches = [_t(dag, "prefetch", i, []) for i in range(n)]
    searches = [_t(dag, "sra_search", i, [fetches[i]]) for i in range(n)]
    _t(dag, "merge_results", 0, searches)
    return dag


FAMILIES: dict[str, tuple[Callable[[random.Random], RawDag], int]] = {
    # family -> (generator, #instances in the paper's Table 8)
    "1000Genome": (gen_1000genome, 22),
    "BLAST": (gen_blast, 15),
    "BWA": (gen_bwa, 15),
    "Cycles": (gen_cycles, 19),
    "Montage": (gen_montage, 12),
    "Nextflow": (gen_nextflow, 9),
    "RNA-seq": (gen_rnaseq, 3),
    "SeismicCrossCorrelation": (gen_seismic, 11),
    "SoyKB": (gen_soykb, 10),
    "Srasearch": (gen_srasearch, 25),
}

# cache-dominant tracks use a fixed model alias to isolate locality and
# prefix-reuse behaviour (Appendix C.1 "Model assignment")
FIXED_MODEL_FAMILIES = {"Srasearch": "qwen-7b",
                        "SeismicCrossCorrelation": "deepseek-7b"}
