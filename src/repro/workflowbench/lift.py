"""DAG lifting: raw task DAGs -> LLM-stage execution graphs
(paper Appendix C.1).

Steps: (1) collapse tasks with the same normalized task-name prefix into
stage groups (splitting oversized groups so prefix collapse does not
over-compress, capping total stages at 64); (2) annotate structure
(level, in/out-degree); (3) assign role templates via deterministic
structural rules; (4) assign model aliases per role with a stable hash
(construction seed 20260423); (5) attach runtime / switch / transfer /
prefix-cache proxies.
"""
from __future__ import annotations

import hashlib
import random
from typing import Optional

from repro.core.workflow import (DEFAULT_PROFILES, ModelProfile, Stage,
                                 Workflow)
from repro.workflowbench.families import (FAMILIES, FIXED_MODEL_FAMILIES,
                                          RawDag)

CONSTRUCTION_SEED = 20260423
MAX_STAGES = 64
MIN_STAGES = 6
GROUP_SPLIT = 4          # max raw tasks collapsed into one stage group


# ---------------------------------------------------------------------------
# Role templates (paper C.1 "Stage-role templates")
# ---------------------------------------------------------------------------

ROLE_ATTRS: dict[str, dict] = {
    # role: complexity, prompt_ktokens, output_tokens, comm_w, R(v), cache
    "prompt_prep":   dict(cx=0.6, prompt=1.0, out=128, comm=0.6, r=1,
                          reuse=False),
    "retrieval":     dict(cx=0.8, prompt=2.0, out=256, comm=1.2, r=2,
                          reuse=True),
    "routing":       dict(cx=0.5, prompt=0.8, out=64, comm=0.5, r=1,
                          reuse=False),
    "decomposition": dict(cx=0.9, prompt=1.5, out=384, comm=1.0, r=1,
                          reuse=True),
    "worker":        dict(cx=1.0, prompt=2.5, out=512, comm=1.0, r=2,
                          reuse=True),
    "merge":         dict(cx=0.9, prompt=3.0, out=384, comm=1.5, r=1,
                          reuse=False),
    "aggregation":   dict(cx=1.0, prompt=3.5, out=512, comm=1.5, r=1,
                          reuse=False),
    "summarization": dict(cx=0.8, prompt=3.0, out=512, comm=1.0, r=1,
                          reuse=True),
    "validation":    dict(cx=0.7, prompt=2.0, out=192, comm=0.8, r=2,
                          reuse=True),
    "final_synthesis": dict(cx=1.1, prompt=3.5, out=768, comm=1.2, r=1,
                            reuse=False),
}

ROLE_MODELS: dict[str, list[str]] = {
    "prompt_prep": ["llama-3b", "qwen-7b"],
    "retrieval": ["qwen-7b", "deepseek-7b"],
    "routing": ["llama-3b"],
    "decomposition": ["qwen-14b", "deepseek-7b"],
    "worker": ["qwen-7b", "deepseek-7b", "llama-8b"],
    "merge": ["llama-8b", "qwen-7b"],
    "aggregation": ["qwen-14b", "llama-8b"],
    "summarization": ["qwen-7b", "llama-8b"],
    "validation": ["deepseek-7b", "llama-3b"],
    "final_synthesis": ["qwen-14b", "llama-8b"],
}


def _stable_hash(*parts: str) -> int:
    h = hashlib.sha256("|".join(parts).encode()).hexdigest()
    return int(h[:12], 16)


def collapse(raw: RawDag) -> tuple[dict[str, list[str]],
                                   dict[str, set[str]]]:
    """Group raw tasks by name family (split into chunks of GROUP_SPLIT);
    return (group -> member tasks, group -> parent groups)."""
    by_family: dict[str, list[str]] = {}
    for t in raw.values():
        by_family.setdefault(t.name_family, []).append(t.tid)
    groups: dict[str, list[str]] = {}
    task_group: dict[str, str] = {}
    for fam, tids in sorted(by_family.items()):
        tids = sorted(tids)
        n_chunks = max(1, (len(tids) + GROUP_SPLIT - 1) // GROUP_SPLIT)
        for c in range(n_chunks):
            gid = fam if n_chunks == 1 else f"{fam}.{c}"
            members = tids[c::n_chunks]
            groups[gid] = members
            for tid in members:
                task_group[tid] = gid
    # merge smallest groups if over MAX_STAGES
    while len(groups) > MAX_STAGES:
        fams: dict[str, list[str]] = {}
        for gid in groups:
            fams.setdefault(gid.split(".")[0], []).append(gid)
        fam, gids = max(((f, g) for f, g in fams.items() if len(g) > 1),
                        key=lambda kv: len(kv[1]), default=(None, None))
        if fam is None:
            break
        keep, drop = gids[0], gids[-1]
        groups[keep] = groups[keep] + groups.pop(drop)
        for tid in groups[keep]:
            task_group[tid] = keep
    edges: dict[str, set[str]] = {g: set() for g in groups}
    for t in raw.values():
        g = task_group[t.tid]
        for p in t.parents:
            pg = task_group[p]
            if pg != g:
                edges[g].add(pg)
    return groups, edges


def _assign_role(gid: str, level: int, max_level: int, indeg: int,
                 outdeg: int, n_members: int) -> str:
    if level == 0:
        if outdeg >= 4 or n_members >= 4:
            return "decomposition"
        if outdeg >= 2:
            return "retrieval"
        return "prompt_prep"
    if indeg >= 4:
        return "aggregation" if level >= max_level - 1 else "merge"
    if level >= max_level and indeg >= 1:
        return "final_synthesis"
    if level >= max_level - 1:
        if indeg >= 2:
            return "summarization"
        return "validation"
    if outdeg >= 3:
        return "decomposition"
    if n_members >= 3 or outdeg >= 1:
        return "worker"
    return "worker"


def lift(raw: RawDag, *, family: str, wid: str, num_queries: int,
         profiles: Optional[dict[str, ModelProfile]] = None,
         seed: int = CONSTRUCTION_SEED,
         prefix_sharing: bool = True) -> Workflow:
    profiles = profiles or DEFAULT_PROFILES
    groups, gedges = collapse(raw)

    # structural annotation: topological order + levels over the group DAG
    level: dict[str, int] = {}
    ordered: list[str] = []
    done: set[str] = set()
    frontier = sorted(g for g, ps in gedges.items() if not ps)
    while frontier:
        for g in frontier:
            level[g] = max([level[p] + 1 for p in gedges[g]] or [0])
            ordered.append(g)
            done.add(g)
        frontier = sorted(g for g in gedges if g not in done
                          and all(p in done for p in gedges[g]))
    if len(ordered) != len(groups):
        raise ValueError(f"{wid}: lifted group graph has a cycle")
    max_level = max(level.values(), default=0)
    outdeg: dict[str, int] = {g: 0 for g in groups}
    for g, ps in gedges.items():
        for p in ps:
            outdeg[p] += 1

    fixed_model = FIXED_MODEL_FAMILIES.get(family)
    stages: dict[str, Stage] = {}
    for gid in ordered:
        indeg = len(gedges[gid])
        role = _assign_role(gid, level[gid], max_level, indeg,
                            outdeg[gid], len(groups[gid]))
        attrs = ROLE_ATTRS[role]
        if fixed_model is not None:
            model = fixed_model
        else:
            cands = ROLE_MODELS[role]
            model = cands[_stable_hash(str(seed), wid, gid) % len(cands)]
        prof = profiles[model]
        # runtime proxy: per-query seconds (same on all devices of the
        # paper's homogeneous 8-GPU setting)
        prefill_part = prof.prefill_coef * attrs["prompt"] * attrs["cx"]
        decode_part = prof.decode_coef * attrs["out"] / 1000.0
        per_query = prefill_part + decode_part
        pgroup = None
        if prefix_sharing and attrs["reuse"]:
            # reuse-eligible stages share the workflow's long-context
            # prefix (system prompt + task context); reuse is realized
            # only when a later stage lands on a device whose cache was
            # warmed under the SAME model (state.py keys entries by
            # model), mirroring per-model KV incompatibility.
            pgroup = f"{wid}:ctx"
        stages[gid] = Stage(
            sid=gid, model=model, max_shards=attrs["r"],
            base_cost={-1: per_query},
            prefix_group=pgroup,
            keep_cache=True, cache_reuse=attrs["reuse"],
            output_tokens=float(attrs["out"]),
            prefill_fraction=prefill_part / per_query,
            comm_weight=attrs["comm"], role=role,
            parents=tuple(sorted(gedges[gid])),
        )
    wf = Workflow(wid=wid, stages=stages, num_queries=num_queries,
                  family=family, meta={"raw_tasks": len(raw)})
    return wf


def build_instance(family: str, index: int, num_queries: int,
                   seed: int = CONSTRUCTION_SEED) -> Workflow:
    gen, _ = FAMILIES[family]
    rng = random.Random(_stable_hash(str(seed), family, str(index)))
    raw = gen(rng)
    wid = f"{family}-{index:03d}-q{num_queries}"
    return lift(raw, family=family, wid=wid, num_queries=num_queries,
                seed=seed)


def build_benchmark(num_queries_list=(16, 32),
                    seed: int = CONSTRUCTION_SEED) -> list[Workflow]:
    """The full workflow-DAG benchmark (fixed manifest)."""
    out: list[Workflow] = []
    for family, (gen, count) in FAMILIES.items():
        for i in range(count):
            for nq in num_queries_list:
                out.append(build_instance(family, i, nq, seed))
    return out
