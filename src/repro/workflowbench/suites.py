"""Controlled behavioural suites (paper §4.3, Appendix C.1 & D.2),
plus the multi-workflow serving trace generator (§2's serving setting:
many agentic DAGs with stochastic arrivals contend for one cluster).

* Prefix-reuse suite — workflow-style DAG templates over long-context
  workloads with shared-prefix repeat ratios {0, 0.25, 0.5, 1.0}.
  Cache-dominant: single model family, shardable workers.  Isolates
  whether reuse alone explains the FATE gap (Table 2).

* Conflict stress suite — appendix-only diagnostic (Table 9): layers of
  parallel stages alternate model families while retaining
  cache-relevant state along chains, so myopic residency/locality
  following serializes onto the few warm devices, while a
  future-state-aware planner balances creating new residencies against
  queueing.  Four templates at repeat ratios {0, .25, .5, 1.0}
  (workflow_cache_conflict_{000,025,050,100}).
"""
from __future__ import annotations

from repro.core.workflow import Stage, Workflow

RATIOS = (0.0, 0.25, 0.5, 1.0)


def prefix_suite_instance(ratio: float, index: int,
                          num_queries: int = 16) -> Workflow:
    """Decompose -> W parallel long-context workers -> 2 verifiers ->
    merge.  All stages one model (cache-dominant); workers shardable."""
    model = "qwen-7b"
    widths = [3, 4, 5, 6]
    w = widths[index % len(widths)]
    grp = f"pref-{ratio}-{index}:ctx"
    stages: dict[str, Stage] = {
        "decompose": Stage("decompose", model, base_cost={-1: 0.06},
                           prefix_group=grp, shared_fraction=ratio,
                           output_tokens=256.0, role="decomposition"),
    }
    for i in range(w):
        stages[f"worker{i}"] = Stage(
            f"worker{i}", model, max_shards=2, base_cost={-1: 0.14},
            prefill_fraction=0.85,
            prefix_group=grp, shared_fraction=ratio,
            output_tokens=512.0, parents=("decompose",), role="worker")
    for j in range(2):
        stages[f"verify{j}"] = Stage(
            f"verify{j}", model, max_shards=2, base_cost={-1: 0.08},
            prefill_fraction=0.85,
            prefix_group=grp, shared_fraction=ratio,
            output_tokens=192.0,
            parents=tuple(f"worker{i}" for i in range(w)
                          if i % 2 == j), role="validation")
    stages["merge"] = Stage(
        "merge", model, base_cost={-1: 0.1}, prefill_fraction=0.85,
        prefix_group=grp,
        shared_fraction=ratio, output_tokens=512.0,
        parents=("verify0", "verify1"), role="merge")
    wf = Workflow(wid=f"prefix-{int(ratio*100):03d}-{index:02d}",
                  stages=stages, num_queries=num_queries,
                  family="prefix-reuse")
    # cache-dominant same-model setting: the serving fleet is dedicated
    # to this model family, so it is resident before the batch arrives
    wf.meta["preload_model"] = model
    return wf


def prefix_suite(ratio: float, n_instances: int = 8,
                 num_queries: int = 16) -> list[Workflow]:
    """Batch of prefix-sharing workflow instances at one shared ratio."""
    return [prefix_suite_instance(ratio, i, num_queries)
            for i in range(n_instances)]


def conflict_suite_instance(ratio: float, index: int,
                            num_queries: int = 16) -> Workflow:
    """workflow_cache_conflict_<ratio>: depth D layers of P parallel
    stages; layer models alternate between two families; each chain
    retains a shared-prefix group, so reuse/residency following pulls
    every chain onto the same 1-2 warm devices."""
    models = ["qwen-7b", "llama-8b"]
    depth, par = 8, 6
    stages: dict[str, Stage] = {}
    prev: list[str] = []
    for lv in range(depth):
        model = models[lv % 2]
        cur = []
        for pch in range(par):
            sid = f"l{lv}c{pch}"
            parents = (f"l{lv-1}c{pch}",) if lv else ()
            stages[sid] = Stage(
                sid, model, base_cost={-1: 0.11},
                prefix_group=f"conf-{index}:chain{pch}",
                shared_fraction=max(ratio, 0.01),
                output_tokens=384.0, comm_weight=1.2,
                parents=parents, role="worker")
            cur.append(sid)
        prev = cur
    stages["final"] = Stage(
        "final", models[0], base_cost={-1: 0.12},
        output_tokens=512.0, parents=tuple(prev),
        role="final_synthesis")
    return Workflow(
        wid=f"workflow_cache_conflict_{int(ratio*100):03d}-{index:02d}",
        stages=stages, num_queries=num_queries, family="conflict")


def conflict_suite(ratio: float, n_instances: int = 4,
                   num_queries: int = 16) -> list[Workflow]:
    """Batch of cache-conflict workflow instances at one shared ratio."""
    return [conflict_suite_instance(ratio, i, num_queries)
            for i in range(n_instances)]


# ---------------------------------------------------------------------------
# multi-workflow serving traces
# ---------------------------------------------------------------------------


def poisson_serving_trace(n_workflows: int = 12, rate: float = 4.0,
                          seed: int = 0, num_queries: int = 8,
                          mix: str = "mixed"
                          ) -> list[tuple[float, "Workflow"]]:
    """Poisson arrival trace of heterogeneous workflow instances.

    Inter-arrival times are Exp(rate); instances cycle through the
    prefix-reuse and conflict-stress templates (``mix='mixed'``), or a
    single family (``mix='prefix'`` / ``mix='conflict'``), each with a
    unique workflow id so many copies can be in flight at once.
    Deterministic in ``seed``.  Returned sorted by arrival time —
    directly consumable by ``ServingExecutor.run``.
    """
    import random

    rng = random.Random(seed)
    trace: list[tuple[float, Workflow]] = []
    t = 0.0
    for i in range(n_workflows):
        t += rng.expovariate(rate)
        ratio = RATIOS[i % len(RATIOS)]
        if mix == "prefix" or (mix == "mixed" and i % 2 == 0):
            wf = prefix_suite_instance(ratio, i, num_queries)
            wf.wid = f"serve-prefix-{i:03d}"
        else:
            wf = conflict_suite_instance(ratio, i, num_queries)
            wf.wid = f"serve-conflict-{i:03d}"
        wf.meta.pop("preload_model", None)   # serving fleet is shared
        trace.append((t, wf))
    return trace


def drifting_serving_trace(n_workflows: int = 24, rate_start: float = 2.0,
                           rate_end: float = 16.0, seed: int = 0,
                           num_queries: int = 8
                           ) -> list[tuple[float, "Workflow"]]:
    """Poisson trace whose arrival rate ramps linearly from
    ``rate_start`` to ``rate_end`` over the trace.

    As load climbs, queueing delay — and with it the true
    observed/predicted probe ratio — drifts upward, so a static probe
    margin is wrong at one end of the trace no matter its value.  The
    regime the online EWMA probe correction is built for
    (``tests/test_calibration.py`` gates convergence on it).
    Deterministic in ``seed``; same mixed workload as
    :func:`poisson_serving_trace`.
    """
    import random

    rng = random.Random(seed)
    trace: list[tuple[float, Workflow]] = []
    t = 0.0
    for i in range(n_workflows):
        frac = i / max(n_workflows - 1, 1)
        rate = rate_start + (rate_end - rate_start) * frac
        t += rng.expovariate(rate)
        ratio = RATIOS[i % len(RATIOS)]
        if i % 2 == 0:
            wf = prefix_suite_instance(ratio, i, num_queries)
            wf.wid = f"drift-prefix-{i:03d}"
        else:
            wf = conflict_suite_instance(ratio, i, num_queries)
            wf.wid = f"drift-conflict-{i:03d}"
        wf.meta.pop("preload_model", None)
        trace.append((t, wf))
    return trace


def overloaded_serving_trace(n_workflows: int = 18, rate: float = 14.0,
                             seed: int = 0, num_queries: int = 8
                             ) -> list[tuple[float, "Workflow"]]:
    """Deliberately overloaded Poisson trace for the SLO control plane.

    Same mixed workload as :func:`poisson_serving_trace` but with an
    arrival rate far above the cluster's service rate, so unconditional
    admission drives queueing delay (and P95) unboundedly up while an
    admission controller can trade rejected arrivals for SLO-met
    goodput.  Used by ``benchmarks/sched_bench.py --serve-slo`` and
    ``tests/test_admission.py``.
    """
    return poisson_serving_trace(n_workflows=n_workflows, rate=rate,
                                 seed=seed, num_queries=num_queries,
                                 mix="mixed")


def routed_workflow_instance(index: int, num_queries: int = 8,
                             candidates: tuple = (("qwen-7b", 0.92),
                                                  ("llama-3b", 0.84))
                             ) -> Workflow:
    """Decompose -> W parallel workers -> merge, with the workers
    defaulting to the LARGE family (``qwen-14b``) while declaring
    cheaper alternates via ``Stage.candidates``.

    The default alternate list offers ``qwen-7b`` at quality 0.92
    (admissible at the default 0.9 quality floor, roughly half the
    cost) and ``llama-3b`` at 0.84 (below the floor — the router must
    exclude it even though it is far cheaper), so one instance
    exercises both sides of the floor.  Decompose/merge stay
    single-family with no alternates: routing must leave them
    untouched.
    """
    w = 3 + index % 3
    grp = f"routed-{index}:ctx"
    stages: dict[str, Stage] = {
        "decompose": Stage("decompose", "qwen-7b",
                           base_cost={-1: 0.06}, prefix_group=grp,
                           shared_fraction=0.5, output_tokens=256.0,
                           role="decomposition"),
    }
    for i in range(w):
        stages[f"worker{i}"] = Stage(
            f"worker{i}", "qwen-14b", max_shards=2,
            base_cost={-1: 0.2}, prefill_fraction=0.7,
            prefix_group=grp, shared_fraction=0.5,
            output_tokens=512.0, parents=("decompose",),
            role="worker", candidates=tuple(candidates))
    stages["merge"] = Stage(
        "merge", "qwen-7b", base_cost={-1: 0.08},
        prefix_group=grp, shared_fraction=0.5, output_tokens=384.0,
        parents=tuple(f"worker{i}" for i in range(w)), role="merge")
    return Workflow(wid=f"routed-{index:03d}", stages=stages,
                    num_queries=num_queries, family="routed")


def routed_serving_trace(n_workflows: int = 10, rate: float = 4.0,
                         seed: int = 0, num_queries: int = 8
                         ) -> list[tuple[float, "Workflow"]]:
    """Poisson trace of :func:`routed_workflow_instance` copies — the
    cost/quality routing benchmark input (``sched_bench --gateway``).

    Every worker stage prefers the large ``qwen-14b`` family but
    declares cheaper admissible alternates, so a routing-enabled
    planner can trade quality margin above the floor for cost, while
    a routing-disabled run must serve everything on the default
    family.  Deterministic in ``seed``; sorted by arrival time.
    """
    import random

    rng = random.Random(seed)
    trace: list[tuple[float, Workflow]] = []
    t = 0.0
    for i in range(n_workflows):
        t += rng.expovariate(rate)
        wf = routed_workflow_instance(i, num_queries)
        trace.append((t, wf))
    return trace


def multiclass_overloaded_trace(n_workflows: int = 18, rate: float = 14.0,
                                seed: int = 0, num_queries: int = 8,
                                class_cycle: tuple = ("platinum", "batch",
                                                      "batch")
                                ) -> list[tuple[float, "Workflow", str]]:
    """The overloaded trace annotated with admission classes.

    Exactly :func:`overloaded_serving_trace` — identical workflows,
    arrival times, and wids (so :func:`chaos_fault_plan`'s targeted
    ``serve-prefix-000``/``serve-conflict-001`` failures keep
    landing) — with each arrival assigned a class from ``class_cycle``
    by arrival index.  The default cycle makes every third arrival
    platinum, so both tiers stay busy through the overload.  Returns
    ``[(arrival, workflow, klass)]`` triples for
    ``Scheduler.submit(wf, at=t, klass=k)``.  Deterministic in
    ``seed``.
    """
    trace = overloaded_serving_trace(n_workflows=n_workflows, rate=rate,
                                     seed=seed, num_queries=num_queries)
    return [(t, wf, class_cycle[i % len(class_cycle)])
            for i, (t, wf) in enumerate(trace)]


def scale_instance(index: int, num_queries: int = 4) -> Workflow:
    """One small workflow for the 1k-workflow scale trace.

    Shapes cycle through four tiny templates (2–5 stages: pair, chain,
    diamond, shardable fan-out/merge) over the five bench model
    families, with prefix groups shared within a burst-sized cohort —
    small enough that a thousand instances drain in bench time, varied
    enough that scoring (transfer, residency, prefix, sharding) and the
    pooled partitioner all stay live.  Deterministic in ``index``.
    """
    models = ["qwen-7b", "deepseek-7b", "llama-8b", "llama-3b",
              "qwen-14b"]
    m = models[index % 5]
    m2 = models[(index + 2) % 5]
    grp = f"scale:g{index % 16}"
    shape = index % 4
    stages: dict[str, Stage] = {}
    if shape == 0:                                  # pair: a -> b
        stages["a"] = Stage("a", m, base_cost={-1: 0.06},
                            prefix_group=grp, shared_fraction=0.5,
                            output_tokens=192.0)
        stages["b"] = Stage("b", m2, base_cost={-1: 0.08},
                            output_tokens=256.0, parents=("a",))
    elif shape == 1:                                # chain: a -> b -> c
        stages["a"] = Stage("a", m, base_cost={-1: 0.05},
                            output_tokens=192.0)
        stages["b"] = Stage("b", m2, base_cost={-1: 0.09},
                            prefix_group=grp, shared_fraction=0.5,
                            output_tokens=256.0, parents=("a",))
        stages["c"] = Stage("c", m, base_cost={-1: 0.06},
                            output_tokens=192.0, parents=("b",))
    elif shape == 2:                                # diamond
        stages["src"] = Stage("src", m, base_cost={-1: 0.05},
                              output_tokens=192.0)
        for side in ("l", "r"):
            stages[side] = Stage(side, m2, base_cost={-1: 0.08},
                                 prefix_group=grp, shared_fraction=0.5,
                                 output_tokens=256.0, parents=("src",))
        stages["sink"] = Stage("sink", m, base_cost={-1: 0.06},
                               output_tokens=192.0,
                               parents=("l", "r"))
    else:                                           # fan-out / merge
        stages["src"] = Stage("src", m, base_cost={-1: 0.05},
                              output_tokens=192.0)
        for i in range(3):
            stages[f"w{i}"] = Stage(
                f"w{i}", m2, max_shards=2, base_cost={-1: 0.1},
                prefix_group=grp, shared_fraction=0.5,
                output_tokens=256.0, parents=("src",))
        stages["merge"] = Stage("merge", m, base_cost={-1: 0.07},
                                output_tokens=256.0,
                                parents=("w0", "w1", "w2"))
    return Workflow(wid=f"scale-{index:04d}", stages=stages,
                    num_queries=num_queries, family="scale")


def scale_serving_trace(n_workflows: int = 1000, burst: int = 8,
                        gap: float = 0.25, num_queries: int = 4
                        ) -> list[tuple[float, "Workflow"]]:
    """Bursty arrival trace for the 1k-workflow ``--scale`` gate.

    Arrivals land in bursts of ``burst`` workflows at the SAME
    timestamp (exercising batched admission probing: one shared
    lookahead overlay per burst), bursts spaced ``gap`` simulated
    seconds apart so in-flight depth stays bounded while consecutive
    bursts overlap.  Instances are the tiny mixed
    :func:`scale_instance` shapes.  Fully deterministic.
    """
    trace: list[tuple[float, Workflow]] = []
    for i in range(n_workflows):
        t = (i // burst) * gap
        trace.append((t, scale_instance(i, num_queries)))
    return trace


def chaos_fault_plan(seed: int = 0) -> "FaultPlan":
    """The chaos-gate fault script for the overloaded serving trace.

    A fixed, seeded :class:`~repro.core.faults.FaultPlan` combining
    every fault class the scheduler handles, with timings tuned to the
    fault-free FATE horizon of ``overloaded_serving_trace(18)`` on a
    6-device homogeneous cluster (≈107 simulated seconds):

    * one device crash at ~30% of the fault-free horizon (device 2 at
      t=30s) with recovery 30 simulated seconds later;
    * a 3× slowdown episode on device 1 (t=10–45s) long enough to
      trip straggler probes (threshold 1.5× believed duration) and
      speculative re-issue;
    * two targeted transient shard failures early in two different
      workflow shapes (a prefix-suite worker and a conflict-suite
      level stage), exercising retry-with-backoff.

    Used by ``benchmarks/sched_bench.py --chaos`` and
    ``tests/test_faults.py``.
    """
    from repro.core.faults import (DeviceCrash, FaultPlan, ShardFailure,
                                   Slowdown)
    return FaultPlan(
        seed=seed,
        crashes=(DeviceCrash(device=2, at=30.0, recover_at=60.0),),
        slowdowns=(Slowdown(device=1, at=10.0, until=45.0, factor=3.0),),
        failures=(ShardFailure(wid="serve-prefix-000", sid="worker0",
                               at_fraction=0.5),
                  ShardFailure(wid="serve-conflict-001", sid="l0c0",
                               at_fraction=0.3)),
        max_retries=3, retry_backoff=0.05, retry_backoff_mult=2.0,
        straggler_threshold=1.5, speculate=True,
        quarantine_after=3, quarantine_s=1.0)
