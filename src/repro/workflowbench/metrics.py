"""Metric definitions (paper Appendix C.2).

NormMS(m)  = exp( mean_i log(T_{m,i} / T_{RR,i}) )
NormP95(m) = exp( mean_i log(L95_{m,i} / L95_{RR,i}) )
XDevEdge   = Σ cross_device_parent_edges / Σ workflow_tasks
CacheScore = Σ prefix_cache_hits_est / Σ workflow_tasks
ModelCont  = Σ same_model_continuations / Σ workflow_tasks
"""
from __future__ import annotations

import math
from typing import Iterable, Sequence


def geomean(xs: Sequence[float]) -> float:
    xs = [x for x in xs if x > 0]
    if not xs:
        return float("nan")
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def normalized(values: dict[str, float], baseline: dict[str, float]
               ) -> list[float]:
    """Per-instance ratios value/baseline over the strict intersection."""
    out = []
    for k, v in values.items():
        b = baseline.get(k)
        if b is not None and b > 0 and v > 0:
            out.append(v / b)
    return out


def mechanism_rates(rows: Iterable[dict]) -> dict[str, float]:
    rows = list(rows)
    tot_tasks = sum(r["total_tasks"] for r in rows)
    if tot_tasks == 0:
        return {"xdev_edge": float("nan"), "cache_score": float("nan"),
                "model_cont": float("nan")}
    return {
        "xdev_edge": sum(r["cross_device_edges"] for r in rows) / tot_tasks,
        "cache_score": sum(r["prefix_hits_est"] for r in rows) / tot_tasks,
        "model_cont": sum(r["same_model_continuations"]
                          for r in rows) / tot_tasks,
    }
