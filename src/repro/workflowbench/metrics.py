"""Metric definitions (paper Appendix C.2).

NormMS(m)  = exp( mean_i log(T_{m,i} / T_{RR,i}) )
NormP95(m) = exp( mean_i log(L95_{m,i} / L95_{RR,i}) )
XDevEdge   = Σ cross_device_parent_edges / Σ workflow_tasks
CacheScore = Σ prefix_cache_hits_est / Σ workflow_tasks
ModelCont  = Σ same_model_continuations / Σ workflow_tasks

Serving metrics (shared-frontier suite): per-workflow makespan is
finish − arrival, P95 is the 95th-percentile per-query latency relative
to arrival, both normalized per instance against the baseline policy
and geomeaned; goodput is completed workflows (and queries) per second
of busy horizon.

SLO control-plane metrics (``slo_summary``): attainment is SLO-met
workflows over OFFERED workflows (rejected arrivals count against it);
SLO goodput is SLO-met workflows per second of busy horizon — shedding
load only pays off if the admitted set actually meets its deadlines.
"""
from __future__ import annotations

import math
from typing import Iterable, Sequence


def geomean(xs: Sequence[float]) -> float:
    xs = [x for x in xs if x > 0]
    if not xs:
        return float("nan")
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def normalized(values: dict[str, float], baseline: dict[str, float]
               ) -> list[float]:
    """Per-instance ratios value/baseline over the strict intersection."""
    out = []
    for k, v in values.items():
        b = baseline.get(k)
        if b is not None and b > 0 and v > 0:
            out.append(v / b)
    return out


def serving_summary(results: dict, baseline: str = "RoundRobin"
                    ) -> dict[str, dict]:
    """Aggregate ``{policy: ServingResult}`` into normalized serving
    metrics: geomean per-workflow makespan/P95 ratios vs ``baseline``
    (strict instance intersection), goodput, and contention stats."""
    base = results.get(baseline)
    out: dict[str, dict] = {}
    for pol, res in results.items():
        ms_ratios, p95_ratios = [], []
        if base is not None:
            for wid, s in res.stats.items():
                b = base.stats.get(wid)
                if b is None:
                    continue
                if b.makespan > 0 and s.makespan > 0:
                    ms_ratios.append(s.makespan / b.makespan)
                if b.p95 > 0 and s.p95 > 0:
                    p95_ratios.append(s.p95 / b.p95)
        out[pol] = {
            "norm_ms": geomean(ms_ratios),
            "norm_p95": geomean(p95_ratios),
            "goodput_wps": res.goodput_wps,
            "goodput_qps": res.goodput_qps,
            "mean_makespan": (sum(s.makespan for s in res.stats.values())
                              / len(res.stats) if res.stats
                              else float("nan")),
            "max_in_flight": res.max_in_flight,
            "replans": res.replans,
            "model_switches": res.model_switches,
            "n": len(res.stats),
        }
    return out


def _pooled_p95(latencies: Sequence[float]) -> float:
    """Nearest-rank 95th percentile of a pooled latency sample."""
    from repro.core.executor import nearest_rank_p95
    return nearest_rank_p95(latencies)


def slo_summary(results: dict) -> dict[str, dict]:
    """Aggregate ``{label: ServingResult}`` into SLO control-plane
    metrics.

    Per label: ``slo_attainment`` (SLO-met workflows / offered —
    rejected arrivals count against it), ``goodput_slo_wps`` (SLO-met
    workflows per second of busy horizon, the objective the control
    plane optimizes), ``rejection_rate``, pooled per-query
    ``p95_latency`` over completed workflows, and the deferral /
    preemption / replan counters.
    """
    out: dict[str, dict] = {}
    for label, res in results.items():
        lat = [v for s in res.stats.values() for v in s.latencies]
        offered = res.n_offered
        out[label] = {
            "n_offered": offered,
            "n_completed": len(res.stats),
            "n_rejected": len(res.rejected),
            "rejection_rate": (len(res.rejected) / offered
                               if offered else float("nan")),
            "slo_attainment": res.slo_attainment,
            "goodput_slo_wps": res.goodput_slo_wps,
            "goodput_wps": res.goodput_wps,
            "p95_latency": _pooled_p95(lat),
            "mean_latency": (sum(lat) / len(lat) if lat
                             else float("nan")),
            "deferrals": res.deferrals,
            "preemptions": res.preemptions,
            "replans": res.replans,
        }
    return out


def class_summary(res) -> dict[str, dict]:
    """Per-admission-class breakdown of one
    :class:`~repro.core.scheduler.ServingResult`.

    Classes come from ``res.classes`` (every OFFERED workflow id, so
    rejected and failed arrivals are attributed to their class too);
    workflows a pre-multiclass run produced (empty ``classes``) fall
    back to the per-stat ``klass`` label.  Per class:

    * ``slo_attainment`` — SLO-met completions over offered in-class
      arrivals (rejections and fault-failures count against it);
    * ``completion_rate`` — completed over offered (the bottom-class
      starvation gate asserts this is 1.0);
    * ``mean_wait`` / ``max_wait`` — end-to-end makespan
      (finish − arrival, queueing included): the bounded-wait side of
      the anti-starvation guarantee;
    * ``p95_latency`` — pooled per-query p95 over in-class completions;
    * offered / completed / rejected / failed counts.
    """
    klass_of = dict(res.classes)
    for wid, s in res.stats.items():
        klass_of.setdefault(wid, s.klass)
    for wid in list(res.rejected) + list(res.failed):
        klass_of.setdefault(wid, "default")
    out: dict[str, dict] = {}
    for klass in sorted(set(klass_of.values())):
        wids = {w for w, k in klass_of.items() if k == klass}
        stats = [s for w, s in res.stats.items() if w in wids]
        n_rej = sum(1 for w in res.rejected if w in wids)
        n_fail = sum(1 for w in res.failed if w in wids)
        offered = len(stats) + n_rej + n_fail
        lat = [v for s in stats for v in s.latencies]
        waits = [s.makespan for s in stats]
        met = sum(1 for s in stats if s.slo_met)
        out[klass] = {
            "n_offered": offered,
            "n_completed": len(stats),
            "n_rejected": n_rej,
            "n_failed": n_fail,
            "slo_attainment": (met / offered if offered
                               else float("nan")),
            "completion_rate": (len(stats) / offered if offered
                                else float("nan")),
            "mean_wait": (sum(waits) / len(waits) if waits
                          else float("nan")),
            "max_wait": (max(waits) if waits else float("nan")),
            "p95_latency": _pooled_p95(lat),
        }
    return out


def rebase_result(res, t0: "float | None" = None):
    """Normalize a :class:`~repro.core.scheduler.ServingResult` onto
    the scheduler clock: shift every absolute timestamp so the
    earliest arrival sits at zero (or at an explicit ``t0``).

    Gateway-injected runs timestamp arrivals from wall-clock
    submission, so their absolute times start at an arbitrary offset
    instead of the trace-time origin the summaries were written
    against.  Every summary metric is difference-based (makespan,
    latencies, P95, SLO slack), so the shift changes nothing for
    trace-driven runs — this helper exists so the wall-clock
    assumption is handled in ONE place rather than per-summary.
    Returns a new result (the input is not mutated); a result already
    at the origin (or with no completions) is returned as-is.
    """
    import dataclasses
    if not res.stats:
        return res
    if t0 is None:
        t0 = min(s.arrival for s in res.stats.values())
    if abs(t0) < 1e-12:
        return res
    stats = {}
    for wid, s in res.stats.items():
        stats[wid] = dataclasses.replace(
            s, arrival=s.arrival - t0, finish=s.finish - t0,
            query_completion=[t - t0 for t in s.query_completion],
            deadline=(s.deadline - t0
                      if s.deadline is not None else None))
    return dataclasses.replace(res, stats=stats)


def _median(xs: Sequence[float]) -> float:
    """``statistics.median`` with NaN (not ValueError) on empty input —
    the robust center the probe-error gate compares, insensitive to the
    one-off tail blowups an overloaded trace produces."""
    import statistics
    return statistics.median(xs) if xs else float("nan")


def probe_error_summary(records: Sequence) -> dict[str, float]:
    """Aggregate an admission controller's ``probe_log``
    (:class:`repro.core.admission.ProbeRecord` list) into
    predicted-vs-observed probe accuracy metrics.

    ``median_abs_err`` / ``mean_abs_err`` are over
    ``|margin · predicted − observed|`` seconds — the quantity the
    online EWMA correction shrinks and the ``sched_bench --calibrate``
    gate compares against the hand-set-margin baseline.
    ``median_ratio`` is the raw ``observed / predicted`` ratio (what a
    perfectly-converged margin would equal); ``mean_margin`` the
    margins actually applied.
    """
    errs = [r.abs_error for r in records]
    ratios = [r.observed / r.predicted for r in records
              if r.predicted > 1e-9]
    return {
        "n": len(errs),
        "median_abs_err": _median(errs),
        "mean_abs_err": (sum(errs) / len(errs) if errs
                         else float("nan")),
        "median_ratio": _median(ratios),
        "mean_margin": (sum(r.margin for r in records) / len(records)
                        if records else float("nan")),
    }


def mechanism_rates(rows: Iterable[dict]) -> dict[str, float]:
    """Mechanism proxies per task (Appendix C.2): cross-device edge
    rate, estimated prefix-cache hit rate, same-model continuation
    rate, over a set of run-row dicts."""
    rows = list(rows)
    tot_tasks = sum(r["total_tasks"] for r in rows)
    if tot_tasks == 0:
        return {"xdev_edge": float("nan"), "cache_score": float("nan"),
                "model_cont": float("nan")}
    return {
        "xdev_edge": sum(r["cross_device_edges"] for r in rows) / tot_tasks,
        "cache_score": sum(r["prefix_hits_est"] for r in rows) / tot_tasks,
        "model_cont": sum(r["same_model_continuations"]
                          for r in rows) / tot_tasks,
    }


def chaos_summary(results: dict) -> dict[str, dict]:
    """Fault-tolerance summary per labelled serving run.

    ``results`` maps a run label (e.g. ``"fault-free"``, ``"chaos"``)
    to a :class:`~repro.core.scheduler.ServingResult`.  Each row
    reports completion accounting (offered / completed / failed /
    completion rate over admitted work), the horizon, and the fault
    machinery counters — the quantities the chaos gate asserts on.
    """
    out: dict[str, dict] = {}
    for label, res in results.items():
        n_completed = len(res.stats)
        n_admitted = n_completed + len(res.failed)
        out[label] = {
            "n_offered": res.n_offered,
            "n_completed": n_completed,
            "n_rejected": len(res.rejected),
            "n_failed": len(res.failed),
            "completion_rate": (n_completed / n_admitted
                                if n_admitted else float("nan")),
            "horizon": res.horizon,
            "device_downs": res.device_downs,
            "shard_failures": res.shard_failures,
            "retries": res.retries,
            "stragglers": res.stragglers,
            "speculations": res.speculations,
        }
    return out
