"""Manifest runner: executes every scheduler on every workflow instance
under the same runtime and exports one CSV per experiment
(paper Appendix C.4 — "Evaluation pipeline and result provenance").

These entry points are BACK-COMPAT WRAPPERS over the event-driven
scheduler core: every call lowers its per-call knobs
(``score_params`` / ``cost_params`` / ``calibration`` / ``slo`` /
``policy_kwargs``) into a typed
:class:`~repro.core.scheduler.SchedulerConfig` and runs through the
executor adapters.  New code should build a ``SchedulerConfig`` and
drive :class:`~repro.core.scheduler.Scheduler` directly (see
``docs/API.md``); the ``policy_kwargs`` escape hatch emits a
``DeprecationWarning``.
"""
from __future__ import annotations

import csv
import dataclasses
import warnings
from pathlib import Path
from typing import Optional, Sequence

from repro.core.admission import SLOConfig
from repro.core.calibration import CalibrationProfile
from repro.core.costs import CostParams
from repro.core.devices import Cluster, homogeneous_cluster
from repro.core.executor import (ServingExecutor, ServingResult,
                                 WorkflowExecutor, fresh_state)
from repro.core.scheduler import SchedulerConfig
from repro.core.scoring import ScoreParams
from repro.core.workflow import Workflow

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "workflow"


@dataclasses.dataclass
class RunRow:
    """One (workflow, policy) batch-run record — a CSV row of the
    Table 1 analogue, including solver statistics when available."""
    wid: str
    family: str
    policy: str
    num_queries: int
    makespan: float
    p95: float
    cross_device_edges: int
    prefix_hits_est: float
    same_model_continuations: float
    total_tasks: int
    model_switches: int
    solver_ms_mean: float = 0.0
    solver_ms_max: float = 0.0
    solver_solves: int = 0
    solver_all_optimal: bool = True

    def as_dict(self) -> dict:
        """Flat dict of every field (CSV export order)."""
        return dataclasses.asdict(self)


def _load_calibration(calibration: Optional[CalibrationProfile],
                      cost_params: Optional[CostParams]
                      ) -> tuple[Optional[dict], Optional[CostParams]]:
    """Lower a calibration profile onto runner inputs: the per-model
    profiles dict for ``fresh_state`` and the calibrated
    :class:`CostParams` (the explicit ``cost_params`` argument is the
    base the profile's scales are applied over)."""
    if calibration is None:
        return None, cost_params
    return (calibration.model_profiles(),
            calibration.cost_params(cost_params))


def _warn_policy_kwargs(policy_kwargs: Optional[dict]) -> dict:
    """Deprecation shim for the untyped ``policy_kwargs`` escape hatch
    (superseded by typed :class:`SchedulerConfig` fields)."""
    if policy_kwargs:
        warnings.warn(
            "policy_kwargs is deprecated; express planner knobs as "
            "SchedulerConfig fields (use_matrix/use_delta/warm_start/"
            "time_limit/max_waves/score/cost) and drive "
            "repro.core.scheduler.Scheduler directly",
            DeprecationWarning, stacklevel=3)
    return dict(policy_kwargs or {})


def _legacy_config(policy_name: str, *,
                   score_params: Optional[ScoreParams] = None,
                   lowered_cost: Optional[CostParams] = None,
                   calibration: Optional[CalibrationProfile] = None,
                   slo: Optional[SLOConfig] = None,
                   policy_kwargs: Optional[dict] = None
                   ) -> SchedulerConfig:
    """Lower one legacy (kwarg-threaded) run description onto a typed
    :class:`SchedulerConfig`.

    Preserves the historical quirks exactly so wrapper runs stay
    bit-identical to the pre-redesign executors: the FATE planner sees
    ``cost_params`` only when a calibration profile was loaded (the
    executor always prices with them), and ``score_params`` falls back
    to defaults.  ``calibration`` itself is pre-lowered by the caller
    (``lowered_cost``), so the config embeds no profile.
    """
    return SchedulerConfig(
        policy=policy_name,
        policy_kwargs=dict(policy_kwargs or {}),
        score=score_params if score_params is not None else ScoreParams(),
        cost=lowered_cost if calibration is not None else None,
        slo=slo)


def run_one(wf: Workflow, policy_name: str, cluster: Cluster, *,
            score_params: Optional[ScoreParams] = None,
            cost_params: Optional[CostParams] = None,
            calibration: Optional[CalibrationProfile] = None,
            policy_kwargs: Optional[dict] = None) -> RunRow:
    """Run one workflow under one policy on a fresh state.

    Honors the workflow's ``meta["preload_model"]`` (cache-dominant
    suites start with the model resident fleet-wide).  With a
    ``calibration`` profile, the execution state's model profiles, the
    executor's cost params, and the FATE planner's cost params all load
    the profile's fitted constants (single source of truth).  Returns
    the :class:`RunRow` with mechanism proxies and solver stats filled
    in.
    """
    kwargs = _warn_policy_kwargs(policy_kwargs)
    profiles, cost_params = _load_calibration(calibration, cost_params)
    state = fresh_state(cluster, profiles=profiles)
    preload = wf.meta.get("preload_model")
    if preload:
        for d in cluster.ids():
            state.residency[d] = preload
    config = _legacy_config(policy_name, score_params=score_params,
                            lowered_cost=cost_params,
                            calibration=calibration,
                            policy_kwargs=kwargs)
    policy = config.build_policy()
    ex = WorkflowExecutor(state, cost_params)
    res = ex.run(wf, policy)
    row = RunRow(
        wid=wf.wid, family=wf.family, policy=policy_name,
        num_queries=wf.num_queries, makespan=res.makespan, p95=res.p95,
        cross_device_edges=res.cross_device_edges,
        prefix_hits_est=res.prefix_hits_est,
        same_model_continuations=res.same_model_continuations,
        total_tasks=res.total_tasks, model_switches=res.model_switches)
    log = getattr(policy, "solve_log", None)
    if log:
        times = [r.wall_time * 1e3 for r in log]
        row.solver_ms_mean = sum(times) / len(times)
        row.solver_ms_max = max(times)
        row.solver_solves = len(times)
        row.solver_all_optimal = all(r.status == "OPTIMAL" for r in log)
    return row


def run_suite(workflows: Sequence[Workflow], policies: Sequence[str],
              cluster: Optional[Cluster] = None, *,
              score_params: Optional[ScoreParams] = None,
              cost_params: Optional[CostParams] = None,
              calibration: Optional[CalibrationProfile] = None,
              csv_name: Optional[str] = None) -> list[RunRow]:
    """Run every (workflow × policy) pair on fresh per-run states and
    optionally export one CSV (``results/workflow/<csv_name>``)."""
    cluster = cluster or homogeneous_cluster(8)
    rows: list[RunRow] = []
    for wf in workflows:
        for pol in policies:
            rows.append(run_one(wf, pol, cluster,
                                score_params=score_params,
                                cost_params=cost_params,
                                calibration=calibration))
    if csv_name:
        export_csv(rows, csv_name)
    return rows


def export_csv(rows: Sequence[RunRow], name: str) -> Path:
    """Write batch-run rows to ``results/workflow/<name>``; returns
    the path."""
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    path = RESULTS_DIR / name
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].as_dict()))
        w.writeheader()
        for r in rows:
            w.writerow(r.as_dict())
    return path


def run_serving(trace: Sequence[tuple[float, Workflow]],
                policies: Sequence[str],
                cluster: Optional[Cluster] = None, *,
                score_params: Optional[ScoreParams] = None,
                cost_params: Optional[CostParams] = None,
                calibration: Optional[CalibrationProfile] = None,
                slo: Optional["SLOConfig"] = None,
                policy_kwargs: Optional[dict] = None,
                csv_name: Optional[str] = None
                ) -> dict[str, ServingResult]:
    """Run one Poisson serving trace under every policy.

    Each policy gets a fresh execution state over the same cluster and
    the same arrival trace (same workflow instances — the generators
    are deterministic, so cross-policy per-workflow ratios are
    meaningful).  With ``slo`` the SLO-aware control plane (admission /
    deferral / preemption) is active; pass
    ``SLOConfig(admission=False, preemption=False)`` to track deadlines
    under unconditional admission (the control-plane baseline), and
    ``SLOConfig(online_margin=True)`` to learn the probe margin online
    from observed completions instead of the hand-set constant.  With
    ``calibration``, every state/executor/planner constant loads the
    profile's fit (see :mod:`repro.core.calibration`).
    ``policy_kwargs`` configure the FATE planner (e.g.
    ``{"use_delta": False, "warm_start": False}`` for parity
    references); like ``score_params`` they are applied to FATE only,
    so mixed-policy comparisons stay valid.  The kwarg path is
    DEPRECATED: new code should express these as
    :class:`~repro.core.scheduler.SchedulerConfig` fields and drive
    the scheduler directly (``docs/API.md`` has the migration table).
    Returns
    ``{policy: ServingResult}``; aggregate with
    :func:`repro.workflowbench.metrics.serving_summary` or
    :func:`repro.workflowbench.metrics.slo_summary`.
    """
    cluster = cluster or homogeneous_cluster(8)
    pk = _warn_policy_kwargs(policy_kwargs)
    profiles, cost_params = _load_calibration(calibration, cost_params)
    results: dict[str, ServingResult] = {}
    for pol_name in policies:
        # policy_kwargs/score_params configure FATE only, so
        # mixed-policy comparisons stay valid (historical contract)
        fate = pol_name == "FATE"
        config = _legacy_config(
            pol_name,
            score_params=score_params if fate else None,
            lowered_cost=cost_params,
            calibration=calibration if fate else None,
            slo=slo, policy_kwargs=pk if fate else None)
        policy = config.build_policy()
        state = fresh_state(cluster, profiles=profiles)
        ex = ServingExecutor(state, cost_params, slo=slo)
        results[pol_name] = ex.run(list(trace), policy)
    if csv_name:
        export_serving_csv(results, csv_name)
    return results


def export_serving_csv(results: dict[str, ServingResult],
                       name: str) -> Path:
    """Write per-workflow serving stats (one row per completed
    workflow per policy) to ``results/workflow/<name>``."""
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    path = RESULTS_DIR / name
    fields = ["policy", "wid", "arrival", "finish", "makespan", "p95",
              "n_stages", "n_queries"]
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=fields)
        w.writeheader()
        for pol, res in results.items():
            for wid, s in sorted(res.stats.items()):
                w.writerow({
                    "policy": pol, "wid": wid, "arrival": s.arrival,
                    "finish": s.finish, "makespan": s.makespan,
                    "p95": s.p95, "n_stages": s.n_stages,
                    "n_queries": len(s.query_completion)})
    return path


def rows_to_tables(rows: Sequence[RunRow], baseline: str = "RoundRobin"):
    """Aggregate rows into the Table 1 style summary."""
    from repro.workflowbench.metrics import geomean, mechanism_rates
    by_policy: dict[str, dict[str, RunRow]] = {}
    for r in rows:
        by_policy.setdefault(r.policy, {})[r.wid] = r
    base = by_policy.get(baseline, {})
    out: dict[str, dict] = {}
    for pol, per_wid in by_policy.items():
        ms_ratios, p95_ratios = [], []
        for wid, r in per_wid.items():
            b = base.get(wid)
            if b and b.makespan > 0:
                ms_ratios.append(r.makespan / b.makespan)
                p95_ratios.append(r.p95 / b.p95)
        mech = mechanism_rates([r.as_dict() for r in per_wid.values()])
        out[pol] = {
            "norm_ms": geomean(ms_ratios),
            "norm_p95": geomean(p95_ratios),
            **mech,
            "n": len(per_wid),
        }
    return out
