"""RWKV-6 ("Finch") blocks: data-dependent-decay linear attention.

Time-mix state: S [B, H, K, V] plus the previous-token shift x_prev;
channel-mix state: previous-token shift.  Chunked parallel form for
train/prefill (per-chunk GEMMs + sequential carry), O(1) decode.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import ParamDef, FSDP, TP


def rwkv6_defs(cfg) -> dict:
    d, dt = cfg.d_model, cfg.dtype
    hd = cfg.rwkv.head_dim
    nh = d // hd
    return {
        "time": {
            "mix": ParamDef((5, d), (None, None), "float32", init="small"),
            "wr": ParamDef((d, d), (FSDP, TP), dt),
            "wk": ParamDef((d, d), (FSDP, TP), dt),
            "wv": ParamDef((d, d), (FSDP, TP), dt),
            "wg": ParamDef((d, d), (FSDP, TP), dt),
            # data-dependent decay: low-rank ddlerp
            "w_decay_a": ParamDef((d, 64), (FSDP, None), dt),
            "w_decay_b": ParamDef((64, d), (None, TP), dt, fan_in_axes=(0,)),
            "decay_base": ParamDef((d,), (None,), "float32", init="zeros"),
            "bonus": ParamDef((nh, hd), (TP, None), "float32", init="small"),
            "wo": ParamDef((d, d), (TP, FSDP), dt),
            "ln": ParamDef((d,), (None,), "float32", init="zeros"),
        },
        "channel": {
            "mix": ParamDef((2, d), (None, None), "float32", init="small"),
            "wk": ParamDef((d, cfg.d_ff), (FSDP, TP), dt),
            "wv": ParamDef((cfg.d_ff, d), (TP, FSDP), dt),
            "wr": ParamDef((d, d), (FSDP, TP), dt),
        },
    }


def _token_shift(x: jax.Array, x_prev: Optional[jax.Array]):
    """Shifted sequence (previous token), carrying last token as state."""
    if x.shape[1] == 1:
        prev = x_prev if x_prev is not None else jnp.zeros_like(x)
        return prev, x
    shifted = jnp.concatenate(
        [x_prev if x_prev is not None
         else jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    return shifted, x[:, -1:]


def _wkv_chunked(r, k, v, w, bonus, chunk, state0=None):
    """Chunked RWKV6 recurrence.

    r,k,v: [B,S,H,D]; w: [B,S,H,D] per-channel decay in (0,1).
    state S_t = diag(w_t) S_{t-1} + k_t v_t^T ;  out_t = r_t (S_{t-1} + bonus k_t v_t^T)
    Returns (out [B,S,H,D], final state [B,H,D,D]).
    """
    b, s, h, d = r.shape
    nc = s // chunk
    rf = r.reshape(b, nc, chunk, h, d).astype(jnp.float32)
    kf = k.reshape(b, nc, chunk, h, d).astype(jnp.float32)
    vf = v.reshape(b, nc, chunk, h, d).astype(jnp.float32)
    lw = jnp.log(jnp.clip(w.reshape(b, nc, chunk, h, d)
                          .astype(jnp.float32), 1e-6, 1 - 1e-6))
    cum = jnp.cumsum(lw, axis=2)                           # [B,nc,L,H,D]

    def step(state, inp):
        rc, kc, vc, lwc, cumc = inp                        # [B,L,H,D]...
        # decay from chunk start up to (but excluding) position i
        dec_in = jnp.exp(cumc - lwc)                       # prod w_1..w_{i-1}
        # inter-chunk: (r_i ⊙ decay(<i)) @ S_prev
        y_st = jnp.einsum("blhk,bhkv->blhv", rc * dec_in, state)
        # intra-chunk causal part (factorized — no [B,i,j,H,D] blowup)
        y_in = _intra_chunk(rc, kc, vc, lwc, cumc, bonus)
        # state update: S_new = decay(total) S + sum_j decay(j+1..L) k_j v_j^T
        total = cumc[:, -1]                                # [B,H,D]
        tail = jnp.exp(total[:, None] - cumc)              # [B,L,H,D]
        st_new = jnp.einsum("blhk,blhv->bhkv", kc * tail, vc)
        state = state * jnp.exp(total)[..., None] + st_new
        return state, y_st + y_in

    if state0 is None:
        state0 = jnp.zeros((b, h, d, d), jnp.float32)
    final, ys = jax.lax.scan(
        step, state0,
        (rf.swapaxes(0, 1), kf.swapaxes(0, 1), vf.swapaxes(0, 1),
         lw.swapaxes(0, 1), cum.swapaxes(0, 1)))
    return ys.swapaxes(0, 1).reshape(b, s, h, d), final


def _intra_chunk(rc, kc, vc, lwc, cumc, bonus):
    """Causal intra-chunk contribution, exact pairwise log-space form.

    score(i,j) = sum_k r_ik * k_jk * exp(cum_i - lw_i - cum_j)_k  (i>j).
    The exponent is a sum of per-step log-decays over s in (j, i), hence
    always <= 0 — numerically safe for any decay magnitude (the
    factorized e^{cum_i}·e^{-cum_j} split overflows; this form cannot).
    Chunk length is kept small (cfg.rwkv.chunk) so the [B,L,L,H,D]
    pairwise tensor stays VMEM-sized.  Diagonal adds the bonus term
    r_i (bonus ⊙ k_i) v_i.
    """
    li = jnp.arange(rc.shape[1])
    dij = cumc[:, :, None] - lwc[:, :, None] - cumc[:, None]  # [B,i,j,H,D]
    strict = (li[:, None] > li[None, :])[None, :, :, None, None]
    pair = jnp.where(strict, jnp.exp(jnp.minimum(dij, 0.0)), 0.0)
    scores = jnp.einsum("bihk,bijhk,bjhk->bijh", rc, pair, kc)
    y = jnp.einsum("bijh,bjhv->bihv", scores, vc)
    diag = jnp.einsum("bihk,bihk->bih", rc * bonus[None, None], kc)
    return y + diag[..., None] * vc


def rwkv6_time_mix(p: dict, cfg, x: jax.Array, state: dict):
    """Returns (out, new_state); state: {"shift": [B,1,d], "wkv": [B,H,D,D]}."""
    t = p["time"]
    hd = cfg.rwkv.head_dim
    nh = cfg.d_model // hd
    b, s, d = x.shape
    shifted, last = _token_shift(x, state.get("shift"))
    mix = t["mix"].astype(x.dtype)                         # [5, d]
    xs = [x + (shifted - x) * mix[i][None, None] for i in range(5)]
    r = jnp.einsum("bsd,de->bse", xs[0], t["wr"]).reshape(b, s, nh, hd)
    k = jnp.einsum("bsd,de->bse", xs[1], t["wk"]).reshape(b, s, nh, hd)
    v = jnp.einsum("bsd,de->bse", xs[2], t["wv"]).reshape(b, s, nh, hd)
    gate = jax.nn.silu(jnp.einsum("bsd,de->bse", xs[3], t["wg"])
                       .astype(jnp.float32))
    dec = jnp.einsum("bsd,dr->bsr", xs[4], t["w_decay_a"])
    dec = jnp.einsum("bsr,rd->bsd", jnp.tanh(dec.astype(jnp.float32))
                     .astype(x.dtype), t["w_decay_b"])
    # w in (0,1): exp(-exp(base + dec))
    w = jnp.exp(-jnp.exp(t["decay_base"][None, None]
                         + dec.astype(jnp.float32)))
    w = w.reshape(b, s, nh, hd)

    if s == 1:
        st = state["wkv"]
        rf, kf, vf = (a[:, 0].astype(jnp.float32) for a in (r, k, v))
        out = jnp.einsum("bhk,bhkv->bhv", rf, st)
        out = out + jnp.einsum("bhk,hk,bhk->bh", rf, t["bonus"], kf)[..., None] * vf
        st = st * w[:, 0][..., None] + jnp.einsum("bhk,bhv->bhkv", kf, vf)
        y = out.reshape(b, 1, d)
        new = {"shift": last, "wkv": st}
    else:
        pad = (-s) % cfg.rwkv.chunk
        if pad:
            # state-neutral padding: k=v=0 and w=1 leave the state intact
            zp = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
            r_, k_, v_ = zp(r), zp(k), zp(v)
            w_ = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                         constant_values=1.0)
        else:
            r_, k_, v_, w_ = r, k, v, w
        y, st = _wkv_chunked(r_, k_, v_, w_, t["bonus"], cfg.rwkv.chunk,
                             state.get("wkv"))
        y = y[:, :s].reshape(b, s, d)
        new = {"shift": last, "wkv": st}
    y = _ln(y, t["ln"], cfg.norm_eps) * gate.reshape(b, s, d).astype(jnp.float32)
    return jnp.einsum("bsd,de->bse", y.astype(x.dtype), t["wo"]), new


def rwkv6_channel_mix(p: dict, cfg, x: jax.Array, state: dict):
    c = p["channel"]
    shifted, last = _token_shift(x, state.get("cshift"))
    mix = c["mix"].astype(x.dtype)
    xk = x + (shifted - x) * mix[0][None, None]
    xr = x + (shifted - x) * mix[1][None, None]
    k = jnp.einsum("bsd,df->bsf", xk, c["wk"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    kv = jnp.einsum("bsf,fd->bsd", k, c["wv"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, c["wr"])
                       .astype(jnp.float32)).astype(x.dtype)
    return r * kv, {"cshift": last}


def _ln(y: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    yf = y.astype(jnp.float32)
    mu = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.var(yf, axis=-1, keepdims=True)
    return (yf - mu) * jax.lax.rsqrt(var + eps) * (1.0 + gamma)


def rwkv6_state_defs(cfg, batch: int) -> dict:
    hd = cfg.rwkv.head_dim
    nh = cfg.d_model // hd
    return {
        "shift": ((batch, 1, cfg.d_model), cfg.dtype),
        "wkv": ((batch, nh, hd, hd), "float32"),
        "cshift": ((batch, 1, cfg.d_model), cfg.dtype),
    }
