"""Mixture-of-experts with sort-based capacity dispatch (TPU-native).

TPU prefers regular GEMMs over scatter: tokens are sorted by assigned
expert, gathered into a dense [E, C, d] block and processed with one
grouped einsum per FFN matrix — the XLA analogue of a MegaBlocks grouped
GEMM, with experts sharded on the ``model`` axis (expert parallelism)
when divisible, falling back to within-expert tensor parallelism.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamDef, FSDP, TP


def moe_defs(cfg) -> dict:
    m, d, dt = cfg.moe, cfg.d_model, cfg.dtype
    e = m.num_experts
    # Expert weights: experts on the model axis (EP) when divisible;
    # launch.mesh.filter_specs falls back to d_expert sharding otherwise.
    defs = {
        "router": ParamDef((d, e), (FSDP, None), "float32"),
        "w_gate": ParamDef((e, d, m.d_expert), (TP, FSDP, None), dt),
        "w_up": ParamDef((e, d, m.d_expert), (TP, FSDP, None), dt),
        "w_down": ParamDef((e, m.d_expert, d), (TP, None, FSDP), dt,
                           fan_in_axes=(1,)),
    }
    if m.num_shared_experts:
        ds = m.d_shared * m.num_shared_experts
        defs["shared"] = {
            "gate": ParamDef((d, ds), (FSDP, TP), dt),
            "up": ParamDef((d, ds), (FSDP, TP), dt),
            "down": ParamDef((ds, d), (TP, FSDP), dt),
        }
    return defs


def _capacity(tokens: int, cfg) -> int:
    m = cfg.moe
    c = int(tokens * m.top_k / m.num_experts * m.capacity_factor)
    return max(8, (c + 7) // 8 * 8)


def apply_moe(p: dict, cfg, x: jax.Array) -> jax.Array:
    """x: [B, S, d] -> [B, S, d].

    Dispatch is PER SAMPLE (capacity, sort and scatter batched over B):
    a global token sort would contract across the data-parallel batch
    dim and force GSPMD to all-gather every token to every chip — the
    dominant collective in the §Perf baseline (EXPERIMENTS.md iteration
    2).  Per-sample dispatch keeps the batch dim intact, so DP sharding
    flows through the whole MoE layer; the expert GEMMs contract only
    sample-local dims.
    """
    m = cfg.moe
    b, s, d = x.shape
    cap = _capacity(s, cfg)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    top_g, top_e = jax.lax.top_k(gates, m.top_k)              # [B, S, K]
    top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)

    # flatten (token, k) pairs within each sample; sort by expert
    flat_e = top_e.reshape(b, s * m.top_k)
    flat_t = jnp.broadcast_to(
        jnp.repeat(jnp.arange(s), m.top_k)[None], (b, s * m.top_k))
    flat_g = top_g.reshape(b, s * m.top_k)
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    se = jnp.take_along_axis(flat_e, order, axis=-1)
    st = jnp.take_along_axis(flat_t, order, axis=-1)
    sg = jnp.take_along_axis(flat_g, order, axis=-1)
    # position within expert = running index − first occurrence index
    first_idx = jax.vmap(jnp.searchsorted)(
        se, jnp.broadcast_to(jnp.arange(m.num_experts),
                             (b, m.num_experts)))
    pos_in_e = (jnp.arange(se.shape[-1])[None]
                - jnp.take_along_axis(first_idx, se, axis=-1))
    keep = pos_in_e < cap
    slot = jnp.where(keep, se * cap + pos_in_e, m.num_experts * cap)

    xt = jnp.take_along_axis(x, st[..., None], axis=1)        # [B,S*K,d]
    gathered = jnp.zeros((b, m.num_experts * cap + 1, d), x.dtype)
    gathered = _batched_scatter_set(gathered, slot,
                                    xt * keep[..., None])
    xe = gathered[:, :-1].reshape(b, m.num_experts, cap, d)

    # When the expert count doesn't divide the model axis (granite: 40
    # experts, 16-wide axis) EP is impossible and GSPMD resolves the
    # d-contraction by partial-summing multi-GB activations across the
    # data axis; gathering the (tiny) expert weights at the use point is
    # orders of magnitude cheaper (§Perf iteration 3).
    wg, wu, wd = p["w_gate"], p["w_up"], p["w_down"]
    if _replicate_expert_weights(m.num_experts):
        from jax.sharding import PartitionSpec as P
        rep = P(None, None, None)
        wg = jax.lax.with_sharding_constraint(wg, rep)
        wu = jax.lax.with_sharding_constraint(wu, rep)
        wd = jax.lax.with_sharding_constraint(wd, rep)

    from repro.models.layers import DP, TP, shard_activation
    xe = shard_activation(xe, DP, TP, None, None)
    g = shard_activation(jnp.einsum("becd,edf->becf", xe, wg),
                         DP, TP, None, None)
    u = shard_activation(jnp.einsum("becd,edf->becf", xe, wu),
                         DP, TP, None, None)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xe.dtype) * u
    ye = shard_activation(jnp.einsum("becf,efd->becd", h, wd),
                          DP, TP, None, None)

    yf = ye.reshape(b, m.num_experts * cap, d)
    safe_slot = jnp.minimum(slot, m.num_experts * cap - 1)
    picked = jnp.take_along_axis(yf, safe_slot[..., None], axis=1)
    contrib = jnp.where(keep, sg, 0.0)[..., None].astype(yf.dtype)
    y = _batched_scatter_add(jnp.zeros((b, s, d), yf.dtype), st,
                             picked * contrib * keep[..., None])

    if m.num_shared_experts:
        from repro.models.layers import apply_ffn
        y = y + apply_ffn(p["shared"], x)
    return y


def _replicate_expert_weights(num_experts: int) -> bool:
    from repro.models.layers import get_axis_env
    env = get_axis_env()
    if env is None:
        return False
    mesh = env.get("mesh")
    if mesh is None or "model" not in mesh.axis_names:
        return False
    tp = mesh.shape["model"]
    return tp > 1 and num_experts % tp != 0


def _batched_scatter_set(target, idx, updates):
    """target[b, idx[b, i]] = updates[b, i] (batched scatter-set)."""
    def one(t, i, u):
        return t.at[i].set(u)
    return jax.vmap(one)(target, idx, updates)


def _batched_scatter_add(target, idx, updates):
    def one(t, i, u):
        return t.at[i].add(u)
    return jax.vmap(one)(target, idx, updates)


def aux_load_balance_loss(p: dict, cfg, x: jax.Array) -> jax.Array:
    """Switch-style auxiliary load-balancing loss (used by train_step)."""
    m = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    _, top_e = jax.lax.top_k(gates, m.top_k)
    frac = jnp.mean(jax.nn.one_hot(top_e, m.num_experts, dtype=jnp.float32),
                    axis=(0, 1))
    prob = jnp.mean(gates, axis=0)
    return m.num_experts * jnp.sum(frac * prob)
