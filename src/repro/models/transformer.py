"""Model assembly: stacked-parameter blocks + lax.scan over layers.

Exposes a uniform ``Model`` facade per architecture family with:
  * ``param_defs()``      — ParamDef tree (shapes + PartitionSpecs)
  * ``init(key)``         — concrete params (smoke tests / examples)
  * ``forward(params, batch)``            — logits (train/prefill math)
  * ``train_loss(params, batch)``         — mean xent (+ MoE aux)
  * ``init_cache(batch, max_len)``        — abstract/concrete cache
  * ``prefill(params, tokens, cache)``    — fills cache, returns logits
  * ``decode_step(params, token, cache, pos)`` — one-token step

Layer stacking: per-layer params are stacked on a leading axis and the
layer loop is a ``jax.lax.scan`` (+ ``jax.checkpoint`` for remat), so
the lowered HLO stays compact even for 60-layer models — essential for
the 512-device AOT dry-run on a single CPU host.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (DP, FSDP, TP, ParamDef, abstract_params,
                                 apply_ffn, embed_defs, ffn_defs,
                                 init_params, norm_defs, param_specs,
                                 rms_norm, stack_defs, unembed_logits)

Cache = Any


def _shard(x, *spec):
    """Sharding constraint; resolves the DP placeholder via the active
    axis environment and is a no-op when no mesh env is set (CPU tests)."""
    from repro.models.layers import resolve_spec
    rs = resolve_spec(spec)
    if rs is None:
        return x
    return jax.lax.with_sharding_constraint(x, P(*rs))


def _remat(fn, enabled: bool):
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable) if enabled else fn


# ---------------------------------------------------------------------------
# Dense / MoE / MLA decoder-only LM
# ---------------------------------------------------------------------------


class DecoderLM:
    """GQA or MLA decoder-only LM; optional MoE FFN; optional
    local:global sliding-window interleave (gemma3); optional VLM patch
    embeddings (llava) via ``extra_embeds``."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.n_global, self.n_local = self._layer_split()

    # --- layer pattern -----------------------------------------------------
    def _layer_split(self):
        cfg = self.cfg
        if not cfg.local_global_pattern:
            return cfg.num_layers, 0
        pat = cfg.local_global_pattern
        n_global = cfg.num_layers // pat
        return n_global, cfg.num_layers - n_global

    def layer_kinds(self) -> list[str]:
        """Execution order of layer kinds ('L' local / 'G' global)."""
        cfg = self.cfg
        if not cfg.local_global_pattern:
            return ["G"] * cfg.num_layers
        pat = cfg.local_global_pattern
        out = []
        for i in range(cfg.num_layers):
            out.append("G" if (i + 1) % pat == 0 else "L")
        return out

    # --- params ------------------------------------------------------------
    def _block_defs(self, is_moe_layer: bool) -> dict:
        cfg = self.cfg
        d = {
            "ln_attn": norm_defs(cfg.d_model),
            "ln_ffn": norm_defs(cfg.d_model),
        }
        if cfg.attention == "mla":
            d["attn"] = attn.mla_defs(cfg)
        else:
            d["attn"] = attn.gqa_defs(cfg)
        if is_moe_layer:
            d["moe"] = moe_mod.moe_defs(cfg)
        else:
            d["ffn"] = ffn_defs(cfg.d_model, cfg.d_ff, cfg.dtype)
        return d

    def param_defs(self) -> dict:
        cfg = self.cfg
        defs: dict[str, Any] = {
            "embed": embed_defs(cfg.vocab_size, cfg.d_model, cfg.dtype),
            "ln_f": norm_defs(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            defs["head"] = ParamDef((cfg.d_model, cfg.vocab_size),
                                    (FSDP, TP), cfg.dtype)
        if cfg.moe is not None and cfg.moe_layer_start > 0:
            defs["dense_blocks"] = stack_defs(
                self._block_defs(False), cfg.moe_layer_start)
            defs["blocks"] = stack_defs(
                self._block_defs(True),
                cfg.num_layers - cfg.moe_layer_start)
        elif cfg.local_global_pattern:
            defs["local_blocks"] = stack_defs(
                self._block_defs(cfg.moe is not None), self.n_local)
            defs["global_blocks"] = stack_defs(
                self._block_defs(cfg.moe is not None), self.n_global)
        else:
            defs["blocks"] = stack_defs(
                self._block_defs(cfg.moe is not None), cfg.num_layers)
        return defs

    def init(self, key: jax.Array) -> dict:
        return init_params(self.param_defs(), key)

    def specs(self) -> dict:
        return param_specs(self.param_defs())

    # --- forward -----------------------------------------------------------
    def _block(self, p: dict, cfg, x, positions, *, window: int,
               cache=None, cache_len=0):
        h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
        if cfg.attention == "mla":
            a, new_cache = attn.mla_attend(p["attn"], cfg, h, positions,
                                           cache=cache, cache_len=cache_len)
        else:
            a, new_cache = attn.gqa_attend(p["attn"], cfg, h, positions,
                                           window=window, cache=cache,
                                           cache_len=cache_len)
        x = x + a
        h = rms_norm(x, p["ln_ffn"], cfg.norm_eps)
        if "moe" in p:
            f = moe_mod.apply_moe(p["moe"], cfg, h)
        else:
            f = apply_ffn(p["ffn"], h)
        return x + f, new_cache

    def _run_stack(self, stacked: dict, x, positions, *, window: int,
                   caches=None, cache_len=0, remat=True):
        cfg = self.cfg

        from repro.models.attention import seq_parallel_degree
        from repro.models.layers import shard_activation
        n_sp = seq_parallel_degree(cfg.num_heads)

        def constrain(xc):
            # sequence-parallel archs keep tokens sharded on the model
            # axis between attention calls (Megatron-SP style): all
            # per-token work then divides by the model axis too.
            # MoE blocks are excluded: their per-sample sort/scatter
            # dispatch contracts along S, and S-sharding there forces
            # per-layer all-gathers (§Perf iteration 3) — attention
            # still sequence-parallelizes internally via the vmap lane.
            if (n_sp > 1 and cfg.moe is None
                    and xc.shape[1] % n_sp == 0 and xc.shape[1] > 1):
                return shard_activation(xc, DP, TP, None)
            return _shard(xc, DP, None, None)

        def body(carry, layer):
            xc = carry
            p, cache = layer
            xc = constrain(xc)
            out, new_cache = self._block(p, cfg, xc, positions,
                                         window=window, cache=cache,
                                         cache_len=cache_len)
            return out, new_cache

        if caches is None:
            def body_nc(carry, p):
                out, _ = _remat(
                    lambda pp, xx: self._block(pp, cfg, xx, positions,
                                               window=window),
                    remat and cfg.remat)(p, constrain(carry))
                return out, None
            x, _ = jax.lax.scan(body_nc, x, stacked)
            return x, None
        x, new_caches = jax.lax.scan(body, x, (stacked, caches))
        return x, new_caches

    def _embed_tokens(self, params, tokens, extra_embeds=None):
        cfg = self.cfg
        x = params["embed"][tokens]          # [B, S, d]
        if cfg.tie_embeddings or cfg.name.startswith("gemma"):
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        if extra_embeds is not None:
            # VLM: first P positions come from the (stub) vision frontend
            pnum = extra_embeds.shape[1]
            x = jnp.concatenate(
                [extra_embeds.astype(x.dtype), x[:, pnum:]], axis=1)
        return _shard(x, DP, None, None)

    def forward(self, params: dict, tokens: jax.Array,
                extra_embeds: Optional[jax.Array] = None,
                remat: bool = True) -> jax.Array:
        cfg = self.cfg
        x = self._embed_tokens(params, tokens, extra_embeds)
        positions = jnp.arange(tokens.shape[1])[None, :]
        x = self._apply_layers(params, x, positions, remat=remat)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = self._logits(params, x)
        return logits

    def _logits(self, params, x):
        cfg = self.cfg
        w = params["embed"] if cfg.tie_embeddings else params["head"]
        logits = unembed_logits(x, w, cfg.tie_embeddings)
        return _shard(logits, DP, None, TP)

    def _apply_layers(self, params, x, positions, *, caches=None,
                      cache_len=0, remat=True):
        cfg = self.cfg
        if cfg.local_global_pattern:
            return self._apply_interleaved(params, x, positions,
                                           caches=caches,
                                           cache_len=cache_len, remat=remat)
        if "dense_blocks" in params:
            c0 = caches["dense"] if caches else None
            x, nc0 = self._run_stack(params["dense_blocks"], x, positions,
                                     window=0, caches=c0,
                                     cache_len=cache_len, remat=remat)
            c1 = caches["moe"] if caches else None
            x, nc1 = self._run_stack(params["blocks"], x, positions,
                                     window=0, caches=c1,
                                     cache_len=cache_len, remat=remat)
            if caches is not None:
                return x, {"dense": nc0, "moe": nc1}
            return x
        c = caches["blocks"] if caches else None
        x, nc = self._run_stack(params["blocks"], x, positions, window=0,
                                caches=c, cache_len=cache_len, remat=remat)
        if caches is not None:
            return x, {"blocks": nc}
        return x

    def _apply_interleaved(self, params, x, positions, *, caches=None,
                           cache_len=0, remat=True):
        """gemma3 5:1 local:global — grouped execution: repeat
        (pattern-1 locals, 1 global) then trailing locals."""
        cfg = self.cfg
        pat = cfg.local_global_pattern
        n_groups = self.n_global
        loc_per_group = pat - 1
        tail = self.n_local - n_groups * loc_per_group

        def slice_stack(tree, lo, hi):
            return jax.tree.map(lambda a: a[lo:hi], tree)

        new_loc, new_glob = [], []
        li = gi = 0
        for g in range(n_groups):
            lp = slice_stack(params["local_blocks"], li, li + loc_per_group)
            lc = (jax.tree.map(lambda a: a[li: li + loc_per_group],
                               caches["local"]) if caches else None)
            x, nlc = self._run_stack(lp, x, positions,
                                     window=cfg.sliding_window, caches=lc,
                                     cache_len=cache_len, remat=remat)
            gp = slice_stack(params["global_blocks"], gi, gi + 1)
            gc = (jax.tree.map(lambda a: a[gi: gi + 1], caches["global"])
                  if caches else None)
            x, ngc = self._run_stack(gp, x, positions, window=0, caches=gc,
                                     cache_len=cache_len, remat=remat)
            li += loc_per_group
            gi += 1
            if caches is not None:
                new_loc.append(nlc)
                new_glob.append(ngc)
        if tail:
            lp = slice_stack(params["local_blocks"], li, li + tail)
            lc = (jax.tree.map(lambda a: a[li: li + tail], caches["local"])
                  if caches else None)
            x, nlc = self._run_stack(lp, x, positions,
                                     window=cfg.sliding_window, caches=lc,
                                     cache_len=cache_len, remat=remat)
            if caches is not None:
                new_loc.append(nlc)
        if caches is not None:
            cat = lambda parts: jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *parts)
            return x, {"local": cat(new_loc), "global": cat(new_glob)}
        return x

    # --- loss --------------------------------------------------------------
    def train_loss(self, params: dict, batch: dict) -> jax.Array:
        cfg = self.cfg
        logits = self.forward(params, batch["tokens"],
                              batch.get("extra_embeds"))
        loss = softmax_xent(logits, batch["labels"])
        if cfg.moe is not None:
            # aux loss on the mean over MoE layers is folded into the
            # router grads via one representative evaluation (cheap proxy
            # — full per-layer aux is available in training.trainer).
            pass
        return loss

    # --- caches ------------------------------------------------------------
    def _kv_cache_shape(self, batch: int, max_len: int):
        cfg = self.cfg
        if cfg.attention == "mla":
            m = cfg.mla
            return (batch, max_len, m.kv_lora_rank + m.qk_rope_head_dim)
        return (batch, max_len, cfg.num_kv_heads, cfg.resolved_head_dim)

    def cache_defs(self, batch: int, max_len: int) -> dict:
        """CacheLeaf tree: KV (or compressed-latent) cache per stack.

        The sequence axis is sharded on ``model`` — universal across all
        kv-head counts (several archs have kv_heads not divisible by the
        model axis); XLA turns the softmax over the sharded axis into a
        distributed flash-decoding reduction.
        """
        cfg = self.cfg
        shape = self._kv_cache_shape(batch, max_len)

        def kv_leaf(n, length):
            if cfg.attention == "mla":
                s = (n, batch, length, shape[-1])
                return {"c": CacheLeaf(s, cfg.dtype,
                                       (None, DP, "model", None))}
            s = (n, batch, length) + shape[2:]
            return {
                "k": CacheLeaf(s, cfg.dtype, (None, DP, "model", None, None)),
                "v": CacheLeaf(s, cfg.dtype, (None, DP, "model", None, None)),
            }

        if cfg.local_global_pattern:
            win = min(cfg.sliding_window, max_len)
            return {"local": kv_leaf(self.n_local, win),
                    "global": kv_leaf(self.n_global, max_len)}
        if cfg.moe is not None and cfg.moe_layer_start:
            return {"dense": kv_leaf(cfg.moe_layer_start, max_len),
                    "moe": kv_leaf(cfg.num_layers - cfg.moe_layer_start,
                                   max_len)}
        return {"blocks": kv_leaf(cfg.num_layers, max_len)}

    def init_cache(self, batch: int, max_len: int, abstract: bool = False):
        return materialize_cache(self.cache_defs(batch, max_len), abstract)

    def _cache_tuple(self, c):
        cfg = self.cfg
        if cfg.attention == "mla":
            return c["c"]
        return (c["k"], c["v"])

    # prefill / decode ------------------------------------------------------
    def prefill(self, params: dict, tokens: jax.Array, cache,
                extra_embeds: Optional[jax.Array] = None):
        cfg = self.cfg
        x = self._embed_tokens(params, tokens, extra_embeds)
        positions = jnp.arange(tokens.shape[1])[None, :]
        caches = jax.tree.map(lambda a: a, cache)
        x, new_caches = self._apply_layers(
            params, x, positions,
            caches=self._unwrap(caches), cache_len=0, remat=False)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        return self._logits(params, x[:, -1:]), self._wrap(new_caches)

    def decode_step(self, params: dict, token: jax.Array, cache,
                    pos: jax.Array):
        """token: [B, 1]; pos: scalar int32 — current cache length."""
        cfg = self.cfg
        x = params["embed"][token]
        if cfg.tie_embeddings or cfg.name.startswith("gemma"):
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        x = _shard(x, DP, None, None)
        positions = jnp.full((1, 1), pos, jnp.int32)
        x, new_caches = self._apply_layers(
            params, x, positions, caches=self._unwrap(cache),
            cache_len=pos, remat=False)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        return self._logits(params, x), self._wrap(new_caches)

    # cache trees are stored as dicts {"k":..., "v":...}/{"c":...}; the
    # block functions take tuples — translate at the boundary.
    def _unwrap(self, cache):
        cfg = self.cfg
        def conv(c):
            if cfg.attention == "mla":
                return c["c"]
            return (c["k"], c["v"])
        return {k: conv(v) for k, v in cache.items()}

    def _wrap(self, caches):
        cfg = self.cfg
        def conv(c):
            if cfg.attention == "mla":
                return {"c": c}
            return {"k": c[0], "v": c[1]}
        return {k: conv(v) for k, v in caches.items()}


@dataclasses.dataclass(frozen=True)
class CacheLeaf:
    shape: tuple
    dtype: str
    spec: tuple


def materialize_cache(defs, abstract: bool):
    def mk(leaf: CacheLeaf):
        if abstract:
            return jax.ShapeDtypeStruct(leaf.shape, jnp.dtype(leaf.dtype))
        return jnp.zeros(leaf.shape, jnp.dtype(leaf.dtype))
    return jax.tree.map(mk, defs,
                        is_leaf=lambda x: isinstance(x, CacheLeaf))


def cache_specs(defs):
    return jax.tree.map(lambda l: P(*l.spec), defs,
                        is_leaf=lambda x: isinstance(x, CacheLeaf))


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)
