"""Core pure-JAX building blocks shared by every architecture.

Params are plain nested dicts of arrays.  Every parameter is declared
once as a :class:`ParamDef` carrying its shape, dtype and
``PartitionSpec``; the same declaration tree produces either abstract
``ShapeDtypeStruct`` trees (for the AOT dry-run — no allocation) or
concretely initialized arrays (for CPU smoke tests / examples).

Sharding convention (axes named ``pod``/``data``/``model``):
  * batch / token dims           -> ("pod", "data") combined as DP
  * weight in-features           -> data axis  (FSDP-style 2D sharding)
  * weight out-features / heads /
    experts / vocab              -> model axis (TP / EP)
Dims are sharded only when divisible by the mesh axis size; the spec
tree is built mesh-agnostically and filtered at lowering time by
:func:`repro.launch.mesh.filter_specs`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# Named sharding atoms.  "dp" expands to ("pod", "data") on the
# multi-pod mesh and ("data",) on the single-pod mesh (see launch.mesh).
DP = "__dp__"      # data-parallel composite axis placeholder
FSDP = "data"      # weight in-feature sharding axis
TP = "model"       # tensor/expert-parallel axis

# Active mesh-axis environment.  None => no mesh (CPU smoke tests):
# sharding constraints become no-ops.  Set by repro.launch.mesh.use_mesh.
_AXIS_ENV: dict | None = None


def set_axis_env(env: dict | None) -> None:
    global _AXIS_ENV
    _AXIS_ENV = env


def get_axis_env() -> dict | None:
    return _AXIS_ENV


def resolve_spec(spec: tuple) -> tuple | None:
    """Resolve DP placeholders against the active env; None if no env."""
    if _AXIS_ENV is None:
        return None
    out = []
    for s in spec:
        if s == DP:
            out.append(_AXIS_ENV.get("dp"))
        else:
            out.append(s)
    return tuple(out)


def shard_activation(x, *spec):
    """Sharding constraint with divisibility checks (GSPMD recovery).

    ``spec`` entries: DP (data-parallel composite), TP, or None.  Any
    axis whose size does not divide the dim is dropped — this is the
    §Perf fix for GSPMD losing batch sharding in attention for archs
    whose head counts don't divide the model axis (it then replicated
    the whole computation; see EXPERIMENTS.md §Perf iteration 1).
    """
    env = _AXIS_ENV
    if env is None:
        return x
    import jax as _jax
    from jax.sharding import PartitionSpec as _P
    mesh = env.get("mesh")
    if mesh is None:
        return x

    def axis_size(ax):
        if ax is None:
            return 1
        if isinstance(ax, (tuple, list)):
            n = 1
            for a in ax:
                n *= mesh.shape[a]
            return n
        return mesh.shape[ax]

    entries = []
    for dim, ax in zip(x.shape, spec):
        if ax == DP:
            ax = env.get("dp")
        n = axis_size(ax)
        if ax is not None and n > 1 and dim % n == 0:
            entries.append(tuple(ax) if isinstance(ax, (tuple, list))
                           else ax)
        else:
            entries.append(None)
    return _jax.lax.with_sharding_constraint(x, _P(*entries))


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    spec: tuple[Any, ...]            # PartitionSpec entries (may contain DP)
    dtype: str = "bfloat16"
    init: str = "normal"             # normal | zeros | ones | small
    fan_in_axes: tuple[int, ...] = (-2,)


ParamTree = Any     # nested dict of ParamDef / arrays / ShapeDtypeStruct


def tree_map_defs(fn: Callable[[ParamDef], Any], defs: ParamTree) -> ParamTree:
    return jax.tree.map(fn, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def abstract_params(defs: ParamTree) -> ParamTree:
    return tree_map_defs(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype)), defs)


def param_specs(defs: ParamTree) -> ParamTree:
    return tree_map_defs(lambda d: P(*d.spec), defs)


def init_params(defs: ParamTree, key: jax.Array) -> ParamTree:
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    out = []
    for d, k in zip(leaves, keys):
        dt = jnp.dtype(d.dtype)
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dt))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dt))
        else:
            fan_in = 1
            for ax in d.fan_in_axes:
                fan_in *= d.shape[ax]
            scale = 1.0 / math.sqrt(max(fan_in, 1))
            if d.init == "small":
                scale *= 0.1
            out.append((jax.random.normal(k, d.shape, jnp.float32)
                        * scale).astype(dt))
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Primitive ops
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    angles = angles[..., None, :]                      # [..., S, 1, D/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


def ffn_defs(d_model: int, d_ff: int, dtype: str) -> dict:
    return {
        "gate": ParamDef((d_model, d_ff), (FSDP, TP), dtype),
        "up": ParamDef((d_model, d_ff), (FSDP, TP), dtype),
        "down": ParamDef((d_ff, d_model), (TP, FSDP), dtype),
    }


def apply_ffn(p: dict, x: jax.Array) -> jax.Array:
    return swiglu(x, p["gate"], p["up"], p["down"])


def norm_defs(d_model: int, dtype: str = "float32") -> ParamDef:
    return ParamDef((d_model,), (None,), dtype, init="zeros")


def embed_defs(vocab: int, d_model: int, dtype: str) -> ParamDef:
    return ParamDef((vocab, d_model), (TP, FSDP), dtype, fan_in_axes=(-1,))


def unembed_logits(x: jax.Array, w_embed_or_head: jax.Array,
                   transpose: bool) -> jax.Array:
    if transpose:      # tied: w is [vocab, d]
        return jnp.einsum("...d,vd->...v", x, w_embed_or_head)
    return jnp.einsum("...d,dv->...v", x, w_embed_or_head)


def stack_defs(defs: ParamTree, n: int, axis_spec: Any = None) -> ParamTree:
    """Stack per-layer ParamDefs along a leading layer axis (for lax.scan)."""
    def s(d: ParamDef) -> ParamDef:
        fan = tuple(a - 1 if a >= 0 else a for a in d.fan_in_axes)
        return ParamDef((n,) + d.shape, (axis_spec,) + d.spec, d.dtype,
                        d.init, fan)
    return tree_map_defs(s, defs)
