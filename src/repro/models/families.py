"""Non-(decoder-only-attention) model families + the model registry.

* :class:`RWKVLM`       — rwkv6-3b (attention-free; recurrent state cache)
* :class:`Mamba2Hybrid` — zamba2-2.7b (Mamba2 backbone, shared attention
                          block applied every ``attn_every`` layers)
* :class:`EncDecLM`     — whisper-small (encoder stub-frontend + decoder
                          with self- and cross-attention)

``build_model(cfg)`` dispatches to the right class; every class exposes
the uniform facade described in ``transformer.py``.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (DP, FSDP, TP, ParamDef, abstract_params,
                                 apply_ffn, embed_defs, ffn_defs,
                                 init_params, norm_defs, param_specs,
                                 rms_norm, stack_defs, unembed_logits)
from repro.models.transformer import (CacheLeaf, DecoderLM, _remat, _shard,
                                      materialize_cache, softmax_xent)


# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------


class RWKVLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    def _block_defs(self) -> dict:
        cfg = self.cfg
        d = rwkv_mod.rwkv6_defs(cfg)
        d["ln_time"] = norm_defs(cfg.d_model)
        d["ln_channel"] = norm_defs(cfg.d_model)
        return d

    def param_defs(self) -> dict:
        cfg = self.cfg
        return {
            "embed": embed_defs(cfg.vocab_size, cfg.d_model, cfg.dtype),
            "ln_in": norm_defs(cfg.d_model),
            "ln_f": norm_defs(cfg.d_model),
            "head": ParamDef((cfg.d_model, cfg.vocab_size), (FSDP, TP),
                             cfg.dtype),
            "blocks": stack_defs(self._block_defs(), cfg.num_layers),
        }

    def init(self, key):
        return init_params(self.param_defs(), key)

    def specs(self):
        return param_specs(self.param_defs())

    def _block(self, p, x, state):
        cfg = self.cfg
        h = rms_norm(x, p["ln_time"], cfg.norm_eps)
        t_out, t_state = rwkv_mod.rwkv6_time_mix(p, cfg, h, state)
        x = x + t_out
        h = rms_norm(x, p["ln_channel"], cfg.norm_eps)
        c_out, c_state = rwkv_mod.rwkv6_channel_mix(p, cfg, h, state)
        return x + c_out, {**t_state, **c_state}

    def _run(self, params, x, states, remat=True):
        cfg = self.cfg

        def body(carry, layer):
            p, st = layer
            out, new_st = _remat(self._block, remat and cfg.remat)(
                p, _shard(carry, DP, None, None), st)
            return out, new_st

        x, new_states = jax.lax.scan(body, x, (params["blocks"], states))
        return x, new_states

    def _fresh_states(self, batch):
        cfg = self.cfg
        defs = rwkv_mod.rwkv6_state_defs(cfg, batch)
        return {k: jnp.zeros((cfg.num_layers,) + s, jnp.dtype(dt))
                for k, (s, dt) in defs.items()}

    def forward(self, params, tokens, extra_embeds=None, remat=True):
        cfg = self.cfg
        x = params["embed"][tokens]
        x = rms_norm(x, params["ln_in"], cfg.norm_eps)
        x = _shard(x, DP, None, None)
        states = self._fresh_states(tokens.shape[0])
        x, _ = self._run(params, x, states, remat=remat)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = unembed_logits(x, params["head"], False)
        return _shard(logits, DP, None, TP)

    def train_loss(self, params, batch):
        return softmax_xent(self.forward(params, batch["tokens"]),
                            batch["labels"])

    def cache_defs(self, batch, max_len):
        cfg = self.cfg
        defs = rwkv_mod.rwkv6_state_defs(cfg, batch)
        spec = {"shift": (None, DP, None, None),
                "wkv": (None, DP, TP, None, None),
                "cshift": (None, DP, None, None)}
        return {k: CacheLeaf((cfg.num_layers,) + s, dt, spec[k])
                for k, (s, dt) in defs.items()}

    def init_cache(self, batch, max_len, abstract=False):
        return materialize_cache(self.cache_defs(batch, max_len), abstract)

    def prefill(self, params, tokens, cache, extra_embeds=None):
        cfg = self.cfg
        x = params["embed"][tokens]
        x = rms_norm(x, params["ln_in"], cfg.norm_eps)
        x = _shard(x, DP, None, None)
        x, states = self._run(params, x, cache, remat=False)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = unembed_logits(x[:, -1:], params["head"], False)
        return _shard(logits, DP, None, TP), states

    def decode_step(self, params, token, cache, pos):
        cfg = self.cfg
        x = params["embed"][token]
        x = rms_norm(x, params["ln_in"], cfg.norm_eps)
        x, states = self._run(params, x, cache, remat=False)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = unembed_logits(x, params["head"], False)
        return _shard(logits, DP, None, TP), states


# ---------------------------------------------------------------------------
# Zamba2: Mamba2 backbone + shared attention block every attn_every layers
# ---------------------------------------------------------------------------


class Mamba2Hybrid:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        assert cfg.ssm is not None
        self.n_attn = (cfg.num_layers // cfg.attn_every
                       if cfg.attn_every else 0)

    def _ssm_block_defs(self):
        cfg = self.cfg
        return {"ln": norm_defs(cfg.d_model),
                "ssm": ssm_mod.mamba2_defs(cfg)}

    def _attn_block_defs(self):
        cfg = self.cfg
        return {"ln_attn": norm_defs(cfg.d_model),
                "ln_ffn": norm_defs(cfg.d_model),
                "attn": attn.gqa_defs(cfg),
                "ffn": ffn_defs(cfg.d_model, cfg.d_ff, cfg.dtype)}

    def param_defs(self):
        cfg = self.cfg
        return {
            "embed": embed_defs(cfg.vocab_size, cfg.d_model, cfg.dtype),
            "ln_f": norm_defs(cfg.d_model),
            "head": ParamDef((cfg.d_model, cfg.vocab_size), (FSDP, TP),
                             cfg.dtype),
            "blocks": stack_defs(self._ssm_block_defs(), cfg.num_layers),
            "shared_attn": self._attn_block_defs(),    # ONE shared block
        }

    def init(self, key):
        return init_params(self.param_defs(), key)

    def specs(self):
        return param_specs(self.param_defs())

    def _attn_block(self, p, x, positions, cache, cache_len):
        cfg = self.cfg
        h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
        a, new_cache = attn.gqa_attend(p["attn"], cfg, h, positions,
                                       cache=cache, cache_len=cache_len)
        x = x + a
        h = rms_norm(x, p["ln_ffn"], cfg.norm_eps)
        return x + apply_ffn(p["ffn"], h), new_cache

    def _run(self, params, x, positions, ssm_states=None, kv_caches=None,
             cache_len=0, decode=False, remat=True):
        """Layer i: mamba block; after every attn_every-th layer the
        shared attention block (same params, per-site KV cache)."""
        cfg = self.cfg
        k = cfg.attn_every
        new_ssm, new_kv = [], []
        for site in range(self.n_attn):
            blk = jax.tree.map(lambda a: a[site * k:(site + 1) * k],
                               params["blocks"])
            st = (jax.tree.map(lambda a: a[site * k:(site + 1) * k],
                               ssm_states) if ssm_states is not None
                  else None)
            x, ns = self._ssm_stack(blk, x, st, decode=decode, remat=remat)
            new_ssm.append(ns)
            kv = (jax.tree.map(lambda a: a[site], kv_caches)
                  if kv_caches is not None else None)
            kv_t = (kv["k"], kv["v"]) if kv is not None else None
            x, nkv = self._attn_block(params["shared_attn"], x, positions,
                                      kv_t, cache_len)
            new_kv.append(nkv)
        tail = cfg.num_layers - self.n_attn * k
        if tail:
            blk = jax.tree.map(lambda a: a[-tail:], params["blocks"])
            st = (jax.tree.map(lambda a: a[-tail:], ssm_states)
                  if ssm_states is not None else None)
            x, ns = self._ssm_stack(blk, x, st, decode=decode, remat=remat)
            new_ssm.append(ns)
        cat = lambda parts: jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *parts)
        states_out = cat(new_ssm) if ssm_states is not None else None
        kv_out = (jax.tree.map(
            lambda *xs: jnp.stack(xs, axis=0),
            *[{"k": c[0], "v": c[1]} for c in new_kv])
            if kv_caches is not None else None)
        return x, states_out, kv_out

    def _ssm_stack(self, blocks, x, states, decode=False, remat=True):
        cfg = self.cfg

        def body(carry, layer):
            p, st = layer
            xc = _shard(carry, DP, None, None)
            h = rms_norm(xc, p["ln"], cfg.norm_eps)
            if decode:
                out, ns = ssm_mod.mamba2_decode(p["ssm"], cfg, h, st)
            else:
                out, ns = ssm_mod.mamba2_forward(p["ssm"], cfg, h, state=st)
            return carry + out, ns

        def body_nostate(carry, p):
            def blk(pp, xx):
                h = rms_norm(xx, pp["ln"], cfg.norm_eps)
                out, _ = ssm_mod.mamba2_forward(pp["ssm"], cfg, h)
                return xx + out
            return _remat(blk, remat and cfg.remat)(p, carry), None

        if states is None:
            x, _ = jax.lax.scan(body_nostate, x, blocks)
            return x, None
        x, new_states = jax.lax.scan(body, x, (blocks, states))
        return x, new_states

    def forward(self, params, tokens, extra_embeds=None, remat=True):
        cfg = self.cfg
        x = params["embed"][tokens]
        x = _shard(x, DP, None, None)
        positions = jnp.arange(tokens.shape[1])[None, :]
        x, _, _ = self._run(params, x, positions, remat=remat)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        return _shard(unembed_logits(x, params["head"], False),
                      DP, None, TP)

    def train_loss(self, params, batch):
        return softmax_xent(self.forward(params, batch["tokens"]),
                            batch["labels"])

    def cache_defs(self, batch, max_len):
        cfg = self.cfg
        ssm_defs = ssm_mod.mamba2_state_defs(cfg, batch)
        hd = cfg.resolved_head_dim
        return {
            "ssm": {k: CacheLeaf((cfg.num_layers,) + s, dt,
                                 (None, DP, TP, None, None) if k == "ssm"
                                 else (None, DP, None, TP))
                    for k, (s, dt) in ssm_defs.items()},
            "kv": {
                "k": CacheLeaf((self.n_attn, batch, max_len,
                                cfg.num_kv_heads, hd), cfg.dtype,
                               (None, DP, "model", None, None)),
                "v": CacheLeaf((self.n_attn, batch, max_len,
                                cfg.num_kv_heads, hd), cfg.dtype,
                               (None, DP, "model", None, None)),
            },
        }

    def init_cache(self, batch, max_len, abstract=False):
        return materialize_cache(self.cache_defs(batch, max_len), abstract)

    def prefill(self, params, tokens, cache, extra_embeds=None):
        cfg = self.cfg
        x = params["embed"][tokens]
        x = _shard(x, DP, None, None)
        positions = jnp.arange(tokens.shape[1])[None, :]
        x, ssm_st, kv = self._run(params, x, positions,
                                  ssm_states=cache["ssm"],
                                  kv_caches=cache["kv"], cache_len=0,
                                  remat=False)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = unembed_logits(x[:, -1:], params["head"], False)
        return _shard(logits, DP, None, TP), {"ssm": ssm_st, "kv": kv}

    def decode_step(self, params, token, cache, pos):
        cfg = self.cfg
        x = params["embed"][token]
        positions = jnp.full((1, 1), pos, jnp.int32)
        x, ssm_st, kv = self._run(params, x, positions,
                                  ssm_states=cache["ssm"],
                                  kv_caches=cache["kv"], cache_len=pos,
                                  decode=True, remat=False)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = unembed_logits(x, params["head"], False)
        return _shard(logits, DP, None, TP), {"ssm": ssm_st, "kv": kv}


# ---------------------------------------------------------------------------
# Whisper-style encoder-decoder
# ---------------------------------------------------------------------------


class EncDecLM:
    """Encoder: bidirectional transformer over (stub) frame embeddings.
    Decoder: causal self-attention + cross-attention to encoder output."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    def _enc_block_defs(self):
        cfg = self.cfg
        return {"ln_attn": norm_defs(cfg.d_model),
                "ln_ffn": norm_defs(cfg.d_model),
                "attn": attn.gqa_defs(cfg),
                "ffn": ffn_defs(cfg.d_model, cfg.d_ff, cfg.dtype)}

    def _dec_block_defs(self):
        d = self._enc_block_defs()
        d["ln_cross"] = norm_defs(self.cfg.d_model)
        d["cross"] = attn.gqa_defs(self.cfg)
        return d

    def param_defs(self):
        cfg = self.cfg
        return {
            "embed": embed_defs(cfg.vocab_size, cfg.d_model, cfg.dtype),
            "pos_enc": ParamDef((cfg.encoder_frames, cfg.d_model),
                                (None, FSDP), cfg.dtype, init="small"),
            "ln_f": norm_defs(cfg.d_model),
            "ln_enc": norm_defs(cfg.d_model),
            "head": ParamDef((cfg.d_model, cfg.vocab_size), (FSDP, TP),
                             cfg.dtype),
            "encoder": stack_defs(self._enc_block_defs(),
                                  cfg.encoder_layers),
            "decoder": stack_defs(self._dec_block_defs(), cfg.num_layers),
        }

    def init(self, key):
        return init_params(self.param_defs(), key)

    def specs(self):
        return param_specs(self.param_defs())

    def encode(self, params, frames, remat=True):
        """frames: [B, T, d] precomputed conv-frontend embeddings (stub)."""
        cfg = self.cfg
        x = frames.astype(jnp.dtype(cfg.dtype))
        x = x + params["pos_enc"][None, : x.shape[1]]
        x = _shard(x, DP, None, None)
        positions = jnp.arange(x.shape[1])[None, :]

        def body(carry, p):
            def blk(pp, xx):
                h = rms_norm(xx, pp["ln_attn"], cfg.norm_eps)
                a, _ = attn.gqa_attend(pp["attn"], cfg, h, positions,
                                       causal=False)
                xx = xx + a
                h = rms_norm(xx, pp["ln_ffn"], cfg.norm_eps)
                return xx + apply_ffn(pp["ffn"], h)
            return _remat(blk, remat and cfg.remat)(p, carry), None

        x, _ = jax.lax.scan(body, x, params["encoder"])
        return rms_norm(x, params["ln_enc"], cfg.norm_eps)

    def _cross_attend(self, p, x, enc_out):
        cfg = self.cfg
        b, sq, _ = x.shape
        q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
        k = jnp.einsum("bsd,dke->bske", enc_out, p["wk"])
        v = jnp.einsum("bsd,dke->bske", enc_out, p["wv"])
        out = attn.flash_attention(q, k, v, causal=False)
        return jnp.einsum("bshe,hed->bsd", out, p["wo"])

    def _dec_block(self, p, x, positions, enc_out, cache, cache_len):
        cfg = self.cfg
        h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
        a, new_cache = attn.gqa_attend(p["attn"], cfg, h, positions,
                                       cache=cache, cache_len=cache_len)
        x = x + a
        h = rms_norm(x, p["ln_cross"], cfg.norm_eps)
        x = x + self._cross_attend(p["cross"], h, enc_out)
        h = rms_norm(x, p["ln_ffn"], cfg.norm_eps)
        return x + apply_ffn(p["ffn"], h), new_cache

    def decode(self, params, tokens, enc_out, caches=None, cache_len=0,
               remat=True):
        cfg = self.cfg
        x = params["embed"][tokens]
        x = _shard(x, DP, None, None)
        positions = (jnp.arange(tokens.shape[1])[None, :] + cache_len
                     if tokens.shape[1] > 1
                     else jnp.full((1, 1), cache_len, jnp.int32))

        def body(carry, layer):
            p, c = layer
            kv = (c["k"], c["v"])
            out, nkv = self._dec_block(p, carry, positions, enc_out, kv,
                                       cache_len)
            return out, {"k": nkv[0], "v": nkv[1]}

        def body_nc(carry, p):
            def blk(pp, xx):
                out, _ = self._dec_block(pp, xx, positions, enc_out,
                                         None, 0)
                return out
            return _remat(blk, remat and cfg.remat)(p, carry), None

        if caches is None:
            x, _ = jax.lax.scan(body_nc, x, params["decoder"])
            new = None
        else:
            x, new = jax.lax.scan(body, x, (params["decoder"], caches))
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        return _shard(unembed_logits(x, params["head"], False),
                      DP, None, TP), new

    def forward(self, params, tokens, extra_embeds=None, remat=True):
        """extra_embeds = encoder frames [B, T, d]."""
        enc = self.encode(params, extra_embeds, remat=remat)
        logits, _ = self.decode(params, tokens, enc, remat=remat)
        return logits

    def train_loss(self, params, batch):
        logits = self.forward(params, batch["tokens"],
                              batch["extra_embeds"])
        return softmax_xent(logits, batch["labels"])

    def cache_defs(self, batch, max_len):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        kv = lambda: CacheLeaf((cfg.num_layers, batch, max_len,
                                cfg.num_kv_heads, hd), cfg.dtype,
                               (None, DP, "model", None, None))
        return {"self": {"k": kv(), "v": kv()},
                "enc_out": CacheLeaf((batch, cfg.encoder_frames,
                                      cfg.d_model), cfg.dtype,
                                     (DP, None, None))}

    def init_cache(self, batch, max_len, abstract=False):
        return materialize_cache(self.cache_defs(batch, max_len), abstract)

    def prefill(self, params, tokens, cache, extra_embeds=None):
        enc = self.encode(params, extra_embeds, remat=False)
        logits, new_self = self.decode(params, tokens, enc,
                                       caches=cache["self"], cache_len=0)
        return logits[:, -1:], {"self": new_self, "enc_out": enc}

    def decode_step(self, params, token, cache, pos):
        logits, new_self = self.decode(params, token, cache["enc_out"],
                                       caches=cache["self"], cache_len=pos)
        return logits, {"self": new_self, "enc_out": cache["enc_out"]}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def build_model(cfg: ArchConfig):
    if cfg.family == "ssm" and cfg.rwkv is not None:
        return RWKVLM(cfg)
    if cfg.family == "hybrid":
        return Mamba2Hybrid(cfg)
    if cfg.family == "audio":
        return EncDecLM(cfg)
    return DecoderLM(cfg)
