"""Attention in pure JAX: chunked online-softmax ("flash") prefill paths
and cache-based decode paths.

The chunked implementation keeps the materialized score block bounded at
``[B, H, q_chunk, kv_chunk]`` regardless of sequence length — this is the
XLA-path equivalent of the Pallas flash kernel in ``repro.kernels`` and
is what the multi-pod dry-run lowers (Pallas cannot compile for the CPU
backend; the kernels are validated separately in interpret mode).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import (DP, FSDP, TP, ParamDef, apply_rope,
                                 shard_activation)

NEG_INF = -1e30


def _chunk_sizes(s: int, target: int) -> int:
    c = min(s, target)
    while s % c:
        c -= 1
    return c


def seq_parallel_degree(num_heads: int) -> int:
    """Sequence-parallel degree for the XLA attention path: when the
    head count doesn't divide the model axis, attention cannot use the
    model axis via head sharding and GSPMD replicates the whole O(S²)
    computation across it (§Perf iteration 1).  Returns the model-axis
    size to shard the query-chunk dimension over instead, or 1."""
    from repro.models.layers import get_axis_env
    env = get_axis_env()
    if env is None:
        return 1
    mesh = env.get("mesh")
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    tp = mesh.shape["model"]
    return 1 if num_heads % tp == 0 else tp


def flash_attention_sp(q, k, v, *, causal=True, window=0, n_sp=1):
    """Sequence-parallel chunked attention: the outer query-chunk dim is
    a real tensor dim sharded on the model axis (a scan/map dim cannot
    be sharded), with per-lane position offsets for causal masking."""
    b, sq, h, d = q.shape
    if n_sp <= 1 or sq % n_sp or (sq // n_sp) < 1:
        return flash_attention(q, k, v, causal=causal, window=window)
    from repro.models.layers import shard_activation, TP
    qs = q.reshape(b, n_sp, sq // n_sp, h, d)
    qs = shard_activation(qs, DP, TP, None, None, None)
    offs = jnp.arange(n_sp) * (sq // n_sp)

    def lane(qq, off):
        return flash_attention(qq, k, v, causal=causal, window=window,
                               q_offset=off)

    out = jax.vmap(lane, in_axes=(1, 0), out_axes=1)(qs, offs)
    return out.reshape(b, sq, h, d)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    window: int = 0,
                    q_offset: jax.Array | int = 0,
                    q_chunk: int = 512,
                    kv_chunk: int = 512,
                    bias: Optional[jax.Array] = None) -> jax.Array:
    """Chunked online-softmax attention.

    q: [B, Sq, H, D]; k, v: [B, Sk, KV, D] with H % KV == 0 (GQA).
    ``window > 0`` restricts attention to the last ``window`` positions
    (sliding-window / local attention).  ``q_offset`` is the absolute
    position of q[0] relative to k[0] (for chunked prefill with history).
    Returns [B, Sq, H, D].
    """
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    dv = v.shape[-1]
    g = h // kv
    qc = _chunk_sizes(sq, q_chunk)
    kc = _chunk_sizes(sk, kv_chunk)
    nq, nk = sq // qc, sk // kc
    scale = d ** -0.5

    # [B, nq, qc, KV, G, D]
    qr = q.reshape(b, nq, qc, kv, g, d)
    kr = k.reshape(b, nk, kc, kv, d)
    vr = v.reshape(b, nk, kc, kv, dv)

    q_pos = q_offset + jnp.arange(sq).reshape(nq, qc)
    k_pos = jnp.arange(sk).reshape(nk, kc)

    def q_block(args):
        qb, qp = args                        # [B, qc, KV, G, D], [qc]

        def kv_step(carry, inp):
            acc, m, l = carry
            kb, vb, kp = inp                 # [B, kc, KV, D], ..., [kc]
            s = jnp.einsum("bqkgd,bckd->bkgqc", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((qc, kc), dtype=bool)
            if causal:
                mask &= qp[:, None] >= kp[None, :]
            if window:
                mask &= qp[:, None] - kp[None, :] < window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(vb.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc = acc * alpha[..., None] + pv
            return (acc, m_new, l), None

        acc0 = jnp.zeros((b, kv, g, qc, dv), jnp.float32)
        m0 = jnp.full((b, kv, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, qc), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (kr.swapaxes(0, 1), vr.swapaxes(0, 1), k_pos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4)         # [B, qc, KV, G, D]

    out = jax.lax.map(q_block, (qr.swapaxes(0, 1), q_pos))
    out = out.swapaxes(0, 1).reshape(b, sq, h, dv)
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array | int, *,
                     window: int = 0) -> jax.Array:
    """Single-token attention against a KV cache.

    q: [B, 1, H, D]; k_cache/v_cache: [B, S, KV, D]. ``cache_len`` is the
    number of valid cache positions (query position == cache_len).
    The score tensor [B, H, S] is linear in S — decode never materializes
    an S×S object.  With the cache sharded on S, XLA inserts the max/sum
    all-reduces of a distributed (flash-decoding style) softmax.
    """
    b, _, h, d = q.shape
    _, s, kv, _ = k_cache.shape
    g = h // kv
    qr = q.reshape(b, kv, g, d)
    scores = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache,
                        preferred_element_type=jnp.float32) * d ** -0.5
    pos = jnp.arange(s)
    valid = pos < cache_len
    if window:
        valid &= pos >= cache_len - window
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


def gqa_defs(cfg) -> dict:
    d, h, kv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    dt = cfg.dtype
    defs = {
        "wq": ParamDef((d, h, hd), (FSDP, TP, None), dt),
        "wk": ParamDef((d, kv, hd), (FSDP, TP, None), dt),
        "wv": ParamDef((d, kv, hd), (FSDP, TP, None), dt),
        "wo": ParamDef((h, hd, d), (TP, None, FSDP), dt,
                       fan_in_axes=(0, 1)),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((h, hd), (TP, None), dt, init="zeros")
        defs["bk"] = ParamDef((kv, hd), (TP, None), dt, init="zeros")
        defs["bv"] = ParamDef((kv, hd), (TP, None), dt, init="zeros")
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((hd,), (None,), "float32", init="zeros")
        defs["k_norm"] = ParamDef((hd,), (None,), "float32", init="zeros")
    return defs


def _qk_norm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def gqa_project_qkv(p: dict, cfg, x: jax.Array, positions: jax.Array):
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dke->bske", x, p["wk"])
    v = jnp.einsum("bsd,dke->bske", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = _qk_norm(q, p["q_norm"], cfg.norm_eps)
        k = _qk_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # pin batch (and heads when divisible) sharding: GSPMD otherwise
    # replicates attention for head counts that don't divide the model
    # axis (§Perf iteration 1)
    q = shard_activation(q, DP, None, TP, None)
    k = shard_activation(k, DP, None, TP, None)
    v = shard_activation(v, DP, None, TP, None)
    return q, k, v


def gqa_attend(p: dict, cfg, x: jax.Array, positions: jax.Array, *,
               causal: bool = True, window: int = 0,
               cache: Optional[tuple] = None,
               cache_len: jax.Array | int = 0):
    """Full-sequence (train/prefill) or decode attention.

    Returns (out, new_cache).  cache = (k_cache, v_cache) of static shape
    [B, S_max, KV, D]; prefill writes positions [0, Sq); decode appends
    at ``cache_len``.
    """
    b, sq, _ = x.shape
    q, k, v = gqa_project_qkv(p, cfg, x, positions)
    new_cache = None
    if cache is not None:
        k_cache, v_cache = cache
        s_cache = k_cache.shape[1]
        if sq == 1 and window and s_cache == window:
            # rolling window cache: shift left, append at the end; valid
            # entries are the last min(pos+1, W) slots.
            k_cache = jnp.concatenate(
                [k_cache[:, 1:], k.astype(k_cache.dtype)], axis=1)
            v_cache = jnp.concatenate(
                [v_cache[:, 1:], v.astype(v_cache.dtype)], axis=1)
            eff = jnp.minimum(_as_idx(cache_len) + 1, window)
            out = _windowed_decode(q, k_cache, v_cache, eff)
            return _proj_out(p, out), (k_cache, v_cache)
        if sq > 1 and s_cache < sq:
            # prefill longer than the (windowed) cache: keep the tail
            k_cache = k[:, -s_cache:].astype(k_cache.dtype)
            v_cache = v[:, -s_cache:].astype(v_cache.dtype)
        else:
            k_cache = jax.lax.dynamic_update_slice(
                k_cache, k.astype(k_cache.dtype),
                (0, _as_idx(cache_len), 0, 0))
            v_cache = jax.lax.dynamic_update_slice(
                v_cache, v.astype(v_cache.dtype),
                (0, _as_idx(cache_len), 0, 0))
        new_cache = (k_cache, v_cache)
        if sq == 1:   # decode against a full-length cache
            out = decode_attention(q, k_cache, v_cache,
                                   cache_len + 1, window=window)
            return _proj_out(p, out), new_cache
        # prefill attends over freshly computed k/v (cache == prefix here)
    out = flash_attention_sp(q, k, v, causal=causal, window=window,
                             n_sp=seq_parallel_degree(cfg.num_heads))
    return _proj_out(p, out), new_cache


def _windowed_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     eff: jax.Array) -> jax.Array:
    """Decode over a rolling window cache whose last ``eff`` slots are
    valid (newest entry at the end)."""
    b, _, h, d = q.shape
    _, w, kv, _ = k_cache.shape
    g = h // kv
    qr = q.reshape(b, kv, g, d)
    scores = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache,
                        preferred_element_type=jnp.float32) * d ** -0.5
    valid = jnp.arange(w) >= w - eff
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    pr = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", pr.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)


def _proj_out(p: dict, out: jax.Array) -> jax.Array:
    return jnp.einsum("bshe,hed->bsd", out, p["wo"])


def _as_idx(x):
    return x if isinstance(x, jax.Array) else jnp.int32(x)


# ---------------------------------------------------------------------------
# Multi-head latent attention (DeepSeek-V2)
# ---------------------------------------------------------------------------


def mla_defs(cfg) -> dict:
    m, d, h = cfg.mla, cfg.d_model, cfg.num_heads
    dt = cfg.dtype
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    defs = {
        "w_dkv": ParamDef((d, m.kv_lora_rank + m.qk_rope_head_dim),
                          (FSDP, None), dt),
        "w_uk": ParamDef((m.kv_lora_rank, h, m.qk_nope_head_dim),
                         (None, TP, None), dt, fan_in_axes=(0,)),
        "w_uv": ParamDef((m.kv_lora_rank, h, m.v_head_dim),
                         (None, TP, None), dt, fan_in_axes=(0,)),
        "wo": ParamDef((h, m.v_head_dim, d), (TP, None, FSDP), dt,
                       fan_in_axes=(0, 1)),
        "kv_norm": ParamDef((m.kv_lora_rank,), (None,), "float32",
                            init="zeros"),
    }
    if m.q_lora_rank:
        defs["w_dq"] = ParamDef((d, m.q_lora_rank), (FSDP, None), dt)
        defs["w_uq"] = ParamDef((m.q_lora_rank, h, qd), (None, TP, None), dt,
                                fan_in_axes=(0,))
        defs["q_norm"] = ParamDef((m.q_lora_rank,), (None,), "float32",
                                  init="zeros")
    else:
        defs["wq"] = ParamDef((d, h, qd), (FSDP, TP, None), dt)
    return defs


def _mla_queries(p: dict, cfg, x: jax.Array, positions: jax.Array):
    from repro.models.layers import rms_norm
    m = cfg.mla
    if m.q_lora_rank:
        cq = jnp.einsum("bsd,dr->bsr", x, p["w_dq"])
        cq = rms_norm(cq, p["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhe->bshe", cq, p["w_uq"])
    else:
        q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions,
                        cfg.rope_theta)
    return q_nope, q_rope


def mla_attend(p: dict, cfg, x: jax.Array, positions: jax.Array, *,
               cache: Optional[jax.Array] = None,
               cache_len: jax.Array | int = 0):
    """MLA with compressed-KV cache [B, S, kv_lora + rope_dim].

    Decode uses the absorbed-matmul formulation: queries are projected
    into the latent space, so per-step work is O(S * kv_lora) and the
    cache stays compressed (the paper-exact memory saving of MLA).
    """
    from repro.models.layers import rms_norm
    m = cfg.mla
    b, sq, _ = x.shape
    ckv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    c, k_rope = ckv[..., : m.kv_lora_rank], ckv[..., m.kv_lora_rank:]
    c = rms_norm(c, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    q_nope, q_rope = _mla_queries(p, cfg, x, positions)

    new_cache = None
    if cache is not None:
        packed = jnp.concatenate([c, k_rope], axis=-1).astype(cache.dtype)
        cache = jax.lax.dynamic_update_slice(
            cache, packed, (0, _as_idx(cache_len), 0))
        new_cache = cache
        c_all = cache[..., : m.kv_lora_rank]
        kr_all = cache[..., m.kv_lora_rank:]
        if sq == 1:   # absorbed decode
            qa = jnp.einsum("bshe,rhe->bshr", q_nope, p["w_uk"])  # latent q
            s_lat = jnp.einsum("bshr,btr->bhst", qa, c_all)
            s_rope = jnp.einsum("bshe,bte->bhst", q_rope, kr_all)
            scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
            scores = (s_lat + s_rope).astype(jnp.float32) * scale
            t = c_all.shape[1]
            valid = jnp.arange(t) < cache_len + 1
            scores = jnp.where(valid[None, None, None], scores, NEG_INF)
            pr = jax.nn.softmax(scores, axis=-1)
            lat = jnp.einsum("bhst,btr->bshr", pr.astype(c_all.dtype), c_all)
            out = jnp.einsum("bshr,rhe->bshe", lat, p["w_uv"])
            return jnp.einsum("bshe,hed->bsd", out, p["wo"]), new_cache
        c, k_rope = c_all[:, : sq], kr_all[:, : sq]

    # train / prefill: expand k, v per position (flash path)
    k_nope = jnp.einsum("bsr,rhe->bshe", c, p["w_uk"])
    v = jnp.einsum("bsr,rhe->bshe", c, p["w_uv"])
    h = cfg.num_heads
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :],
                                k_rope.shape[:2] + (h, m.qk_rope_head_dim))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    out = flash_attention(q_full, k_full, v, causal=True)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"]), new_cache
