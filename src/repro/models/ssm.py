"""Mamba2 (SSD) blocks — chunked scan for train/prefill, O(1) recurrent
state update for decode.

The chunked formulation converts the per-token recurrence into dense
per-chunk GEMMs (MXU-friendly) plus a short sequential carry over
chunks — the TPU-native adaptation of the CUDA selective-scan kernel.
Recurrent decode state: [B, H, d_head, N] per layer.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import ParamDef, FSDP, TP


def mamba2_defs(cfg) -> dict:
    s, d, dt = cfg.ssm, cfg.d_model, cfg.dtype
    d_in = s.expand * d
    nh = d_in // s.head_dim
    return {
        "w_in": ParamDef((d, 2 * d_in + 2 * s.state_dim + nh),
                         (FSDP, TP), dt),
        "conv": ParamDef((s.conv_width, d_in + 2 * s.state_dim),
                         (None, TP), dt, init="small", fan_in_axes=(0,)),
        "a_log": ParamDef((nh,), (TP,), "float32", init="zeros"),
        "d_skip": ParamDef((nh,), (TP,), "float32", init="ones"),
        "dt_bias": ParamDef((nh,), (TP,), "float32", init="zeros"),
        "norm": ParamDef((d_in,), (TP,), "float32", init="zeros"),
        "w_out": ParamDef((d_in, d), (TP, FSDP), dt),
    }


def _split_proj(p: dict, cfg, x: jax.Array):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z, xbc, dt_raw = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * s.state_dim],
                               axis=-1)
    return z, xbc, dt_raw, d_in, nh


def _causal_conv(xbc: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv, width W.  state: [B, W-1, C] history."""
    wdt = xbc.dtype
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[2]), wdt)
    else:
        pad = state.astype(wdt)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i: i + xbc.shape[1]] * w[i][None, None]
              for i in range(width))
    new_state = xp[:, -(width - 1):] if width > 1 else pad
    return jax.nn.silu(out.astype(jnp.float32)).astype(wdt), new_state


def _ssd_chunked(xh, b, c, dt, a_log, chunk, state0=None):
    """Chunked SSD: xh [B,S,H,P], b/c [B,S,N], dt [B,S,H] (softplus'd).

    Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    bsz, s, h, pdim = xh.shape
    n = b.shape[-1]
    nc = s // chunk
    a = -jnp.exp(a_log)                                   # [H]
    dta = dt * a[None, None]                              # [B,S,H] (<=0)

    xr = xh.reshape(bsz, nc, chunk, h, pdim)
    br = b.reshape(bsz, nc, chunk, n).astype(jnp.float32)
    cr = c.reshape(bsz, nc, chunk, n).astype(jnp.float32)
    dtr = dt.reshape(bsz, nc, chunk, h)
    dtar = dta.reshape(bsz, nc, chunk, h)
    cum = jnp.cumsum(dtar, axis=2)                        # [B,nc,L,H]

    def chunk_step(state, inp):
        xc, bc, cc, dtc, cumc, dtac = inp   # leading dim B
        total = cumc[:, -1]                               # [B,H]
        # intra-chunk (causal) contribution
        li = jnp.arange(chunk)
        decay = cumc[:, :, None, :] - cumc[:, None, :, :]  # [B,i,j,H]
        mask = li[:, None] >= li[None, :]
        g = jnp.where(mask[None, :, :, None], jnp.exp(decay), 0.0)
        sb = jnp.einsum("bin,bjn->bij", cc, bc)           # [B,i,j]
        m = sb[..., None] * g                             # [B,i,j,H]
        y_in = jnp.einsum("bijh,bjh,bjhp->bihp",
                          m, dtc, xc.astype(jnp.float32))
        # inter-chunk: contribution of carried state
        state_decay = jnp.exp(cumc)                       # [B,L,H]
        y_st = jnp.einsum("bin,bhpn,bih->bihp", cc, state, state_decay)
        # update carried state
        in_decay = jnp.exp(total[:, None, :] - cumc)      # [B,L,H]
        st_new = jnp.einsum("blh,blh,blhp,bln->bhpn",
                            in_decay, dtc, xc.astype(jnp.float32), bc)
        state = state * jnp.exp(total)[:, :, None, None] + st_new
        return state, (y_in + y_st)

    if state0 is None:
        state0 = jnp.zeros((bsz, h, pdim, n), jnp.float32)
    final, ys = jax.lax.scan(
        chunk_step, state0,
        (xr.swapaxes(0, 1), br.swapaxes(0, 1), cr.swapaxes(0, 1),
         dtr.swapaxes(0, 1), cum.swapaxes(0, 1), dtar.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1).reshape(bsz, s, h, pdim)
    return y, final


def mamba2_forward(p: dict, cfg, x: jax.Array, *,
                   state: Optional[dict] = None):
    """Full-sequence forward.  Returns (out, new_state) where state
    carries {"ssm": [B,H,P,N], "conv": [B,W-1,C]} for chunked prefill."""
    s = cfg.ssm
    z, xbc, dt_raw, d_in, nh = _split_proj(p, cfg, x)
    conv_state = state["conv"] if state is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv"], conv_state)
    xs, b, c = jnp.split(xbc, [d_in, d_in + s.state_dim], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    xh = xs.reshape(*xs.shape[:2], nh, s.head_dim)
    st0 = state["ssm"] if state is not None else None
    seq = xh.shape[1]
    pad = (-seq) % s.chunk
    if pad:
        # state-neutral padding: dt=0 => no decay and no state update
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        xh, b, c, dt = zp(xh), zp(b), zp(c), zp(dt)
    y, fin = _ssd_chunked(xh, b, c, dt, p["a_log"], s.chunk, st0)
    if pad:
        y = y[:, :seq]
        xh = xh[:, :seq]
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(*xs.shape[:2], d_in).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = _group_norm(y, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    new_state = {"ssm": fin, "conv": new_conv}
    return out, new_state


def mamba2_decode(p: dict, cfg, x: jax.Array, state: dict):
    """Single-token recurrent step.  x: [B, 1, d]."""
    s = cfg.ssm
    z, xbc, dt_raw, d_in, nh = _split_proj(p, cfg, x)
    xbc, new_conv = _causal_conv(xbc, p["conv"], state["conv"])
    xs, b, c = jnp.split(xbc, [d_in, d_in + s.state_dim], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])[:, 0]
    xh = xs.reshape(xs.shape[0], nh, s.head_dim).astype(jnp.float32)
    bv = b[:, 0].astype(jnp.float32)
    cv = c[:, 0].astype(jnp.float32)
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * a[None])                          # [B,H]
    ssm = state["ssm"] * decay[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, bv)
    y = jnp.einsum("bhpn,bn->bhp", ssm, cv)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(x.shape[0], 1, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = _group_norm(y, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, {"ssm": ssm, "conv": new_conv}


def _group_norm(y: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    dt = y.dtype
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps)
            * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def mamba2_state_defs(cfg, batch: int) -> dict:
    """Abstract per-layer state shapes (for cache construction)."""
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    return {
        "ssm": ((batch, nh, s.head_dim, s.state_dim), "float32"),
        "conv": ((batch, s.conv_width - 1, d_in + 2 * s.state_dim),
                 cfg.dtype),
    }
