"""AdamW in pure JAX with memory-lean state layout.

Canonical params are fp32; first/second moments are bf16 (a standard
large-model memory trick — exact-dtype moments cost 8 extra bytes/param
that v5e HBM cannot spare for the 236B config).  Forward computation
casts to the config dtype at use.  The optimizer state inherits each
parameter's PartitionSpec, so ZeRO-style sharding falls out of the
2D-sharded parameter layout for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # int32 scalar
    mu: Any                  # bf16 tree
    nu: Any                  # bf16 tree


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def init_state(params: Any) -> AdamWState:
    z = lambda p: jnp.zeros(p.shape, jnp.bfloat16)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(z, params),
                      nu=jax.tree.map(z, params))


def abstract_state(params: Any) -> AdamWState:
    z = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.bfloat16)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      mu=jax.tree.map(z, params),
                      nu=jax.tree.map(z, params))


def state_specs(specs: Any) -> AdamWState:
    from jax.sharding import PartitionSpec as P
    return AdamWState(step=P(), mu=specs, nu=specs)


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decayed = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, decayed)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params: Any, grads: Any,
                  state: AdamWState) -> tuple[Any, AdamWState]:
    """grads: fp32 tree (already averaged over microbatches/devices)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (delta + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m32.astype(jnp.bfloat16), \
            v32.astype(jnp.bfloat16)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_p = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)
