"""Mesh-independent sharded checkpointing (pure JAX + msgpack).

Layout: one manifest (tree structure, global shapes/dtypes, step) plus
one blob file per host-shard.  Arrays are saved by GLOBAL shape, so a
checkpoint written under one mesh restores under any other mesh (or none)
— the elastic-rescale primitive.  On multi-host deployments each host
writes its addressable shards; this container is single-host, where the
process holds everything.

Fault-tolerance contract used by the trainer:
  * atomic write (tmp dir + rename) — a crash never corrupts the latest
    checkpoint;
  * ``latest_step`` scans for the newest complete manifest;
  * restore validates structure + shapes before any device placement.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str | Path, step: int, tree: Any) -> Path:
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "leaves": [{"shape": list(np.shape(x)),
                    "dtype": str(jnp.asarray(x).dtype)} for x in leaves],
        "format": 1,
    }
    blobs = []
    for x in leaves:
        arr = np.asarray(jax.device_get(x))
        blobs.append(arr.tobytes())
    with open(tmp / "shard_0.msgpack", "wb") as f:
        msgpack.pack(blobs, f)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic publish
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for p in ckpt_dir.iterdir():
        if p.name.startswith("step_") and (p / "manifest.json").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str | Path, step: int,
                       target: Any, shardings: Any = None) -> Any:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings`` optionally re-shards each leaf —
    pass shardings built for a DIFFERENT mesh to rescale elastically."""
    path = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    with open(path / "shard_0.msgpack", "rb") as f:
        blobs = msgpack.unpack(f)
    t_leaves, treedef = _flatten(target)
    if len(blobs) != len(t_leaves):
        raise ValueError(
            f"checkpoint has {len(blobs)} leaves, target {len(t_leaves)}")
    out = []
    infos = manifest["leaves"]
    s_leaves = (jax.tree.flatten(shardings)[0]
                if shardings is not None else [None] * len(t_leaves))
    for blob, info, tgt, sh in zip(blobs, infos, t_leaves, s_leaves):
        arr = np.frombuffer(blob, dtype=np.dtype(info["dtype"])) \
            .reshape(info["shape"])
        if tuple(arr.shape) != tuple(np.shape(tgt)):
            raise ValueError(
                f"shape mismatch: ckpt {arr.shape} vs target "
                f"{np.shape(tgt)}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out)
