"""Fault-tolerant training loop: checkpoint/restart, simulated node
failure, elastic re-mesh, straggler-aware step timing.

Designed for 1000+ node deployments:
  * periodic + emergency checkpoints (atomic, mesh-independent);
  * on failure: rebuild the mesh without the failed slice, restore the
    latest checkpoint under the new shardings, replay data from the
    exact step (deterministic pipeline);
  * step-time watchdog flags stragglers (on real pods this triggers
    hot-spare swap; here it logs and continues — policy pluggable).
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt
from repro.training.data import DataConfig, SyntheticTokens


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "checkpoints"
    log_every: int = 10
    straggler_factor: float = 3.0   # step slower than median×f => flag
    keep_last: int = 3


@dataclasses.dataclass
class TrainReport:
    losses: list[float]
    step_times: list[float]
    straggler_flags: list[int]
    restored_from: Optional[int]
    final_step: int


class Trainer:
    def __init__(self, model_cfg, train_step: Callable, params: Any,
                 opt_state: opt.AdamWState, data: SyntheticTokens,
                 cfg: TrainConfig):
        self.model_cfg = model_cfg
        self.train_step = train_step
        self.params = params
        self.opt_state = opt_state
        self.data = data
        self.cfg = cfg

    # -- fault tolerance hooks -------------------------------------------
    def save(self, step: int) -> None:
        ckpt.save_checkpoint(self.cfg.ckpt_dir, step,
                             {"params": self.params,
                              "opt": self.opt_state})
        self._gc(step)

    def _gc(self, newest: int) -> None:
        root = Path(self.cfg.ckpt_dir)
        steps = sorted(int(p.name.split("_")[1]) for p in root.iterdir()
                       if p.name.startswith("step_"))
        for s in steps[: -self.cfg.keep_last]:
            import shutil
            shutil.rmtree(root / f"step_{s:08d}")

    def try_restore(self, shardings: Any = None) -> Optional[int]:
        last = ckpt.latest_step(self.cfg.ckpt_dir)
        if last is None:
            return None
        tree = ckpt.restore_checkpoint(
            self.cfg.ckpt_dir, last,
            {"params": self.params, "opt": self.opt_state}, shardings)
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        return last

    # -- main loop ---------------------------------------------------------
    def run(self, start_step: int = 0,
            fail_at: Optional[int] = None) -> TrainReport:
        """``fail_at`` simulates a node failure (raises) at that step —
        the driver is expected to restart and resume from checkpoint."""
        losses, times, flags = [], [], []
        restored = self.try_restore()
        step = (restored + 1) if restored is not None else start_step
        while step < self.cfg.steps:
            if fail_at is not None and step == fail_at:
                # emergency checkpoint then die (simulated hardware loss)
                self.save(step - 1)
                raise RuntimeError(f"simulated node failure at step {step}")
            batch = self.data.batch_at(step)
            t0 = time.perf_counter()
            loss, self.params, self.opt_state = self.train_step(
                self.params, self.opt_state, batch)
            loss = float(loss)
            dt = time.perf_counter() - t0
            losses.append(loss)
            times.append(dt)
            med = sorted(times)[len(times) // 2]
            if len(times) > 5 and dt > self.cfg.straggler_factor * med:
                flags.append(step)
            if step % self.cfg.ckpt_every == 0 and step > 0:
                self.save(step)
            step += 1
        self.save(self.cfg.steps - 1)
        return TrainReport(losses, times, flags, restored,
                           self.cfg.steps - 1)
