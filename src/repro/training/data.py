"""Deterministic synthetic token pipeline with skip-replay.

Batches are a pure function of (seed, step), so a restarted/rescaled
job resumes mid-stream exactly: no data is repeated or skipped after a
failure (the "deterministic data-skip replay" straggler/restart story).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234


class SyntheticTokens:
    """Zipf-ish synthetic LM stream; labels are next-token shifted."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # fixed unigram distribution (zipf) for realistic token stats
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = 1.0 / ranks
        self._probs = probs / probs.sum()

    def batch_at(self, step: int) -> dict[str, jnp.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        toks = rng.choice(cfg.vocab_size, p=self._probs,
                          size=(cfg.global_batch, cfg.seq_len + 1))
        toks = toks.astype(np.int32)
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
