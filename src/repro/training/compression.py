"""Gradient compression with error feedback (distributed-optimization
trick for DCN-limited multi-pod training).

int8 block-quantized gradients cut cross-pod all-reduce bytes 4×
(vs fp32 accumulation).  Error feedback keeps the quantization residual
locally and re-adds it next step, preserving convergence (Karimireddy
et al., 2019).  The compressor runs INSIDE the grad-accum loop before
the deferred psum, so what crosses the network is the compressed form.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

BLOCK = 256


def _quantize_leaf(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_leaf(q: jax.Array, scale: jax.Array,
                     shape: tuple, ) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_roundtrip(g: jax.Array) -> jax.Array:
    """Quantize→dequantize one leaf (what the wire would carry)."""
    q, scale = _quantize_leaf(g)
    return _dequantize_leaf(q, scale, g.shape).astype(g.dtype)


def make_error_feedback_compressor():
    """Returns (compress_fn, init_state): grads_hat, new_err =
    compress(grads + err)."""

    def init_state(params: Any) -> Any:
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def compress(grads: Any, err: Any) -> tuple[Any, Any]:
        def one(g, e):
            corrected = g.astype(jnp.float32) + e
            ghat = compress_roundtrip(corrected)
            return ghat.astype(g.dtype), corrected - ghat

        out = jax.tree.map(one, grads, err)
        ghat = jax.tree.map(lambda t: t[0], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda t: t[1], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        return ghat, new_err

    return compress, init_state
