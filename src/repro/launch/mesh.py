"""Production mesh construction + spec filtering.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benches see the real single device.
"""
from __future__ import annotations

import contextlib
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.layers import DP, get_axis_env, resolve_spec, set_axis_env


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many host devices exist (CPU tests)."""
    return jax.make_mesh(shape, axes)


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Enter a mesh and set the DP axis environment for _shard()."""
    axis_names = mesh.axis_names
    dp = ("pod", "data") if "pod" in axis_names else ("data",)
    old = get_axis_env()
    set_axis_env({"dp": dp, "mesh": mesh})
    try:
        with mesh:
            yield mesh
    finally:
        set_axis_env(old)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= _axis_size(mesh, a)
        return out
    return mesh.shape[axis]


def filter_spec(mesh: Mesh, shape: tuple, spec: tuple) -> P:
    """Resolve DP placeholders and drop sharding on non-divisible dims.

    Several configs have head/expert counts that do not divide the model
    axis (e.g. qwen1.5 20 heads, granite 40 experts on a 16-wide axis);
    those dims fall back to replication — the fallback is part of the
    documented sharding policy, not an error.
    """
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    entries = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax == DP:
            ax = dp
        if ax is None:
            entries.append(None)
            continue
        size = _axis_size(mesh, ax)
        if dim % size == 0 and dim >= size:
            entries.append(tuple(ax) if isinstance(ax, (tuple, list)) else ax)
        else:
            # try partial composite: e.g. DP=(pod,data) but dim only
            # divides data
            if isinstance(ax, (tuple, list)):
                for sub in (ax[1:], ax[:1]):
                    ssize = _axis_size(mesh, tuple(sub))
                    if sub and dim % ssize == 0 and dim >= ssize:
                        entries.append(tuple(sub) if len(sub) > 1
                                       else sub[0])
                        break
                else:
                    entries.append(None)
            else:
                entries.append(None)
    return P(*entries)


def shardings_for(mesh: Mesh, abstract: Any, specs: Any) -> Any:
    """NamedSharding tree matching an abstract value tree + spec tree."""
    def mk(av, sp):
        entries = sp if isinstance(sp, P) else P(*sp)
        fs = filter_spec(mesh, av.shape, tuple(entries))
        return NamedSharding(mesh, fs)
    return jax.tree.map(mk, abstract, specs)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
