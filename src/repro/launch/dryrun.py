import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
DOC = """Multi-pod AOT dry-run.

For every (architecture × input shape) cell, lower + compile the
train/prefill/decode step on the production meshes:

    single-pod:  (16, 16)      axes (data, model)          256 chips
    multi-pod:   (2, 16, 16)   axes (pod, data, model)     512 chips

and record memory_analysis / cost_analysis / collective schedule +
roofline terms as one JSON artifact per cell under ``results/dryrun``.
The run is resumable: completed cells are skipped unless --force.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi    # multi-pod only
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs.base import SHAPES, cells_for
from repro.configs.archs import ARCHS
from repro.launch import analysis
from repro.launch.mesh import (filter_spec, make_production_mesh,
                               shardings_for, use_mesh)
from repro.launch.steps import (abstract_serve_params, abstract_train_state,
                                batch_specs_shardings, input_specs,
                                make_decode_step, make_prefill_step,
                                make_train_step)

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _dp_size(mesh) -> int:
    s = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        s *= mesh.shape["pod"]
    return s


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict | None = None) -> dict:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    with use_mesh(mesh):
        specs = input_specs(cfg, shape_name)
        batch_sh = batch_specs_shardings(mesh, cfg, shape_name)
        if shape.kind == "train":
            from jax.sharding import NamedSharding, PartitionSpec as P
            aparams, astate, pspecs, sspecs = abstract_train_state(cfg)
            p_sh = shardings_for(mesh, aparams, pspecs)
            s_sh = type(astate)(
                step=NamedSharding(mesh, P()),
                mu=shardings_for(mesh, astate.mu, sspecs.mu),
                nu=shardings_for(mesh, astate.nu, sspecs.nu))
            step_fn, model = make_train_step(
                cfg, dp_size=_dp_size(mesh),
                global_batch=shape.global_batch)
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_sh, s_sh, batch_sh),
                out_shardings=(NamedSharding(mesh, P()), p_sh, s_sh),
                donate_argnums=(0, 1))
            lowered = jitted.lower(aparams, astate, specs)
        else:
            aparams, pspecs = abstract_serve_params(cfg)
            p_sh = shardings_for(mesh, aparams, pspecs)
            if shape.kind == "prefill":
                step_fn, model = make_prefill_step(cfg)
            else:
                step_fn, model = make_decode_step(cfg)
            jitted = jax.jit(step_fn, in_shardings=(p_sh, batch_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(aparams, specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mf = analysis.model_flops(cfg, shape)
    terms = analysis.roofline_terms(compiled, model_flops_global=mf,
                                    n_chips=n_chips)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_chips": int(n_chips),
        "kind": shape.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "model_flops_global": mf,
        **terms,
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    RESULTS.mkdir(parents=True, exist_ok=True)
    cells = []
    for arch, cfg in ARCHS.items():
        if args.arch and arch != args.arch:
            continue
        for _, shape_name in cells_for(cfg):
            if args.shape and shape_name != args.shape:
                continue
            meshes = (["single", "multi"] if args.mesh == "both"
                      else [args.mesh])
            for m in meshes:
                cells.append((arch, shape_name, m == "multi"))

    print(f"dry-run: {len(cells)} cells", flush=True)
    n_ok = n_fail = n_skip = 0
    for arch, shape_name, multi in cells:
        tag = f"{arch}__{shape_name}__{'multi' if multi else 'single'}"
        out = RESULTS / f"{tag}.json"
        if out.exists() and not args.force:
            prev = json.loads(out.read_text())
            if "error" not in prev:
                n_skip += 1
                continue
        t0 = time.time()
        try:
            rec = run_cell(arch, shape_name, multi)
            out.write_text(json.dumps(rec, indent=1, default=str))
            n_ok += 1
            print(f"OK   {tag:60s} compile={rec['compile_s']:8.1f}s "
                  f"dominant={rec['dominant']:<12s} "
                  f"bound={rec['roofline_bound_s']*1e3:9.2f}ms "
                  f"useful={rec['useful_flop_ratio']:.3f}", flush=True)
        except Exception as e:
            n_fail += 1
            err = {"arch": arch, "shape": shape_name,
                   "mesh": "multi" if multi else "single",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            out.write_text(json.dumps(err, indent=1))
            print(f"FAIL {tag:60s} {type(e).__name__}: {str(e)[:120]}",
                  flush=True)
        finally:
            jax.clear_caches()
    print(f"done: ok={n_ok} fail={n_fail} skipped={n_skip}", flush=True)


if __name__ == "__main__":
    main()
