"""Roofline-term extraction from compiled AOT artifacts.

XLA's built-in ``cost_analysis()`` visits a ``while`` body ONCE, so any
scan-over-layers model is undercounted by ~L×.  We therefore walk the
optimized HLO text ourselves with trip-count multiplication (XLA
annotates ``backend_config={"known_trip_count":{"n":...}}`` on counted
loops) and derive:

  * dot-FLOPs      — 2 · |result| · contraction-size per ``dot``
                     (+ convolution approximation), the standard
                     MFU numerator.
  * HBM bytes      — Σ over top-level ops of (result + operand) bytes;
                     fusion internals stay on-chip (their boundary
                     counts), loop bodies multiply by trip count.
                     This is a "perfect-fusion" traffic model.
  * collective bytes — ring-algorithm estimates per collective op.

All HLO shapes in an SPMD module are per-partition, so every quantity
is per-chip.  Roofline terms with v5e constants:

    compute    = dot_FLOPs / 197e12           [bf16 peak]
    memory     = HBM bytes / 819e9             [HBM BW]
    collective = ring bytes moved / 50e9       [ICI link]

Ring models per collective (size = per-chip result bytes, n = group):
    all-reduce         2 * size * (n-1)/n
    all-gather         size * (n-1)/n
    reduce-scatter     size * (n-1)
    all-to-all         size * (n-1)/n
    collective-permute size
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Any, Optional

PEAK_FLOPS = 197e12       # bf16 per chip, TPU v5e
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s+"
                     r"([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "iota",
    "reshape",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")


def _elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        total += _elems(dims) * _DTYPE_BYTES.get(dt, 0)
    return total


def _shape_dims(text: str) -> list[int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d.strip()]


@dataclasses.dataclass
class _Instr:
    name: str
    result: str        # result shape text
    op: str
    operands: list[str]
    line: str


@dataclasses.dataclass
class _Comp:
    name: str
    instrs: list[_Instr]
    shapes: dict[str, str]   # symbol -> result shape text


def _parse_computations(hlo: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = _Comp(m.group(1), [], {})
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        ins = _parse_instr(line)
        if ins is None:
            continue
        cur.instrs.append(ins)
        cur.shapes[ins.name] = ins.result
    return comps


_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")


def _parse_instr(line: str) -> Optional["_Instr"]:
    """Parse '%name = <type> op(args...), attrs' robustly.

    Tuple result types may contain nested parens and /*index=k*/ comments
    (which contain '='), so the type is skipped by paren balancing rather
    than regex.
    """
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):           # tuple type: skip balanced parens
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    result = rest[: i + 1]
                    rest = rest[i + 1:]
                    break
        else:
            return None
    else:                              # plain shape token
        sp = rest.find(" ")
        if sp < 0:
            return None
        result = rest[:sp]
        rest = rest[sp:]
    rest = rest.lstrip()
    par = rest.find("(")
    if par < 0:
        return None
    op = rest[:par].strip()
    if not op or not re.fullmatch(r"[\w\-]+", op):
        return None
    paren = rest[par + 1:]
    depth = 1
    end = 0
    for i, ch in enumerate(paren):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    operands = _OPERAND_RE.findall(paren[:end])
    return _Instr(name, result, op, operands, line)


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    if "source_target_pairs" in line:
        return 2
    return 1


@dataclasses.dataclass
class HloCost:
    dot_flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_counts: dict = dataclasses.field(default_factory=dict)
    coll_bytes_by_op: dict = dataclasses.field(default_factory=dict)
    dynamic_loops: int = 0

    def add(self, other: "HloCost", mult: float = 1.0) -> None:
        self.dot_flops += other.dot_flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        self.dynamic_loops += other.dynamic_loops
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v * mult
        for k, v in other.coll_bytes_by_op.items():
            self.coll_bytes_by_op[k] = (self.coll_bytes_by_op.get(k, 0.0)
                                        + v * mult)


class HloCostModel:
    """Trip-count-aware cost walker over optimized HLO text."""

    def __init__(self, hlo_text: str):
        self.comps = _parse_computations(hlo_text)
        self._memo: dict[str, HloCost] = {}
        entry = None
        for name in self.comps:
            if name.startswith("main") or ".main" in name:
                entry = name
        if entry is None:    # fall back: last computation in file
            entry = list(self.comps)[-1] if self.comps else None
        self.entry = entry

    def cost(self) -> HloCost:
        if self.entry is None:
            return HloCost()
        return self._comp_cost(self.entry)

    def _comp_cost(self, name: str) -> HloCost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = HloCost()   # cycle guard
        comp = self.comps.get(name)
        if comp is None:
            return self._memo[name]
        c = HloCost()
        for ins in comp.instrs:
            if ins.op == "while":
                m = _TRIP_RE.search(ins.line)
                trip = int(m.group(1)) if m else 1
                if not m:
                    c.dynamic_loops += 1
                body = _BODY_RE.search(ins.line)
                cond = _COND_RE.search(ins.line)
                if body:
                    c.add(self._comp_cost(body.group(1)), trip)
                if cond:
                    c.add(self._comp_cost(cond.group(1)), trip)
                continue
            if ins.op in ("call", "conditional"):
                for callee in _CALLS_RE.findall(ins.line):
                    c.add(self._comp_cost(callee))
                # fall through: no self bytes for call
                continue
            if ins.op == "fusion":
                callee = _CALLS_RE.search(ins.line)
                if callee:
                    sub = self._comp_cost(callee.group(1))
                    # fusions keep internals on-chip: take flops +
                    # collectives, not bytes
                    c.dot_flops += sub.dot_flops
                    c.coll_bytes += sub.coll_bytes
                    c.bytes += self._fusion_bytes(comp, ins,
                                                  callee.group(1))
                else:
                    c.bytes += self._io_bytes(comp, ins)
                continue
            if ins.op == "dynamic-update-slice":
                c.bytes += self._dus_bytes(comp, ins)
                continue
            if ins.op == "dot":
                c.dot_flops += self._dot_flops(comp, ins)
                c.bytes += self._io_bytes(comp, ins)
                continue
            if ins.op == "convolution":
                c.dot_flops += self._conv_flops(comp, ins)
                c.bytes += self._io_bytes(comp, ins)
                continue
            if any(ins.op.startswith(col) for col in _COLLECTIVES):
                if ins.op.endswith("-done"):
                    continue
                base = ins.op.replace("-start", "")
                size = _shape_bytes(ins.result)
                if base == "all-gather" and "-start" in ins.op:
                    # all-gather-start result is a tuple (in, out)
                    size = size // 2
                n = max(_group_size(ins.line), 1)
                if base == "all-reduce":
                    mv = 2 * size * (n - 1) / n
                elif base == "all-gather":
                    mv = size * (n - 1) / n
                elif base == "reduce-scatter":
                    mv = size * (n - 1)
                elif base in ("all-to-all", "ragged-all-to-all"):
                    mv = size * (n - 1) / n
                else:
                    mv = size
                c.coll_bytes += mv
                c.coll_counts[base] = c.coll_counts.get(base, 0) + 1
                c.coll_bytes_by_op[base] = (
                    c.coll_bytes_by_op.get(base, 0.0) + mv)
                c.bytes += self._io_bytes(comp, ins)
                continue
            if ins.op in _NO_TRAFFIC:
                continue
            c.bytes += self._io_bytes(comp, ins)
        self._memo[name] = c
        return c

    def _io_bytes(self, comp: _Comp, ins: _Instr) -> float:
        total = float(_shape_bytes(ins.result))
        for op in ins.operands:
            sh = comp.shapes.get(op)
            if sh is not None:
                total += _shape_bytes(sh)
        return total

    def _dus_bytes(self, comp: _Comp, ins: _Instr) -> float:
        """dynamic-update-slice updates in place: traffic is the slice
        (read+write) plus indices, not the full buffer."""
        if len(ins.operands) >= 2:
            upd = comp.shapes.get(ins.operands[1])
            if upd is not None:
                return 2.0 * _shape_bytes(upd) + 64.0
        return self._io_bytes(comp, ins)

    def _fusion_bytes(self, comp: _Comp, ins: _Instr,
                      callee: str) -> float:
        """Fusion boundary traffic; when the fused computation performs
        an in-place dynamic-update-slice on a parameter that aliases the
        fusion result (the donated-KV-cache pattern), the full buffer is
        neither read nor rewritten — count the updated slice only."""
        sub = self.comps.get(callee)
        result_b = _shape_bytes(ins.result)
        operand_b = 0.0
        largest_op = 0.0
        for op in ins.operands:
            sh = comp.shapes.get(op)
            if sh is not None:
                b = _shape_bytes(sh)
                operand_b += b
                largest_op = max(largest_op, b)
        total = float(result_b + operand_b)
        if sub is not None:
            for i2 in sub.instrs:
                if i2.op == "dynamic-update-slice" and i2.operands:
                    target = sub.shapes.get(i2.operands[0], "")
                    tb = _shape_bytes(target)
                    upd = (_shape_bytes(sub.shapes.get(i2.operands[1],
                                                       ""))
                           if len(i2.operands) > 1 else 0)
                    if tb and abs(tb - result_b) < 1e-6 * max(tb, 1):
                        # in-place update: drop full read+write, keep
                        # the slice write + read
                        total = max(0.0,
                                    total - tb - min(tb, largest_op)
                                    + 2.0 * upd)
                        break
        return total

    def _dot_flops(self, comp: _Comp, ins: _Instr) -> float:
        res_elems = 1
        for dt, dims in _SHAPE_RE.findall(ins.result):
            res_elems = _elems(dims)
            break
        m = _LHS_CDIMS_RE.search(ins.line)
        lhs_shape = comp.shapes.get(ins.operands[0], "") if ins.operands \
            else ""
        ldims = _shape_dims(lhs_shape)
        contract = 1
        if m and ldims:
            for idx in m.group(1).split(","):
                if idx.strip():
                    i = int(idx)
                    if i < len(ldims):
                        contract *= ldims[i]
        return 2.0 * res_elems * contract

    def _conv_flops(self, comp: _Comp, ins: _Instr) -> float:
        res_elems = 1
        for dt, dims in _SHAPE_RE.findall(ins.result):
            res_elems = _elems(dims)
            break
        if len(ins.operands) < 2:
            return 0.0
        kshape = _shape_dims(comp.shapes.get(ins.operands[1], ""))
        if not kshape:
            return 0.0
        # kernel [spatial..., in, out]: per-output MACs = prod(k)/out
        out_f = kshape[-1] if kshape else 1
        per_out = max(1, math.prod(kshape) // max(out_f, 1))
        return 2.0 * res_elems * per_out


def roofline_terms(compiled, *, model_flops_global: float,
                   n_chips: int) -> dict:
    """Derive the three terms + diagnostics from a compiled executable."""
    hlo = compiled.as_text()
    model = HloCostModel(hlo)
    cost = model.cost()

    xla_ca = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        xla_ca = {"flops": float(ca.get("flops", 0.0)),
                  "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
                  "note": "XLA visits while bodies once; see walker values"}
    except Exception as e:   # pragma: no cover
        xla_ca = {"error": str(e)}

    compute_s = cost.dot_flops / PEAK_FLOPS
    memory_s = cost.bytes / HBM_BW
    collective_s = cost.coll_bytes / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception as e:   # pragma: no cover - backend-specific
        mem["error"] = str(e)

    model_flops_chip = model_flops_global / n_chips
    return {
        "hlo_flops_per_chip": cost.dot_flops,
        "hlo_bytes_per_chip": cost.bytes,
        "collective_bytes_per_chip": cost.coll_bytes,
        "collective_counts": cost.coll_counts,
        "collective_bytes_by_op": cost.coll_bytes_by_op,
        "dynamic_loops": cost.dynamic_loops,
        **terms,
        "dominant": dominant,
        "model_flops_per_chip": model_flops_chip,
        "useful_flop_ratio": (model_flops_chip / cost.dot_flops)
        if cost.dot_flops else 0.0,
        "roofline_bound_s": max(terms.values()),
        "memory_analysis": mem,
        "xla_cost_analysis": xla_ca,
    }


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D (train) or 2·N·D (inference) over active params."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch * 1
    return 2.0 * n_active * tokens
