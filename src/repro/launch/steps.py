"""train_step / serve_step builders + input_specs for every arch×shape.

These are the functions the multi-pod dry-run lowers and compiles, and
the same functions the real trainer/server jit on actual devices.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, SHAPES, ShapeConfig
from repro.models.families import build_model
from repro.models.layers import DP, abstract_params, param_specs
from repro.models.transformer import cache_specs, materialize_cache, _shard
from repro.training import optimizer as opt


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def _token_budget(cfg: ArchConfig, shape: ShapeConfig) -> int:
    """Decoder token positions (VLM reserves the patch prefix)."""
    return shape.seq_len


def input_specs(cfg: ArchConfig, shape_name: str) -> dict:
    """Abstract model inputs for one (arch, shape) cell."""
    shape = SHAPES[shape_name] if isinstance(shape_name, str) else shape_name
    gb, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    model = build_model(cfg)
    specs: dict[str, Any] = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((gb, s), i32)
        specs["labels"] = jax.ShapeDtypeStruct((gb, s), i32)
        if cfg.family == "audio":
            specs["extra_embeds"] = jax.ShapeDtypeStruct(
                (gb, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
        elif cfg.family == "vlm":
            specs["extra_embeds"] = jax.ShapeDtypeStruct(
                (gb, cfg.num_patches, cfg.d_model), jnp.bfloat16)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((gb, s), i32)
        specs["cache"] = model.init_cache(gb, s, abstract=True)
        if cfg.family == "audio":
            specs["extra_embeds"] = jax.ShapeDtypeStruct(
                (gb, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
        elif cfg.family == "vlm":
            specs["extra_embeds"] = jax.ShapeDtypeStruct(
                (gb, cfg.num_patches, cfg.d_model), jnp.bfloat16)
    else:  # decode
        specs["token"] = jax.ShapeDtypeStruct((gb, 1), i32)
        specs["cache"] = model.init_cache(gb, s, abstract=True)
        specs["pos"] = jax.ShapeDtypeStruct((), i32)
    return specs


def batch_specs_shardings(mesh, cfg: ArchConfig, shape_name: str):
    """NamedShardings for the input_specs tree."""
    from repro.launch.mesh import filter_spec
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    out = {}
    sp = input_specs(cfg, shape_name)
    for k, v in sp.items():
        if k == "cache":
            cspec = cache_specs(model.cache_defs(shape.global_batch,
                                                 shape.seq_len))
            out[k] = jax.tree.map(
                lambda leaf_sds, leaf_spec: NamedSharding(
                    mesh, filter_spec(mesh, leaf_sds.shape,
                                      tuple(leaf_spec))),
                v, cspec)
        elif k == "pos":
            out[k] = NamedSharding(mesh, P())
        else:
            out[k] = NamedSharding(
                mesh, filter_spec(mesh, v.shape,
                                  (DP,) + (None,) * (len(v.shape) - 1)))
    return out


# ---------------------------------------------------------------------------
# Train step (microbatched grad accumulation + AdamW)
# ---------------------------------------------------------------------------


def resolve_microbatch(cfg: ArchConfig, global_batch: int,
                       dp_size: int) -> int:
    mb = max(cfg.microbatch, dp_size)
    while global_batch % mb:
        mb += dp_size
    return min(mb, global_batch)


def make_train_step(cfg: ArchConfig, *, dp_size: int, global_batch: int,
                    opt_cfg: Optional[opt.AdamWConfig] = None,
                    grad_compression=None):
    """Returns train_step(params_f32, opt_state, batch) -> (loss, params,
    opt_state).  Gradients are accumulated over microbatches with a
    single deferred all-reduce (XLA emits the psum once, after the accum
    scan — communication amortized over microbatches)."""
    model = build_model(cfg)
    ocfg = opt_cfg or opt.AdamWConfig()
    mb = resolve_microbatch(cfg, global_batch, dp_size)
    n_accum = global_batch // mb

    def cast(p):
        if p.dtype == jnp.float32 and p.ndim > 1:
            return p.astype(cfg.dtype)
        return p

    def loss_fn(params, micro):
        cparams = jax.tree.map(cast, params)
        return model.train_loss(cparams, micro)

    def train_step(params, opt_state, batch):
        def reshape(x):
            x = x.reshape((n_accum, mb) + x.shape[1:])
            return x

        micro_batches = jax.tree.map(reshape, batch)

        def accum(carry, micro):
            g_acc, l_acc = carry
            micro = jax.tree.map(lambda x: _shard(
                x, DP, *([None] * (x.ndim - 1))), micro)
            loss, grads = jax.value_and_grad(loss_fn)(params, micro)
            if grad_compression is not None:
                grads = grad_compression(grads)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
            return (g_acc, l_acc + loss), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), _ = jax.lax.scan(
            accum, (g0, jnp.zeros((), jnp.float32)), micro_batches,
            length=n_accum)
        grads = jax.tree.map(lambda g: g / n_accum, grads)
        new_params, new_state = opt.apply_updates(ocfg, params, grads,
                                                  opt_state)
        return loss_sum / n_accum, new_params, new_state

    return train_step, model


def make_prefill_step(cfg: ArchConfig):
    model = build_model(cfg)

    def prefill_step(params, batch):
        ee = batch.get("extra_embeds")
        logits, cache = model.prefill(params, batch["tokens"],
                                      batch["cache"], ee)
        return logits, cache

    return prefill_step, model


def make_decode_step(cfg: ArchConfig):
    model = build_model(cfg)

    def decode_step(params, batch):
        logits, cache = model.decode_step(params, batch["token"],
                                          batch["cache"], batch["pos"])
        return logits, cache

    return decode_step, model


# ---------------------------------------------------------------------------
# Abstract params / optimizer state for the dry-run
# ---------------------------------------------------------------------------


def abstract_train_state(cfg: ArchConfig):
    """(params_f32, opt_state) as ShapeDtypeStructs + matching specs."""
    model = build_model(cfg)
    defs = model.param_defs()
    aparams = abstract_params(defs)
    # canonical fp32 master params
    aparams = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), aparams)
    astate = opt.abstract_state(aparams)
    specs = param_specs(defs)
    sspecs = opt.state_specs(specs)
    return aparams, astate, specs, sspecs


def abstract_serve_params(cfg: ArchConfig):
    model = build_model(cfg)
    defs = model.param_defs()
    return abstract_params(defs), param_specs(defs)
