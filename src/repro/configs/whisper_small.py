"""Arch config module (selectable via --arch)."""
from repro.configs.archs import WHISPER_SMALL as CONFIG
from repro.configs.archs import SMOKE
SMOKE_CONFIG = SMOKE[CONFIG.name]
