"""Arch config module (selectable via --arch)."""
from repro.configs.archs import DEEPSEEK_V2_236B as CONFIG
from repro.configs.archs import SMOKE
SMOKE_CONFIG = SMOKE[CONFIG.name]
