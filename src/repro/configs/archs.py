"""The 10 assigned architecture configs (exact published configurations)
plus reduced same-family smoke configs for CPU tests.

Each arch also has its own module ``repro/configs/<id>.py`` re-exporting
``CONFIG``/``SMOKE_CONFIG`` so ``--arch <id>`` resolves per file.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (ArchConfig, MLAConfig, MoEConfig,
                                RWKVConfig, SSMConfig)

# ---------------------------------------------------------------------------
# Full configs
# ---------------------------------------------------------------------------

GLM4_9B = ArchConfig(
    name="glm4-9b", family="dense", num_layers=40, d_model=4096,
    num_heads=32, num_kv_heads=2, d_ff=13696, vocab_size=151552,
    attention="gqa", rope_theta=10000.0,
    source="hf:THUDM/glm-4-9b; hf",
)

QWEN15_4B = ArchConfig(
    name="qwen1.5-4b", family="dense", num_layers=40, d_model=2560,
    num_heads=20, num_kv_heads=20, d_ff=6912, vocab_size=151936,
    attention="gqa", qkv_bias=True, rope_theta=5000000.0,
    source="hf:Qwen/Qwen1.5-0.5B; hf",
)

GEMMA3_4B = ArchConfig(
    name="gemma3-4b", family="dense", num_layers=34, d_model=2560,
    num_heads=8, num_kv_heads=4, d_ff=10240, vocab_size=262144,
    head_dim=256, attention="gqa", qk_norm=True,
    sliding_window=1024, local_global_pattern=6, rope_theta=1000000.0,
    tie_embeddings=True, sub_quadratic=True,
    source="hf:google/gemma-3-1b-pt; unverified",
)

QWEN3_17B = ArchConfig(
    name="qwen3-1.7b", family="dense", num_layers=28, d_model=2048,
    num_heads=16, num_kv_heads=8, d_ff=6144, vocab_size=151936,
    head_dim=128, attention="gqa", qk_norm=True, rope_theta=1000000.0,
    tie_embeddings=True,
    source="hf:Qwen/Qwen3-8B; hf",
)

GRANITE_MOE_3B = ArchConfig(
    name="granite-moe-3b-a800m", family="moe", num_layers=32,
    d_model=1536, num_heads=24, num_kv_heads=8, d_ff=512,
    vocab_size=49155, attention="gqa", rope_theta=10000.0,
    moe=MoEConfig(num_experts=40, top_k=8, d_expert=512),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)

DEEPSEEK_V2_236B = ArchConfig(
    name="deepseek-v2-236b", family="moe", num_layers=60, d_model=5120,
    num_heads=128, num_kv_heads=128, d_ff=12288, vocab_size=102400,
    attention="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(num_experts=160, top_k=6, d_expert=1536,
                  num_shared_experts=2, d_shared=1536),
    moe_layer_start=1, rope_theta=10000.0, microbatch=8,
    source="arXiv:2405.04434; hf",
)

ZAMBA2_27B = ArchConfig(
    name="zamba2-2.7b", family="hybrid", num_layers=54, d_model=2560,
    num_heads=32, num_kv_heads=32, d_ff=10240, vocab_size=32000,
    attention="gqa", ssm=SSMConfig(state_dim=64, head_dim=64, expand=2),
    attn_every=6, rope_theta=10000.0, sub_quadratic=True,
    source="arXiv:2411.15242; hf",
)

RWKV6_3B = ArchConfig(
    name="rwkv6-3b", family="ssm", num_layers=32, d_model=2560,
    num_heads=40, num_kv_heads=40, d_ff=8960, vocab_size=65536,
    attention="none", rwkv=RWKVConfig(head_dim=64), sub_quadratic=True,
    source="arXiv:2404.05892; hf",
)

LLAVA_NEXT_MISTRAL_7B = ArchConfig(
    name="llava-next-mistral-7b", family="vlm", num_layers=32,
    d_model=4096, num_heads=32, num_kv_heads=8, d_ff=14336,
    vocab_size=32000, attention="gqa", rope_theta=1000000.0,
    num_patches=576,   # base 24x24 grid; anyres tiles are a stub frontend
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)

WHISPER_SMALL = ArchConfig(
    name="whisper-small", family="audio", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=12, d_ff=3072, vocab_size=51865,
    attention="gqa", encoder_layers=12, encoder_frames=1500,
    rope_theta=10000.0,
    source="arXiv:2212.04356; unverified",
)

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in [
        GLM4_9B, QWEN15_4B, GEMMA3_4B, QWEN3_17B, GRANITE_MOE_3B,
        DEEPSEEK_V2_236B, ZAMBA2_27B, RWKV6_3B, LLAVA_NEXT_MISTRAL_7B,
        WHISPER_SMALL,
    ]
}

# ---------------------------------------------------------------------------
# Reduced smoke configs — same family/topology, tiny dims
# ---------------------------------------------------------------------------


def _smoke(cfg: ArchConfig, **over) -> ArchConfig:
    base = dict(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=16, microbatch=2, remat=False,
    )
    base.update(over)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **base)


SMOKE: dict[str, ArchConfig] = {
    "glm4-9b": _smoke(GLM4_9B),
    "qwen1.5-4b": _smoke(QWEN15_4B, num_heads=4, num_kv_heads=4),
    "gemma3-4b": _smoke(GEMMA3_4B, num_layers=7, num_heads=4,
                        num_kv_heads=2, sliding_window=8,
                        local_global_pattern=3),
    "qwen3-1.7b": _smoke(QWEN3_17B),
    "granite-moe-3b-a800m": _smoke(
        GRANITE_MOE_3B,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=32)),
    "deepseek-v2-236b": _smoke(
        DEEPSEEK_V2_236B, num_heads=4, num_kv_heads=4,
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=24, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=32,
                      num_shared_experts=1, d_shared=32),
        moe_layer_start=1, num_layers=3),
    "zamba2-2.7b": _smoke(ZAMBA2_27B, num_layers=5, attn_every=2,
                          ssm=SSMConfig(state_dim=8, head_dim=16, expand=2,
                                        conv_width=4, chunk=4)),
    "rwkv6-3b": _smoke(RWKV6_3B, num_heads=4, num_kv_heads=4,
                       rwkv=RWKVConfig(head_dim=16, chunk=4)),
    "llava-next-mistral-7b": _smoke(LLAVA_NEXT_MISTRAL_7B, num_patches=4),
    "whisper-small": _smoke(WHISPER_SMALL, encoder_layers=2,
                            encoder_frames=12),
}
