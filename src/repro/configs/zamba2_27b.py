"""Arch config module (selectable via --arch)."""
from repro.configs.archs import ZAMBA2_27B as CONFIG
from repro.configs.archs import SMOKE
SMOKE_CONFIG = SMOKE[CONFIG.name]
