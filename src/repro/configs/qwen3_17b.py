"""Arch config module (selectable via --arch)."""
from repro.configs.archs import QWEN3_17B as CONFIG
from repro.configs.archs import SMOKE
SMOKE_CONFIG = SMOKE[CONFIG.name]
