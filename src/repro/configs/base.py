"""Architecture and shape configuration for the repro framework.

Every assigned architecture gets one module in this package exporting
``CONFIG`` (the exact published configuration) and ``SMOKE_CONFIG`` (a
reduced same-family configuration for CPU smoke tests).  The full
configs are exercised only via the AOT dry-run (ShapeDtypeStruct — no
allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                    # per-expert FFN hidden dim
    num_shared_experts: int = 0
    d_shared: int = 0                # hidden dim of the shared expert(s)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0             # 0 => full-rank q projection
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2-style SSD configuration."""
    state_dim: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    # small chunk: the exact pairwise intra-chunk tensor is [B,L,L,H,D]
    chunk: int = 32


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 => d_model // num_heads
    # attention flavour
    attention: str = "gqa"           # gqa | mla | none
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # local/global interleave (gemma3): window size for local layers and
    # the repeating pattern length; layer i is GLOBAL iff (i+1) % pattern == 0.
    sliding_window: int = 0          # 0 => all layers global full attention
    local_global_pattern: int = 0    # e.g. 6 => 5 local : 1 global
    # mixture of experts
    moe: Optional[MoEConfig] = None
    moe_layer_start: int = 0         # dense layers before the first MoE layer
    # MLA
    mla: Optional[MLAConfig] = None
    # SSM / hybrid
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    attn_every: int = 0              # zamba2: shared attn block every k SSM layers
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_frames: int = 1500       # conv-frontend output length (stub)
    # vlm (llava)
    num_patches: int = 0             # patch embeddings prepended (stub frontend)
    # numerics / training
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # distribution knobs (overridable per shape at dry-run time)
    microbatch: int = 16             # micro-batch per grad-accum step (global)
    remat: bool = True
    sub_quadratic: bool = False      # eligible for long_500k
    source: str = ""                 # provenance note

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        n = self.vocab_size * d                       # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d                  # lm head
        per_layer = 0
        if self.attention == "mla" and self.mla is not None:
            m = self.mla
            qd = (m.qk_nope_head_dim + m.qk_rope_head_dim) * self.num_heads
            if m.q_lora_rank:
                per_layer += d * m.q_lora_rank + m.q_lora_rank * qd
            else:
                per_layer += d * qd
            per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            per_layer += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
            per_layer += self.num_heads * m.v_head_dim * d
        elif self.attention == "gqa":
            per_layer += d * self.num_heads * hd      # q
            per_layer += 2 * d * self.num_kv_heads * hd  # k, v
            per_layer += self.num_heads * hd * d      # o
        if self.ssm is not None:
            s = self.ssm
            d_inner = s.expand * d
            nheads = d_inner // s.head_dim
            per_layer_ssm = d * (2 * d_inner + 2 * s.state_dim + nheads)
            per_layer_ssm += d_inner * d + s.conv_width * (d_inner + 2 * s.state_dim)
            per_layer_ssm += nheads  # A_log
        if self.rwkv is not None:
            per_layer += 6 * d * d  # r,k,v,g,o,+decay/bonus approx

        def ffn_params(dff: int) -> int:
            return 3 * d * dff  # SwiGLU

        if self.family == "ssm" and self.rwkv is not None:
            per_layer += 2 * d * self.d_ff  # rwkv channel-mix (k,v) + recept
            per_layer += d * d
        elif self.ssm is None:
            per_layer += ffn_params(self.d_ff)

        n_moe_layers = 0
        if self.moe is not None:
            n_moe_layers = L - self.moe_layer_start
            moe_layer = self.moe.num_experts * 3 * d * self.moe.d_expert
            moe_layer += self.moe.num_shared_experts * 3 * d * self.moe.d_shared
            moe_layer += d * self.moe.num_experts
            dense_layer = per_layer + ffn_params(self.d_ff)
            n += self.moe_layer_start * dense_layer
            n += n_moe_layers * (per_layer + moe_layer)
        elif self.ssm is not None and self.attn_every:
            # zamba2: L ssm layers + shared attention applied every attn_every
            d_inner = self.ssm.expand * d
            nheads = d_inner // self.ssm.head_dim
            ssm_layer = (d * (2 * d_inner + 2 * self.ssm.state_dim + nheads)
                         + d_inner * d
                         + self.ssm.conv_width * (d_inner + 2 * self.ssm.state_dim) + nheads)
            shared_attn = (d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                           + self.num_heads * hd * d + ffn_params(self.d_ff))
            n += L * ssm_layer + shared_attn
        elif self.ssm is not None:
            d_inner = self.ssm.expand * d
            nheads = d_inner // self.ssm.head_dim
            ssm_layer = (d * (2 * d_inner + 2 * self.ssm.state_dim + nheads)
                         + d_inner * d
                         + self.ssm.conv_width * (d_inner + 2 * self.ssm.state_dim) + nheads)
            n += L * ssm_layer
        else:
            n += L * per_layer
        if self.encoder_layers:
            enc_layer = (d * self.num_heads * hd * 2 + 2 * d * self.num_kv_heads * hd * 2
                         + ffn_params(self.d_ff))
            n += self.encoder_layers * enc_layer
        return n

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: routed top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        total = self.param_count()
        m = self.moe
        n_moe_layers = L - self.moe_layer_start
        all_experts = n_moe_layers * m.num_experts * 3 * d * m.d_expert
        active_experts = n_moe_layers * m.top_k * 3 * d * m.d_expert
        return total - all_experts + active_experts


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cells_for(cfg: ArchConfig) -> Sequence[Tuple[str, str]]:
    """All (arch, shape) dry-run cells for one architecture.

    ``long_500k`` requires sub-quadratic attention; it is skipped (and the
    skip is documented in DESIGN.md §4) for pure full-attention archs.
    """
    out = [(cfg.name, "train_4k"), (cfg.name, "prefill_32k"),
           (cfg.name, "decode_32k")]
    if cfg.sub_quadratic:
        out.append((cfg.name, "long_500k"))
    return out
