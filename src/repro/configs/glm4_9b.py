"""Arch config module (selectable via --arch)."""
from repro.configs.archs import GLM4_9B as CONFIG
from repro.configs.archs import SMOKE
SMOKE_CONFIG = SMOKE[CONFIG.name]
