"""Arch config module (selectable via --arch)."""
from repro.configs.archs import QWEN15_4B as CONFIG
from repro.configs.archs import SMOKE
SMOKE_CONFIG = SMOKE[CONFIG.name]
