"""Arch config module (selectable via --arch)."""
from repro.configs.archs import LLAVA_NEXT_MISTRAL_7B as CONFIG
from repro.configs.archs import SMOKE
SMOKE_CONFIG = SMOKE[CONFIG.name]
