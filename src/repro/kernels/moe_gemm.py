"""Pallas TPU grouped (per-expert) GEMM for MoE FFNs.

x [E, C, D] @ w [E, D, F] -> [E, C, F], tiled (bc × bf) with the D
contraction innermost-sequential and an fp32 VMEM accumulator — the
TPU-native replacement for a scatter-based CUDA grouped GEMM: tokens are
pre-sorted into dense per-expert blocks (see ``repro.models.moe``), so
every tile is a regular MXU matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _moe_kernel(x_ref, w_ref, o_ref, acc_scr):
    di = pl.program_id(3)
    nd = pl.num_programs(3)

    @pl.when(di == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0]                                     # [bc, bd]
    w = w_ref[0]                                     # [bd, bf]
    acc_scr[...] += jax.lax.dot(x, w,
                                preferred_element_type=jnp.float32)

    @pl.when(di == nd - 1)
    def _finish():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def moe_gemm(x: jax.Array, w: jax.Array, *, block_c: int = 128,
             block_f: int = 128, block_d: int = 256,
             interpret: bool = False) -> jax.Array:
    """x: [E, C, D]; w: [E, D, F] -> [E, C, F]."""
    e, c, d = x.shape
    _, _, f = w.shape
    block_c = min(block_c, c)
    block_f = min(block_f, f)
    block_d = min(block_d, d)
    grid = (e, pl.cdiv(c, block_c), pl.cdiv(f, block_f),
            pl.cdiv(d, block_d))
    return pl.pallas_call(
        _moe_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, block_d),
                         lambda ei, ci, fi, di: (ei, ci, di)),
            pl.BlockSpec((1, block_d, block_f),
                         lambda ei, ci, fi, di: (ei, di, fi)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda ei, ci, fi, di: (ei, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w)
