"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels run in interpret mode (the TPU target
is validated structurally); pass ``interpret=False`` on real TPUs.
``REPRO_KERNEL_INTERPRET=0`` flips the default.
"""
from __future__ import annotations

import os
from functools import partial

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import decode_attention as _da
from repro.kernels import moe_gemm as _mg
from repro.kernels import mamba2_scan as _ms
from repro.kernels import rwkv6_scan as _rs

_DEFAULT_INTERPRET = os.environ.get("REPRO_KERNEL_INTERPRET", "1") == "1"


@partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                   "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=_DEFAULT_INTERPRET):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


@partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(q, k_cache, v_cache, cache_len, *, block_s=256,
                     interpret=_DEFAULT_INTERPRET):
    return _da.decode_attention(q, k_cache, v_cache, cache_len,
                                block_s=block_s, interpret=interpret)


@partial(jax.jit, static_argnames=("block_c", "block_f", "block_d",
                                   "interpret"))
def moe_gemm(x, w, *, block_c=128, block_f=128, block_d=256,
             interpret=_DEFAULT_INTERPRET):
    return _mg.moe_gemm(x, w, block_c=block_c, block_f=block_f,
                        block_d=block_d, interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba2_scan(xh, b, c, dt, a_log, *, chunk=128,
                interpret=_DEFAULT_INTERPRET):
    return _ms.mamba2_scan(xh, b, c, dt, a_log, chunk=chunk,
                           interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, w, bonus, *, chunk=32,
               interpret=_DEFAULT_INTERPRET):
    return _rs.rwkv6_scan(r, k, v, w, bonus, chunk=chunk,
                          interpret=interpret)
