"""Pallas TPU flash attention (prefill): GQA + causal + sliding window.

TPU-native tiling: the grid is (B·KV·G, nQ, nK) with the KV dimension
innermost and ``arbitrary`` (sequential) semantics, so the online-softmax
running state (m, l, acc) lives in VMEM scratch that persists across KV
steps.  Block shapes are MXU-aligned (multiples of 128 where the head
dim allows).  HBM→VMEM movement is expressed entirely through BlockSpecs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, sk: int, causal: bool,
                  window: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # [bq, D]
    k = k_ref[0].astype(jnp.float32)                  # [bk, D]
    v = v_ref[0].astype(jnp.float32)                  # [bk, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = k_pos < sk
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: [B, Sq, H, D]; k, v: [B, Sk, KV, D]; returns [B, Sq, H, D]."""
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    assert h % kv == 0
    g = h // kv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    nq = pl.cdiv(sq, block_q)
    nk = pl.cdiv(sk, block_k)

    # layout: fold heads into the leading grid dim
    qh = q.reshape(b, sq, kv, g, d).transpose(0, 2, 3, 1, 4) \
        .reshape(b * kv * g, sq, d)
    kh = k.transpose(0, 2, 1, 3).reshape(b * kv, sk, d)
    vh = v.transpose(0, 2, 1, 3).reshape(b * kv, sk, d)

    grid = (b * kv * g, nq, nk)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                          sk=sk, causal=causal, window=window,
                          scale=d ** -0.5),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, qi, ki, g_=g: (bh // g_, ki, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, qi, ki, g_=g: (bh // g_, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kv * g, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(b, kv, g, sq, d).transpose(0, 3, 1, 2, 4) \
        .reshape(b, sq, h, d)
