"""Pallas TPU chunked Mamba2 (SSD) scan.

Grid (B·H, nChunks) with chunks sequential; the carried SSM state
[P, N] lives in VMEM scratch.  Within a chunk, the recurrence is the
dense pairwise-decay form (exponents ≤ 0, numerically safe) computed
with MXU matmuls — the TPU adaptation of the CUDA selective-scan: the
sequential dimension is chunk-granular, everything inside a chunk is a
regular GEMM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _mamba_kernel(xh_ref, b_ref, c_ref, dta_ref, dt_ref, o_ref, fin_ref,
                  state_scr, *, chunk: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = xh_ref[0].astype(jnp.float32)        # [L, P]
    bb = b_ref[0].astype(jnp.float32)        # [L, N]
    cc = c_ref[0].astype(jnp.float32)        # [L, N]
    dta = dta_ref[0].astype(jnp.float32)     # [L, 1]  (dt * a, <= 0)
    dt = dt_ref[0].astype(jnp.float32)       # [L, 1]

    cum = jnp.cumsum(dta, axis=0)            # [L, 1]
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    # intra-chunk: y_i += sum_{j<=i} exp(cum_i - cum_j) dt_j (c_i·b_j) x_j
    decay = jnp.where(li >= lj, jnp.exp(cum - cum.T), 0.0)   # [L, L]
    sb = jax.lax.dot_general(cc, bb, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    m = sb * decay * dt.T                                    # [L, L]
    y = jax.lax.dot(m, x, preferred_element_type=jnp.float32)
    # inter-chunk: y_i += exp(cum_i) * c_i @ state^T   (state: [P, N])
    state = state_scr[...]
    y += jnp.exp(cum) * jax.lax.dot_general(
        cc, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    # state update: state = exp(total) * state + sum_j exp(total-cum_j) dt_j x_j b_j^T
    total = cum[chunk - 1]
    tail = jnp.exp(total[None] - cum) * dt                   # [L, 1]
    st_new = jax.lax.dot_general(x, bb * tail,
                                 (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    state_scr[...] = state * jnp.exp(total)[None] + st_new

    o_ref[0] = y.astype(o_ref.dtype)

    @pl.when(ci == nc - 1)
    def _finish():
        fin_ref[0] = state_scr[...].astype(fin_ref.dtype)


def mamba2_scan(xh: jax.Array, b: jax.Array, c: jax.Array, dt: jax.Array,
                a_log: jax.Array, *, chunk: int = 128,
                interpret: bool = False):
    """xh: [B, S, H, P]; b, c: [B, S, N]; dt: [B, S, H] (softplus'd);
    a_log: [H].  Returns (y [B, S, H, P], final_state [B, H, P, N])."""
    bsz, s, h, p = xh.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, "pad sequence to a chunk multiple"
    nc = s // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))
    dta = dt * a[None, None]                                 # [B, S, H]

    xf = xh.transpose(0, 2, 1, 3).reshape(bsz * h, s, p)
    bf = jnp.broadcast_to(b[:, None], (bsz, h, s, n)).reshape(bsz * h, s, n)
    cf = jnp.broadcast_to(c[:, None], (bsz, h, s, n)).reshape(bsz * h, s, n)
    dtaf = dta.transpose(0, 2, 1).reshape(bsz * h, s, 1)
    dtf = dt.transpose(0, 2, 1).reshape(bsz * h, s, 1)

    y, fin = pl.pallas_call(
        functools.partial(_mamba_kernel, chunk=chunk),
        grid=(bsz * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bh, ci: (bh, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, p, n), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz * h, s, p), xh.dtype),
            jax.ShapeDtypeStruct((bsz * h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xf, bf, cf, dtaf, dtf)
    y = y.reshape(bsz, h, s, p).transpose(0, 2, 1, 3)
    fin = fin.reshape(bsz, h, p, n)
    return y, fin
