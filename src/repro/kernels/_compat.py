"""Pallas-TPU API drift shims.

``jax.experimental.pallas.tpu`` renamed ``TPUCompilerParams`` to
``CompilerParams`` across jax releases; the pinned container ships the
older name.  Import ``CompilerParams`` from here so the kernels build
against either API.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
