"""Pallas TPU chunked RWKV6 (Finch) scan with data-dependent decay.

Grid (B·H, nChunks), chunks sequential, carried state [K, V] in VMEM
scratch.  Intra-chunk uses the exact pairwise log-space form (exponents
are sums of per-step log decays over (j, i), always ≤ 0 — safe for any
decay magnitude); chunk length is kept small because the pairwise decay
tensor is [L, L, K].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _rwkv_kernel(r_ref, k_ref, v_ref, lw_ref, bonus_ref, o_ref, fin_ref,
                 state_scr, *, chunk: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0].astype(jnp.float32)         # [L, K]
    k = k_ref[0].astype(jnp.float32)         # [L, K]
    v = v_ref[0].astype(jnp.float32)         # [L, V]
    lw = lw_ref[0].astype(jnp.float32)       # [L, K] log decay (<= 0)
    bonus = bonus_ref[0].astype(jnp.float32)  # [1, K] -> [K]

    cum = jnp.cumsum(lw, axis=0)             # [L, K]
    # inter-chunk: out_i += (r_i ⊙ prod_{s<i} w_s) @ state
    dec_in = jnp.exp(cum - lw)               # [L, K]
    out = jax.lax.dot(r * dec_in, state_scr[...],
                      preferred_element_type=jnp.float32)
    # intra-chunk, strict lower triangle: pairwise exponents <= 0
    dij = (cum - lw)[:, None, :] - cum[None, :, :]      # [L, L, K]
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    strict = (li > lj)[:, :, None]
    pair = jnp.where(strict, jnp.exp(jnp.minimum(dij, 0.0)), 0.0)
    scores = jnp.einsum("ik,ijk,jk->ij", r, pair, k)
    out += jax.lax.dot(scores, v, preferred_element_type=jnp.float32)
    # diagonal bonus
    diag = jnp.sum(r * bonus * k, axis=1, keepdims=True)  # [L, 1]
    out += diag * v
    # state update
    total = cum[chunk - 1]                               # [K]
    tail = jnp.exp(total[None] - cum)                    # [L, K]
    st_new = jax.lax.dot_general(k * tail, v, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    state_scr[...] = state_scr[...] * jnp.exp(total)[:, None] + st_new

    o_ref[0] = out.astype(o_ref.dtype)

    @pl.when(ci == nc - 1)
    def _finish():
        fin_ref[0] = state_scr[...].astype(fin_ref.dtype)


def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               bonus: jax.Array, *, chunk: int = 32,
               interpret: bool = False):
    """r,k,v,w: [B, S, H, D]; bonus: [H, D].
    Returns (out [B, S, H, D], final state [B, H, D, D])."""
    b, s, h, d = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, "pad sequence to a chunk multiple"
    nc = s // chunk
    lw = jnp.log(jnp.clip(w.astype(jnp.float32), 1e-8, 1.0))

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, d)

    rf, kf, vf = fold(r), fold(k), fold(v)
    lwf = lw.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    bonus_f = jnp.broadcast_to(bonus[None], (b, h, d)) \
        .reshape(b * h, 1, d)

    out, fin = pl.pallas_call(
        functools.partial(_rwkv_kernel, chunk=chunk),
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, d), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, d), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, d), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, d), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1, d), lambda bh, ci: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, d, d), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, d), r.dtype),
            jax.ShapeDtypeStruct((b * h, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rf, kf, vf, lwf, bonus_f)
    return (out.reshape(b, h, s, d).transpose(0, 2, 1, 3),
            fin.reshape(b, h, d, d))
