"""Pallas TPU flash-decoding: single-token attention over a KV cache.

Grid (B·KV, nS) with the cache-length dimension sequential; the running
(m, l, acc) state for all G query heads of the KV group sits in VMEM
scratch.  Invalid cache positions (≥ cache_len) are masked, so the same
kernel serves any fill level of a static cache.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   block_s: int, scale: float):
    si = pl.program_id(1)
    ns = pl.num_programs(1)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # [G, D]
    k = k_ref[0].astype(jnp.float32)                  # [bs, D]
    v = v_ref[0].astype(jnp.float32)                  # [bs, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = si * block_s + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    s = jnp.where(pos < len_ref[0], s, NEG_INF)       # [G, bs]

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(si == ns - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array | int, *,
                     block_s: int = 256,
                     interpret: bool = False) -> jax.Array:
    """q: [B, 1, H, D]; caches: [B, S, KV, D] -> [B, 1, H, D]."""
    b, _, h, d = q.shape
    _, s, kv, _ = k_cache.shape
    assert h % kv == 0
    g = h // kv
    block_s = min(block_s, s)
    ns = pl.cdiv(s, block_s)

    qh = q.reshape(b, kv, g, d).reshape(b * kv, g, d)
    kh = k_cache.transpose(0, 2, 1, 3).reshape(b * kv, s, d)
    vh = v_cache.transpose(0, 2, 1, 3).reshape(b * kv, s, d)
    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b * kv,))

    out = pl.pallas_call(
        functools.partial(_decode_kernel, block_s=block_s,
                          scale=d ** -0.5),
        grid=(b * kv, ns),
        in_specs=[
            pl.BlockSpec((1,), lambda bh, si: (bh,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, g, d), lambda bh, si: (bh, 0, 0)),
            pl.BlockSpec((1, block_s, d), lambda bh, si: (bh, si, 0)),
            pl.BlockSpec((1, block_s, d), lambda bh, si: (bh, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda bh, si: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lens, qh, kh, vh)
    return out.reshape(b, 1, h, d)
