"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are deliberately simple O(S²)/unfused implementations — no
chunking, no online softmax — so the kernels are validated against
independent math, not against themselves.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: [B, Sq, H, D]; k, v: [B, Sk, KV, D]; GQA via H % KV == 0."""
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    qr = q.reshape(b, sq, kv, g, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qr, kf) * d ** -0.5
    qi = jnp.arange(sq)[:, None]
    ki = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qi >= ki
    if window:
        mask &= qi - ki < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    return o.reshape(b, sq, h, d).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, cache_len):
    """q: [B, 1, H, D]; caches: [B, S, KV, D]; valid positions < cache_len."""
    b, _, h, d = q.shape
    _, s, kv, _ = k_cache.shape
    g = h // kv
    qr = q.reshape(b, kv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qr,
                        k_cache.astype(jnp.float32)) * d ** -0.5
    valid = jnp.arange(s) < cache_len
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, 1, h, d).astype(q.dtype)


def moe_gemm_ref(x, w):
    """Grouped GEMM: x [E, C, D] @ w [E, D, F] -> [E, C, F]."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def mamba2_scan_ref(xh, b, c, dt, a_log):
    """Sequential SSD recurrence (the exact math, step by step).

    xh: [B, S, H, P]; b, c: [B, S, N]; dt: [B, S, H] (already softplus'd);
    a_log: [H].  Returns (y [B, S, H, P], final_state [B, H, P, N]).
    """
    bsz, s, h, p = xh.shape
    n = b.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))

    def step(state, inp):
        x_t, b_t, c_t, dt_t = inp          # [B,H,P], [B,N], [B,N], [B,H]
        decay = jnp.exp(dt_t * a[None])    # [B,H]
        state = state * decay[..., None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dt_t, x_t, b_t)
        y = jnp.einsum("bhpn,bn->bhp", state, c_t)
        return state, y

    xs = (xh.swapaxes(0, 1).astype(jnp.float32),
          b.swapaxes(0, 1).astype(jnp.float32),
          c.swapaxes(0, 1).astype(jnp.float32),
          dt.swapaxes(0, 1).astype(jnp.float32))
    state0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    fin, ys = jax.lax.scan(step, state0, xs)
    return ys.swapaxes(0, 1), fin


def rwkv6_scan_ref(r, k, v, w, bonus):
    """Sequential RWKV6 recurrence.

    r,k,v,w: [B, S, H, D]; bonus: [H, D].
    out_t = r_t S_{t-1} + (r_t · (bonus ⊙ k_t)) v_t ;  S_t = diag(w_t) S_{t-1} + k_t v_t^T.
    Returns (out [B, S, H, D], final state [B, H, D, D]).
    """
    b, s, h, d = r.shape

    def step(state, inp):
        r_t, k_t, v_t, w_t = (x.astype(jnp.float32) for x in inp)
        out = jnp.einsum("bhk,bhkv->bhv", r_t, state)
        diag = jnp.einsum("bhk,hk,bhk->bh", r_t, bonus.astype(jnp.float32),
                          k_t)
        out = out + diag[..., None] * v_t
        state = state * w_t[..., None] + jnp.einsum(
            "bhk,bhv->bhkv", k_t, v_t)
        return state, out

    xs = tuple(x.swapaxes(0, 1) for x in (r, k, v, w))
    state0 = jnp.zeros((b, h, d, d), jnp.float32)
    fin, ys = jax.lax.scan(step, state0, xs)
    return ys.swapaxes(0, 1), fin
