PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-sched bench-sched check

test:
	$(PYTHON) -m pytest -q

# Scheduler tier: the suites that are green and need only numpy/scipy
# (the seed's kernel tests fail on jax/pallas API drift and need an
# accelerator toolchain CI does not have).
test-sched:
	$(PYTHON) -m pytest -q tests/test_executor.py tests/test_solvers.py \
	  tests/test_workflowbench.py tests/test_score_matrix_parity.py

bench-sched:
	$(PYTHON) -m benchmarks.sched_bench --quick

# CI smoke gate: scheduler tests + planner-throughput regression check
# (sched_bench exits nonzero if the vectorized engine drops below the
# 5x wide-frontier target or placements diverge from the scalar path).
check: test-sched bench-sched
