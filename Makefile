PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-sched bench-sched check

test:
	$(PYTHON) -m pytest -q

# Scheduler tier: the suites that are green and need only numpy/scipy
# (kernel tests additionally need the jax/pallas toolchain).
test-sched:
	$(PYTHON) -m pytest -q tests/test_executor.py tests/test_solvers.py \
	  tests/test_workflowbench.py tests/test_score_matrix_parity.py \
	  tests/test_delta_rescoring.py tests/test_shared_frontier.py

bench-sched:
	$(PYTHON) -m benchmarks.sched_bench --quick --profile --serve

# CI smoke gate: scheduler tests + planner-throughput regression checks
# (sched_bench exits nonzero if the vectorized engine drops below the
# 5x wide-frontier target, if steady-state delta rescoring drops below
# the 2x guard — PR target 3x — or if either engine's placements
# diverge from the reference path).
check: test-sched bench-sched
