PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-sched bench-sched calibrate audit docs-check \
  deprecated-check gateway-smoke check

test:
	$(PYTHON) -m pytest -q

# Scheduler tier: the suites that are green and need only numpy/scipy
# (kernel tests additionally need the jax/pallas toolchain).
test-sched:
	$(PYTHON) -m pytest -q tests/test_executor.py tests/test_solvers.py \
	  tests/test_workflowbench.py tests/test_score_matrix_parity.py \
	  tests/test_delta_rescoring.py tests/test_shared_frontier.py \
	  tests/test_admission.py tests/test_preemption.py \
	  tests/test_scheduler_api.py tests/test_faults.py \
	  tests/test_recovery.py tests/test_pool_partition.py \
	  tests/test_batched_probe.py tests/test_scan_index.py \
	  tests/test_scale_stress.py tests/test_multiclass.py \
	  tests/test_routing.py tests/test_gateway.py \
	  tests/test_arrival_queue.py tests/test_pools_auto.py \
	  tests/test_event_stream_live.py

bench-sched:
	$(PYTHON) -m benchmarks.sched_bench --quick --profile --serve \
	  --serve-slo --calibrate --chaos --recovery --scale --classes \
	  --gateway

# Cost-model calibration gate (fit round-trip, >=2x probe-error
# reduction vs hand-set constants, fixed-profile score-path parity);
# writes CALIBRATION_profile.json next to BENCH_sched.json.
calibrate:
	$(PYTHON) -m benchmarks.sched_bench --quick --calibrate

# Invariant auditor smoke: build a journaled chaos run in a temp dir,
# kill it mid-flight, restore from snapshot + journal replay, and
# assert the cross-structure invariants hold (tools/invariant_audit.py
# also audits archived SNAPSHOT.json / journal artifacts directly).
audit:
	$(PYTHON) tools/invariant_audit.py --self-test

# Docs gate: markdown link check over README.md/docs/ plus a
# pydocstyle-equivalent docstring lint on the documented-surface
# modules (offline container: no pydocstyle wheel, tools/docs_check.py
# implements the same checks on ast).
docs-check:
	$(PYTHON) tools/docs_check.py

# Gateway smoke: boot the asyncio HTTP gateway on an ephemeral port,
# submit one workflow over real HTTP, drain its NDJSON event stream,
# and exit nonzero if any event was dropped or the workflow never
# reached its terminal event (see serving/gateway.py --smoke).
gateway-smoke:
	$(PYTHON) -m repro.serving.gateway --smoke

# Deprecated-surface gate: fails if any in-repo caller still uses the
# policy_kwargs path outside the back-compat wrappers / parity tests
# (the typed SchedulerConfig is the supported surface).
deprecated-check:
	$(PYTHON) tools/check_deprecated.py

# CI smoke gate: scheduler tests + planner-throughput regression checks
# (sched_bench exits nonzero if the vectorized engine drops below the
# 5x wide-frontier target, if steady-state delta rescoring drops below
# the 2x guard — PR target 3x — if either engine's placements diverge
# from the reference path, if the --serve-slo control plane stops
# beating unconditional admission / loses cold-solve parity, if the
# --calibrate loop stops recovering coefficients / cutting probe error
# >= 2x / holding fixed-profile parity, if the --chaos gate stops
# completing 100% of admitted workflows under the seeded fault script
# within 2x fault-free makespan with bit-identical replay and
# empty-plan parity, if the --recovery gate stops restoring a
# killed journaled run bit-identically with clean invariant audits,
# or if the --scale gate stops completing 1000 workflows on 64
# devices with zero invariant violations under the per-event
# overhead ceiling and single-pool/monolithic parity, or if the
# --classes gate loses default-class bit-parity, platinum attainment
# under the weighted multi-class config, the bottom class's bounded-
# wait completion guarantee, or bit-identical journaled recovery of
# runs killed mid-preemption, or if the --gateway gate loses
# single-replica gateway/direct-Scheduler bit-parity, 100% completion
# under wall-clock Poisson HTTP load, routing-disabled bit-identity,
# or the routed-cheaper-than-fixed-at-quality-floor contract)
# + docs + the deprecated-surface gate.
check: test-sched bench-sched docs-check deprecated-check
