"""Deprecated-surface gate: no in-repo caller may use the untyped
``policy_kwargs`` path outside the sanctioned back-compat layer.

PR 5 redesigned the scheduler's public API around a typed
:class:`~repro.core.scheduler.SchedulerConfig`; the old
``policy_kwargs`` dicts survive only as deprecated escape hatches in
the ``workflowbench.runner`` wrappers (which emit a
``DeprecationWarning``) and in the parity tests that deliberately
exercise the old path against the new one.  Everything else must
express planner knobs as config fields — this gate greps the tree so
a stray reintroduction fails ``make check`` instead of rotting.

Run from the repo root (CI and ``make check`` do):

    python tools/check_deprecated.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

#: Directories scanned for Python sources.
SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "tools")

#: The deprecated identifier this gate hunts for.
PATTERN = re.compile(r"\bpolicy_kwargs\b")

#: Files allowed to mention the deprecated surface: the back-compat
#: wrappers themselves, the parity suite that intentionally runs the
#: old path against the new one, the config object that documents the
#: migration, and this gate.
ALLOWLIST = {
    "src/repro/workflowbench/runner.py",
    "src/repro/core/scheduler.py",
    "src/repro/core/policies/base.py",
    "src/repro/core/policies/fate.py",
    "tests/test_scheduler_api.py",
    "tools/check_deprecated.py",
}


def main() -> int:
    """Scan the tree; print offenders; exit nonzero on any."""
    offenders: list[str] = []
    for top in SCAN_DIRS:
        root = REPO / top
        if not root.exists():
            continue
        for path in sorted(root.rglob("*.py")):
            rel = str(path.relative_to(REPO))
            if rel in ALLOWLIST:
                continue
            for lineno, line in enumerate(
                    path.read_text().splitlines(), start=1):
                if PATTERN.search(line):
                    offenders.append(f"{rel}:{lineno}: {line.strip()}")
    if offenders:
        print(f"deprecated-surface check: {len(offenders)} use(s) of "
              f"policy_kwargs outside the back-compat layer")
        for o in offenders:
            print(f"  {o}")
        print("  -> express planner knobs as SchedulerConfig fields "
              "(see docs/API.md migration table)")
        return 1
    print("deprecated-surface check: OK (policy_kwargs confined to "
          "the back-compat layer)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
