"""Documentation gate: markdown link check + docstring lint.

``pydocstyle`` is not installable in the offline container, so this is
the equivalent gate implemented on ``ast``:

1. **Markdown link check** — every relative link/image target in
   ``README.md`` and ``docs/*.md`` must exist on disk (http(s) and
   mailto links are skipped; ``#fragment`` suffixes are stripped).
2. **Docstring lint** over the documented-surface modules
   (``core/scoring.py``, ``core/state.py``, ``core/planner.py``,
   ``core/executor.py``, ``core/costs.py``, ``core/admission.py``,
   ``core/calibration.py``, ``core/frontier_solver.py``,
   ``workflowbench/runner.py``, ``workflowbench/suites.py``): the
   module itself and every PUBLIC
   class, function, method, and property (name not starting with
   ``_``) must carry a docstring whose first paragraph (summary) ends
   with ``.``, ``:``, ``?`` or ``!`` (pydocstyle D1xx presence + a
   wrap-tolerant D400 analogue).

Run from the repo root (CI and ``make docs-check`` do):

    python tools/docs_check.py
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

DOCSTRING_MODULES = [
    "src/repro/core/scoring.py",
    "src/repro/core/state.py",
    "src/repro/core/planner.py",
    "src/repro/core/executor.py",
    "src/repro/core/scheduler.py",
    "src/repro/core/faults.py",
    "src/repro/core/journal.py",
    "src/repro/core/costs.py",
    "src/repro/core/admission.py",
    "src/repro/core/calibration.py",
    "src/repro/core/frontier_solver.py",
    "src/repro/core/policies/__init__.py",
    "src/repro/core/policies/base.py",
    "src/repro/core/policies/fate.py",
    "src/repro/core/policies/baselines.py",
    "src/repro/workflowbench/runner.py",
    "src/repro/workflowbench/suites.py",
    "src/repro/core/routing.py",
    "src/repro/serving/engine.py",
    "src/repro/serving/gateway.py",
]

MARKDOWN_FILES = ["README.md", *sorted(
    str(p.relative_to(REPO)) for p in (REPO / "docs").glob("*.md"))]

_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")


def check_markdown(rel: str, errors: list[str]) -> None:
    """Verify every relative link target in one markdown file exists."""
    path = REPO / rel
    if not path.exists():
        errors.append(f"{rel}: file missing")
        return
    text = path.read_text()
    # drop fenced code blocks — their brackets are not links
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for target in _LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        dest = (path.parent / target.split("#", 1)[0]).resolve()
        if not dest.exists():
            errors.append(f"{rel}: broken link -> {target}")


def _ok_docstring(node) -> bool:
    doc = ast.get_docstring(node)
    if not doc or not doc.strip():
        return False
    summary: list[str] = []
    for line in doc.strip().splitlines():
        if not line.strip():
            break
        summary.append(line.strip())
    return " ".join(summary).endswith((".", ":", "?", "!"))


def _public_defs(body, prefix=""):
    """Yield (qualname, node) for public defs, recursing into classes."""
    for node in body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            if node.name.startswith("_"):
                continue
            qual = f"{prefix}{node.name}"
            yield qual, node
            if isinstance(node, ast.ClassDef):
                yield from _public_defs(node.body, prefix=f"{qual}.")


def check_docstrings(rel: str, errors: list[str]) -> None:
    """pydocstyle-equivalent pass over one module's public surface."""
    path = REPO / rel
    tree = ast.parse(path.read_text())
    if not _ok_docstring(tree):
        errors.append(f"{rel}: module docstring missing/unterminated")
    for qual, node in _public_defs(tree.body):
        if not _ok_docstring(node):
            errors.append(
                f"{rel}:{node.lineno}: {qual}: docstring missing or "
                f"summary paragraph not ending in punctuation")


def main() -> int:
    """Run both gates; print findings; exit nonzero on any."""
    errors: list[str] = []
    for rel in MARKDOWN_FILES:
        check_markdown(rel, errors)
    for rel in DOCSTRING_MODULES:
        check_docstrings(rel, errors)
    if errors:
        print(f"docs check: {len(errors)} problem(s)")
        for e in errors:
            print(f"  {e}")
        return 1
    n_md, n_py = len(MARKDOWN_FILES), len(DOCSTRING_MODULES)
    print(f"docs check: OK ({n_md} markdown files, "
          f"{n_py} docstring-gated modules)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
