"""Offline invariant auditor for durable scheduler state.

Restores a :class:`~repro.core.scheduler.Scheduler` from a snapshot
document (optionally replaying a journal tail on top) and runs
:func:`~repro.core.scheduler.audit_invariants` over the rehydrated
state — the same cross-structure consistency checks the in-process
``audit_every`` debug hook runs between steps:

* every pending token-valid completion event references live issued
  work (no lost in-flight shards);
* committed placements are unique, not yet issued, not already
  completed, and touch no downed device;
* the shared frontier, workflow registry, arrival table, and per-
  workflow stats agree with each other;
* the event ring's counters (``n_total = n_dropped + len``) are
  consistent with its capacity.

Usage (from the repo root):

    python tools/invariant_audit.py SNAPSHOT.json [--journal DIR]
    python tools/invariant_audit.py --journal DIR      # latest snapshot
    python tools/invariant_audit.py --self-test

With ``--journal`` and no positional snapshot, the newest snapshot
inside the journal directory is used.  ``--self-test`` builds a small
journaled chaos run in a temp directory, kills it mid-flight, and
audits the restored scheduler — a dependency-free smoke for ``make
audit``.  Exit status is 0 when the audit is clean, 1 when violations
are found (each printed on its own line), 2 on usage errors.
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))


def _audit(sched) -> int:
    """Print violations (if any) and return the process exit code."""
    from repro.core.scheduler import audit_invariants

    violations = audit_invariants(sched)
    if violations:
        for v in violations:
            print(f"VIOLATION: {v}")
        print(f"audit: {len(violations)} violation(s)")
        return 1
    print("audit: clean (0 violations)")
    return 0


def _restore(snapshot_path, journal_dir):
    """Rehydrate a scheduler from CLI arguments."""
    from repro.core.journal import EventJournal
    from repro.core.scheduler import Scheduler

    journal = EventJournal(journal_dir) if journal_dir else None
    if snapshot_path is not None:
        doc = json.loads(Path(snapshot_path).read_text())
    else:
        doc = journal.latest_snapshot()
        if doc is None:
            print(f"no snapshot found in journal {journal_dir}",
                  file=sys.stderr)
            raise SystemExit(2)
    return Scheduler.restore(doc, journal)


def _self_test() -> int:
    """Journaled chaos run, killed mid-flight, restored, audited."""
    from repro.core.admission import SLOConfig
    from repro.core.journal import EventJournal
    from repro.core.scheduler import Scheduler, SchedulerConfig
    from repro.workflowbench.suites import chaos_fault_plan, \
        overloaded_serving_trace

    trace = overloaded_serving_trace(n_workflows=12, rate=14.0, seed=0,
                                     num_queries=8)
    cfg = SchedulerConfig(policy="FATE", slo=SLOConfig(),
                          faults=chaos_fault_plan(0))
    from repro.core.devices import homogeneous_cluster
    cluster = homogeneous_cluster(6)
    with tempfile.TemporaryDirectory() as tmp:
        journal = EventJournal(tmp)
        sched = Scheduler(cluster, cfg, journal=journal)
        for t, wf in trace:
            sched.submit(wf, at=t)
        journal.write_snapshot(sched.snapshot())
        steps = 0
        while sched.events.n_total < 300 and sched.step():
            steps += 1
            if steps % 25 == 0:
                journal.write_snapshot(sched.snapshot())
        killed = sched.events.n_total
        del sched, journal
        reopened = EventJournal(tmp)
        restored = Scheduler.restore(reopened.latest_snapshot(),
                                     reopened)
        print(f"self-test: killed at event {killed}, restored at "
              f"event {restored.events.n_total}")
        code = _audit(restored)
        restored.drain()
        return code or _audit(restored)


def main(argv=None) -> int:
    """CLI entry point; returns the process exit code."""
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("snapshot", nargs="?", default=None,
                    help="snapshot JSON (from Scheduler.save_snapshot "
                         "or EventJournal.write_snapshot)")
    ap.add_argument("--journal", default=None, metavar="DIR",
                    help="journal directory to replay on top of the "
                         "snapshot (and to locate the latest snapshot "
                         "when no positional path is given)")
    ap.add_argument("--self-test", action="store_true",
                    help="build, kill, and audit a small journaled "
                         "chaos run in a temp directory")
    args = ap.parse_args(argv)

    if args.self_test:
        return _self_test()
    if args.snapshot is None and args.journal is None:
        ap.error("need a snapshot path, --journal, or --self-test")
    sched = _restore(args.snapshot, args.journal)
    print(f"restored scheduler at event {sched.events.n_total} "
          f"(lifecycle: {sched._lifecycle})")
    return _audit(sched)


if __name__ == "__main__":
    sys.exit(main())
