"""Controlled prefix-reuse demo (paper Table 2 in miniature): sweep the
shared-prefix repeat ratio and watch the schedulers separate.

    PYTHONPATH=src python examples/prefix_reuse_demo.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.workflowbench.metrics import geomean              # noqa: E402
from repro.workflowbench.runner import run_one               # noqa: E402
from repro.workflowbench.suites import (RATIOS, prefix_suite)  # noqa: E402
from repro.core.devices import homogeneous_cluster           # noqa: E402


def main() -> None:
    cluster = homogeneous_cluster(8)
    halo0 = {w.wid.rsplit("-", 1)[1]: run_one(w, "Halo", cluster).makespan
             for w in prefix_suite(0.0)}
    print("geomean makespan normalized by Halo @ ratio 0 "
          "(lower is better):\n")
    print(f"{'policy':8s} " + " ".join(f"r={r:<5}" for r in RATIOS))
    for pol in ["Halo", "KVFlow", "FATE"]:
        vals = []
        for r in RATIOS:
            ms = [run_one(w, pol, cluster).makespan
                  / halo0[w.wid.rsplit('-', 1)[1]]
                  for w in prefix_suite(r)]
            vals.append(geomean(ms))
        print(f"{pol:8s} " + " ".join(f"{v:<7.3f}" for v in vals))
    print("\nFATE's edge persists at ratio 0 — future-state preservation"
          "\n(residency + shard planning), not cache reuse alone, drives"
          "\nthe gap (the paper's §4.3 conclusion).")


if __name__ == "__main__":
    main()
