"""End-to-end driver: serve a heterogeneous workflow of REAL models with
batched requests, scheduled by FATE on virtual devices.

Two reduced-config models (qwen3-style, glm4-style) execute a
retrieval -> 2x worker -> merge DAG over a batch of 8 queries: real
prefill + autoregressive decode per stage, model residency switches,
and prefix-cache reuse on the serving engine.

    PYTHONPATH=src python examples/serve_workflow.py
"""
import dataclasses
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax                                                   # noqa: E402

from repro.configs.archs import SMOKE                        # noqa: E402
from repro.core.devices import homogeneous_cluster           # noqa: E402
from repro.core.executor import fresh_state                  # noqa: E402
from repro.core.policies import make_policy                  # noqa: E402
from repro.core.workflow import Stage, Workflow              # noqa: E402
from repro.serving.engine import ModelBundle, ServingEngine  # noqa: E402


def main() -> None:
    cfg_a = SMOKE["qwen3-1.7b"]
    cfg_b = dataclasses.replace(SMOKE["glm4-9b"],
                                vocab_size=cfg_a.vocab_size)
    print("loading model bundles (reduced configs)...")
    bundles = {
        "qwen-7b": ModelBundle.create("qwen-7b", cfg_a, seed=0),
        "llama-8b": ModelBundle.create("llama-8b", cfg_b, seed=1),
    }
    stages = {
        "retrieve": Stage("retrieve", "qwen-7b", base_cost={-1: 0.01},
                          prefix_group="ctx", max_shards=2,
                          output_tokens=128),
        "work_a": Stage("work_a", "llama-8b", base_cost={-1: 0.02},
                        parents=("retrieve",), output_tokens=256),
        "work_b": Stage("work_b", "qwen-7b", base_cost={-1: 0.02},
                        prefix_group="ctx", parents=("retrieve",),
                        output_tokens=256),
        "merge": Stage("merge", "qwen-7b", base_cost={-1: 0.015},
                       prefix_group="ctx",
                       parents=("work_a", "work_b")),
    }
    wf = Workflow(wid="agentic-demo", stages=stages, num_queries=8)

    engine = ServingEngine(bundles, n_devices=2, gen_len=6, prompt_len=16)
    state = fresh_state(homogeneous_cluster(2))
    prompts = jax.random.randint(jax.random.PRNGKey(0), (8, 16), 0,
                                 cfg_a.vocab_size)
    t0 = time.perf_counter()
    results = engine.run_workflow(wf, make_policy("FATE"), state, prompts)
    wall = time.perf_counter() - t0

    print(f"\nserved {len(results)} stages x {wf.num_queries} queries "
          f"in {wall:.2f}s")
    for sid in wf.topo_order:
        r = results[sid]
        flags = []
        if r.switched:
            flags.append("model-switch")
        if r.prefix_hit:
            flags.append("prefix-hit")
        print(f"  {sid:10s} devices={r.device_ids} "
              f"tokens={tuple(r.tokens_out.shape)} wall={r.wall_s:.2f}s "
              f"{' '.join(flags)}")
    print("\nresidency:", {d.did: d.resident for d in engine.devices})


if __name__ == "__main__":
    main()
