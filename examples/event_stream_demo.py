"""Event-driven Scheduler API demo: typed config, submit/drain
lifecycle, and the replayable event stream.

Runs an overloaded Poisson trace under the SLO control plane, prints
control-plane decisions live from `on()` subscriptions, then replays
the event log to summarize the run — no accelerator required.

    PYTHONPATH=src python examples/event_stream_demo.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.admission import SLOConfig                    # noqa: E402
from repro.core.devices import homogeneous_cluster            # noqa: E402
from repro.core.scheduler import (AdmittedEvent,              # noqa: E402
                                  CompletionEvent, DeferredEvent,
                                  PreemptionEvent, RejectedEvent,
                                  Scheduler, SchedulerConfig)
from repro.workflowbench.suites import overloaded_serving_trace  # noqa: E402


def main() -> None:
    """Drive one overloaded trace through the Scheduler lifecycle."""
    config = SchedulerConfig(policy="FATE", slo=SLOConfig())
    print("config artifact (reproduces this run via sched_bench "
          "--config):")
    print("  " + " | ".join(config.to_json().split("\n")[1:4]))

    sched = Scheduler(homogeneous_cluster(6), config)
    sched.on(AdmittedEvent, lambda e: print(
        f"[{e.t:7.2f}s] admit  {e.wid} (deadline {e.deadline:.1f}s)"))
    sched.on(DeferredEvent, lambda e: print(
        f"[{e.t:7.2f}s] defer  {e.wid} "
        f"(predicted {e.predicted_latency:.1f}s)"))
    sched.on(RejectedEvent, lambda e: print(
        f"[{e.t:7.2f}s] reject {e.wid} ({e.reason})"))
    sched.on(PreemptionEvent, lambda e: print(
        f"[{e.t:7.2f}s] preempt: {e.n_revoked} commitments revoked "
        f"for {e.trigger_wid}"))

    for t, wf in overloaded_serving_trace(n_workflows=18, rate=14.0,
                                          seed=0, num_queries=8):
        sched.submit(wf, at=t)
    res = sched.drain()

    print(f"\ncompleted {len(res.stats)}/{res.n_offered} workflows, "
          f"attainment {res.slo_attainment:.2f}, "
          f"SLO goodput {res.goodput_slo_wps:.3f} wf/s, "
          f"{res.preemptions} preemptions")
    by_type: dict = {}
    for ev in sched.events:                    # replayable stream
        by_type[type(ev).__name__] = by_type.get(type(ev).__name__,
                                                 0) + 1
    done = [e for e in sched.events
            if isinstance(e, CompletionEvent) and e.workflow_done]
    print("event log: " + "  ".join(
        f"{k}={v}" for k, v in sorted(by_type.items())))
    print(f"workflow completions in stream: {len(done)}")


if __name__ == "__main__":
    main()
