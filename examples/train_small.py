"""Train a reduced qwen3-family model for a few hundred steps with the
fault-tolerant trainer: checkpointing, a simulated node failure at step
120, and automatic resume.

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse
import shutil
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax                                                   # noqa: E402
import jax.numpy as jnp                                      # noqa: E402

from repro.configs.archs import SMOKE                        # noqa: E402
from repro.launch.steps import make_train_step               # noqa: E402
from repro.models.families import build_model                # noqa: E402
from repro.training import optimizer as opt                  # noqa: E402
from repro.training.data import DataConfig, SyntheticTokens  # noqa: E402
from repro.training.trainer import TrainConfig, Trainer      # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen3-1.7b")
    args = ap.parse_args()

    cfg = SMOKE[args.arch]
    model = build_model(cfg)
    params = jax.tree.map(lambda p: p.astype(jnp.float32),
                          model.init(jax.random.PRNGKey(0)))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.2f}M params")

    opt_state = opt.init_state(params)
    ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=20,
                           total_steps=args.steps)
    step_fn, _ = make_train_step(cfg, dp_size=1, global_batch=8,
                                 opt_cfg=ocfg)
    data = SyntheticTokens(DataConfig(cfg.vocab_size, seq_len=32,
                                      global_batch=8))
    ckpt_dir = tempfile.mkdtemp(prefix="repro_train_")
    tc = TrainConfig(steps=args.steps, ckpt_every=50, ckpt_dir=ckpt_dir)

    trainer = Trainer(cfg, jax.jit(step_fn), params, opt_state, data, tc)
    fail_step = args.steps * 3 // 5
    print(f"training {args.steps} steps; simulated node failure at "
          f"step {fail_step}...")
    try:
        trainer.run(fail_at=fail_step)
    except RuntimeError as e:
        print(f"  !! {e} — restarting from checkpoint")
    trainer2 = Trainer(cfg, jax.jit(step_fn), params, opt_state, data, tc)
    report = trainer2.run()
    print(f"resumed from step {report.restored_from}; finished at "
          f"step {report.final_step}")
    print(f"loss: first={report.losses[0]:.3f} "
          f"last={report.losses[-1]:.3f}")
    print(f"straggler flags: {len(report.straggler_flags)}")
    shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
