"""Quickstart: schedule one lifted workflow with FATE vs the baselines.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.devices import homogeneous_cluster          # noqa: E402
from repro.core.executor import WorkflowExecutor, fresh_state  # noqa: E402
from repro.core.policies import make_policy                 # noqa: E402
from repro.workflowbench.lift import build_instance         # noqa: E402


def main() -> None:
    wf = build_instance("Montage", 0, num_queries=16)
    print(f"workflow {wf.wid}: {len(wf.stages)} stages, "
          f"{wf.max_level()+1} levels, {wf.num_queries} queries")
    cluster = homogeneous_cluster(8)
    print(f"cluster: {cluster.n} devices\n")
    print(f"{'policy':12s} {'makespan':>9s} {'P95':>9s} {'switches':>9s}")
    base = None
    for pol in ["RoundRobin", "HEFT", "Halo", "Helix", "KVFlow", "FATE"]:
        res = WorkflowExecutor(fresh_state(cluster)).run(
            wf, make_policy(pol))
        if pol == "RoundRobin":
            base = res.makespan
        print(f"{pol:12s} {res.makespan:9.2f} {res.p95:9.2f} "
              f"{res.model_switches:9d}   "
              f"({res.makespan / base:.3f}x RR)")

    # FATE internals: every frontier solve is exact
    pol = make_policy("FATE")
    WorkflowExecutor(fresh_state(cluster)).run(wf, pol)
    times = [r.wall_time * 1e3 for r in pol.solve_log]
    print(f"\nFATE planner: {len(times)} CP-SAT solves, all "
          f"{'OPTIMAL' if all(r.status == 'OPTIMAL' for r in pol.solve_log) else '??'}, "
          f"mean {sum(times)/len(times):.2f} ms, max {max(times):.2f} ms")


if __name__ == "__main__":
    main()
