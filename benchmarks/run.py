"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus human-readable summaries)
and writes per-experiment CSVs under results/workflow.

    PYTHONPATH=src python -m benchmarks.run              # everything
    PYTHONPATH=src python -m benchmarks.run --only table1,table12
    PYTHONPATH=src python -m benchmarks.run --quick      # small slices
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import kernels_bench, tables

    benches = {
        "table1": lambda: tables.table1_main(full=not args.quick),
        "table2": tables.table2_prefix,
        "table3": tables.table3_ablation,
        "table8": tables.table8_families,
        "table9": tables.table9_conflict,
        "table10": tables.table10_sensitivity,
        "table11": tables.table11_perturbation,
        "table12": tables.table12_solver,
        "fig2": tables.fig2_ecdf,
        "kernels": kernels_bench.run,
        "roofline": _roofline_summary,
    }
    all_rows: list[str] = []
    t_start = time.time()
    for name, fn in benches.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            rows = fn() or []
            all_rows.extend(rows)
            print(f"[{name} done in {time.time()-t0:.1f}s]")
        except Exception as e:   # keep the harness running
            import traceback
            traceback.print_exc()
            all_rows.append(f"{name}/ERROR,0,{type(e).__name__}")
    print("\n# CSV (name,us_per_call,derived)")
    for row in all_rows:
        print(row)
    print(f"# total wall time {time.time()-t_start:.1f}s")


def _roofline_summary() -> list[str]:
    """§Roofline: summarize the dry-run artifacts (single-pod mesh)."""
    import json
    root = Path(__file__).resolve().parents[1] / "results" / "dryrun"
    rows = []
    if not root.exists():
        print("no dry-run artifacts; run: python -m repro.launch.dryrun")
        return rows
    print("\n# Roofline terms per (arch × shape), single-pod 256 chips:")
    print(f"{'cell':46s} {'comp(s)':>9s} {'mem(s)':>9s} {'coll(s)':>9s} "
          f"{'dominant':>12s} {'useful':>7s}")
    for f in sorted(root.glob("*__single.json")):
        r = json.loads(f.read_text())
        if "error" in r:
            continue
        cell = f"{r['arch']}/{r['shape']}"
        print(f"{cell:46s} {r['compute_s']:9.3f} {r['memory_s']:9.3f} "
              f"{r['collective_s']:9.3f} {r['dominant']:>12s} "
              f"{r['useful_flop_ratio']:7.3f}")
        rows.append(f"roofline/{cell}/bound_s,0,"
                    f"{r['roofline_bound_s']:.4f}")
        rows.append(f"roofline/{cell}/useful,0,"
                    f"{r['useful_flop_ratio']:.4f}")
    return rows


if __name__ == "__main__":
    main()
