"""Planner-throughput benchmark: vectorized frontier-scoring engine vs
the seed's scalar per-(stage, slot, device) loop.

Sweeps frontier width × device count × horizon on a map/reduce-shaped
DAG (each ready worker roots a fan-out subtree, so the horizon tail has
real downstream demand to fold), checks that both paths emit
bit-identical placements, and writes a ``BENCH_sched.json`` trajectory.

    PYTHONPATH=src python -m benchmarks.sched_bench            # full grid
    PYTHONPATH=src python -m benchmarks.sched_bench --quick    # smoke gate

The wide-frontier config (32 ready × 16 devices, horizon 4) is the
acceptance target: >= 5x planner wall-time speedup.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.devices import heterogeneous_cluster          # noqa: E402
from repro.core.executor import fresh_state                   # noqa: E402
from repro.core.planner import FrontierPlanner                # noqa: E402
from repro.core.scoring import ScoreParams                    # noqa: E402
from repro.core.workflow import Stage, Workflow               # noqa: E402

MODELS = ["qwen-7b", "deepseek-7b", "llama-8b", "llama-3b", "qwen-14b"]
REPO_ROOT = Path(__file__).resolve().parents[1]
TARGET_SPEEDUP = 5.0
WIDE = (32, 16, 4)                  # width, devices, horizon


def bench_workflow(width: int, depth: int = 3, fanout: int = 2,
                   num_queries: int = 16) -> Workflow:
    """Map/reduce-style DAG: ``width`` parallel workers, each rooting a
    ``fanout**depth`` subtree (descendant demand for the horizon tail),
    fed by completed ingest stages (parent-location/transfer signals)."""
    stages: dict[str, Stage] = {}
    for i in range(width):
        stages[f"in{i}"] = Stage(f"in{i}", MODELS[i % 5],
                                 base_cost={-1: 0.05},
                                 output_tokens=256.0)
        stages[f"w{i}"] = Stage(
            f"w{i}", MODELS[(i + 1) % 5], max_shards=2,
            base_cost={-1: 0.1 + 0.01 * (i % 7)},
            prefix_group=f"g{i % 4}", shared_fraction=0.5,
            output_tokens=384.0,
            parents=(f"in{i}", f"in{(i + 1) % width}"))
        prev = [f"w{i}"]
        for lv in range(1, depth + 1):
            cur = []
            for pi, par in enumerate(prev):
                for b in range(fanout):
                    sid = f"c{i}_{lv}_{pi}_{b}"
                    stages[sid] = Stage(
                        sid, MODELS[(i + lv + b) % 5],
                        base_cost={-1: 0.08},
                        prefix_group=f"g{i % 4}",
                        output_tokens=256.0, parents=(par,))
                    cur.append(sid)
            prev = cur
    return Workflow(wid=f"sched-bench-{width}", stages=stages,
                    num_queries=num_queries)


def _warmed_state(wf: Workflow, width: int, cluster):
    """Ingest stages done, models resident, some prefixes warm — so every
    scoring term (transfer, locality, prefix, residency) is live."""
    state = fresh_state(cluster)
    n_dev = cluster.n
    for i in range(width):
        d = i % n_dev
        state.output_loc[(wf.wid, f"in{i}")] = (d,)
        state.completed.add((wf.wid, f"in{i}"))
        state.residency[d] = MODELS[i % 5]
        state.warm_prefix(d, f"g{i % 4}", MODELS[(i + 1) % 5], 8, 0.0)
    return state


def _time_plans(planner: FrontierPlanner, wf: Workflow, state,
                ready: list[str], min_reps: int,
                min_seconds: float) -> tuple[float, list[tuple]]:
    placements = planner.plan(wf, state, list(ready))   # warm caches
    reps, elapsed = 0, 0.0
    t_start = time.perf_counter()
    while reps < min_reps or elapsed < min_seconds:
        placements = planner.plan(wf, state, list(ready))
        reps += 1
        elapsed = time.perf_counter() - t_start
        if reps >= 200:
            break
    key = [(p.sid, p.devices, p.shard_sizes) for p in placements]
    return elapsed / reps, key


def run_config(width: int, n_devices: int, horizon: int, *,
               min_reps: int = 5, min_seconds: float = 0.3) -> dict:
    wf = bench_workflow(width)
    cluster = heterogeneous_cluster(n_devices)
    state = _warmed_state(wf, width, cluster)
    ready = [f"w{i}" for i in range(width)]
    params = ScoreParams(horizon=horizon)

    fast = FrontierPlanner(params, use_matrix=True)
    slow = FrontierPlanner(params, use_matrix=False)
    t_fast, key_fast = _time_plans(fast, wf, state, ready,
                                   min_reps, min_seconds)
    t_slow, key_slow = _time_plans(slow, wf, state, ready,
                                   max(2, min_reps // 2), min_seconds)
    return {
        "frontier_width": width,
        "n_devices": n_devices,
        "horizon": horizon,
        "n_stages": len(wf.stages),
        "fast_ms": t_fast * 1e3,
        "slow_ms": t_slow * 1e3,
        "speedup": t_slow / t_fast,
        "identical_placements": key_fast == key_slow,
        "n_placed": len(key_fast),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="wide-frontier config only, short timing windows")
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_sched.json"))
    args = ap.parse_args()

    if args.quick:
        grid = [WIDE]
        min_reps, min_seconds = 3, 0.1
    else:
        grid = [(w, d, h)
                for w in (8, 16, 32, 48)
                for d in (8, 16)
                for h in (2, 4)]
        if WIDE not in grid:
            grid.append(WIDE)
        min_reps, min_seconds = 5, 0.3

    rows = []
    for width, n_dev, horizon in grid:
        row = run_config(width, n_dev, horizon,
                         min_reps=min_reps, min_seconds=min_seconds)
        rows.append(row)
        print(f"width={width:3d} devices={n_dev:3d} horizon={horizon} | "
              f"fast {row['fast_ms']:7.2f} ms  slow {row['slow_ms']:7.2f} ms"
              f"  speedup {row['speedup']:5.1f}x  "
              f"identical={row['identical_placements']}")

    wide = next(r for r in rows
                if (r["frontier_width"], r["n_devices"], r["horizon"])
                == WIDE)
    ok = (wide["speedup"] >= TARGET_SPEEDUP
          and all(r["identical_placements"] for r in rows))
    report = {
        "benchmark": "sched_bench",
        "unix_time": time.time(),
        "target_speedup": TARGET_SPEEDUP,
        "wide_frontier": wide,
        "configs": rows,
        "pass": ok,
    }
    out = Path(args.out)
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"\nwide frontier (32x16, H=4): {wide['speedup']:.1f}x "
          f"(target >= {TARGET_SPEEDUP:.0f}x)  ->  "
          f"{'PASS' if ok else 'FAIL'}  [{out}]")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
