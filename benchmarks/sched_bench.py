"""Planner-throughput benchmark: vectorized frontier-scoring engine vs
the seed's scalar per-(stage, slot, device) loop, plus the incremental
delta-rescoring engine vs full matrix rebuilds on a steady-state
rolling-frontier trace, plus a Poisson multi-workflow serving smoke.

Sweeps frontier width × device count × horizon on a map/reduce-shaped
DAG (each ready worker roots a fan-out subtree, so the horizon tail has
real downstream demand to fold), checks that both paths emit
bit-identical placements, and writes a ``BENCH_sched.json`` trajectory.

    PYTHONPATH=src python -m benchmarks.sched_bench            # full grid
    PYTHONPATH=src python -m benchmarks.sched_bench --quick    # smoke gate
    PYTHONPATH=src python -m benchmarks.sched_bench --profile  # phase times
    PYTHONPATH=src python -m benchmarks.sched_bench --serve    # serving mode
    PYTHONPATH=src python -m benchmarks.sched_bench --serve-slo  # SLO plane
    PYTHONPATH=src python -m benchmarks.sched_bench --calibrate  # cost model
    PYTHONPATH=src python -m benchmarks.sched_bench --chaos      # fault gate
    PYTHONPATH=src python -m benchmarks.sched_bench --scale      # 1k gate
    PYTHONPATH=src python -m benchmarks.sched_bench --classes    # priority gate
    PYTHONPATH=src python -m benchmarks.sched_bench --config SCHED_config.json

Gates (enforced by exit code, used by ``make check`` / CI):
  * wide-frontier (32 ready × 16 devices, horizon 4) matrix vs scalar
    planner wall-time speedup >= 5x, bit-identical placements;
  * steady-state replanning on the same 32x16 H=4 rolling-frontier
    trace: delta rescoring >= 2x faster than the full-rescore matrix
    path (guard; the PR target is 3x, recorded in the report), with
    bit-identical score tables and solver placements at every event;
  * ``--serve-slo``: on an overloaded Poisson trace the SLO control
    plane (admission + deferral + preemption + warm-started merged
    solves) achieves STRICTLY better SLO attainment and SLO goodput
    than unconditional admission, with nonzero rejections/preemptions
    and placements bit-identical to a cold-solve reference; every leg
    runs through the event-driven ``Scheduler`` API and the
    controlled leg's ``SchedulerConfig`` is archived as
    ``SCHED_config.json`` (replayable via ``--config``);
  * ``--calibrate``: the cost-model calibration loop (see
    ``run_calibrate``) — the fit recovers a synthetic truth's
    coefficients within 15%, the calibrated profile + online probe
    correction cut median probe absolute error >= 2x vs the hand-set
    constants on the overloaded n=18 trace, and placements stay
    bit-identical across score paths under the fitted profile; the
    fitted ``CALIBRATION_profile.json`` is written next to
    ``BENCH_sched.json`` (CI uploads both);
  * ``--chaos``: under a seeded fault script (device crash + recovery,
    slowdown episode, targeted transient shard failures) FATE
    completes 100% of admitted workflows with makespan <= 2x the
    fault-free horizon, two same-seed runs produce bit-identical
    event streams, and an EMPTY armed fault plan reproduces the
    fault-free run bit-for-bit; writes ``BENCH_chaos.json`` next to
    ``BENCH_sched.json`` (CI uploads it);
  * ``--scale``: 1000 bursty workflows on a 64-device cluster under
    the hierarchical pooled solve + batched admission probing — 100%
    completion, zero invariant-audit violations (audited every 100
    steps and after drain), mean per-event scheduler overhead under
    the 5 ms ceiling, and single-pool hierarchical placements
    bit-identical to the monolithic merged solve; writes
    ``BENCH_scale.json`` next to ``BENCH_sched.json`` (CI uploads it).
"""
from __future__ import annotations

import argparse
import json
import random
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np                                            # noqa: E402

from repro.core.costs import CostModel                        # noqa: E402
from repro.core.devices import heterogeneous_cluster, \
    homogeneous_cluster                                       # noqa: E402
from repro.core.executor import fresh_state                   # noqa: E402
from repro.core.frontier_solver import FrontierProblem, \
    solve_frontier_exact                                      # noqa: E402
from repro.core.planner import FrontierPlanner                # noqa: E402
from repro.core.scoring import ScoreParams, Scorer            # noqa: E402
from repro.core.workflow import Stage, Workflow               # noqa: E402

MODELS = ["qwen-7b", "deepseek-7b", "llama-8b", "llama-3b", "qwen-14b"]
REPO_ROOT = Path(__file__).resolve().parents[1]
TARGET_SPEEDUP = 5.0
DELTA_TARGET = 3.0              # steady-state replanning speedup target
DELTA_GUARD = 2.0               # make-check / CI regression guard
WIDE = (32, 16, 4)                  # width, devices, horizon
CALIBRATE_TARGET = 2.0          # median probe abs-error reduction gate
CALIBRATE_FIT_TOL = 0.15        # max rel coefficient error of the fit


def bench_workflow(width: int, depth: int = 3, fanout: int = 2,
                   num_queries: int = 16) -> Workflow:
    """Map/reduce-style DAG: ``width`` parallel workers, each rooting a
    ``fanout**depth`` subtree (descendant demand for the horizon tail),
    fed by completed ingest stages (parent-location/transfer signals)."""
    stages: dict[str, Stage] = {}
    for i in range(width):
        stages[f"in{i}"] = Stage(f"in{i}", MODELS[i % 5],
                                 base_cost={-1: 0.05},
                                 output_tokens=256.0)
        stages[f"w{i}"] = Stage(
            f"w{i}", MODELS[(i + 1) % 5], max_shards=2,
            base_cost={-1: 0.1 + 0.01 * (i % 7)},
            prefix_group=f"g{i % 4}", shared_fraction=0.5,
            output_tokens=384.0,
            parents=(f"in{i}", f"in{(i + 1) % width}"))
        prev = [f"w{i}"]
        for lv in range(1, depth + 1):
            cur = []
            for pi, par in enumerate(prev):
                for b in range(fanout):
                    sid = f"c{i}_{lv}_{pi}_{b}"
                    stages[sid] = Stage(
                        sid, MODELS[(i + lv + b) % 5],
                        base_cost={-1: 0.08},
                        prefix_group=f"g{i % 4}",
                        output_tokens=256.0, parents=(par,))
                    cur.append(sid)
            prev = cur
    return Workflow(wid=f"sched-bench-{width}", stages=stages,
                    num_queries=num_queries)


def _warmed_state(wf: Workflow, width: int, cluster, profiles=None):
    """Ingest stages done, models resident, some prefixes warm — so every
    scoring term (transfer, locality, prefix, residency) is live."""
    state = fresh_state(cluster, profiles=profiles)
    n_dev = cluster.n
    for i in range(width):
        d = i % n_dev
        state.output_loc[(wf.wid, f"in{i}")] = (d,)
        state.completed.add((wf.wid, f"in{i}"))
        state.residency[d] = MODELS[i % 5]
        state.warm_prefix(d, f"g{i % 4}", MODELS[(i + 1) % 5], 8, 0.0)
    return state


def _time_plans(planner: FrontierPlanner, wf: Workflow, state,
                ready: list[str], min_reps: int,
                min_seconds: float) -> tuple[float, list[tuple]]:
    placements = planner.plan(wf, state, list(ready))   # warm caches
    reps, elapsed = 0, 0.0
    t_start = time.perf_counter()
    while reps < min_reps or elapsed < min_seconds:
        placements = planner.plan(wf, state, list(ready))
        reps += 1
        elapsed = time.perf_counter() - t_start
        if reps >= 200:
            break
    key = [(p.sid, p.devices, p.shard_sizes) for p in placements]
    return elapsed / reps, key


def run_config(width: int, n_devices: int, horizon: int, *,
               min_reps: int = 5, min_seconds: float = 0.3) -> dict:
    wf = bench_workflow(width)
    cluster = heterogeneous_cluster(n_devices)
    state = _warmed_state(wf, width, cluster)
    ready = [f"w{i}" for i in range(width)]
    params = ScoreParams(horizon=horizon)

    # use_delta=False: this gate isolates the batched BUILD engine vs
    # the scalar loop; cross-plan delta reuse has its own benchmark
    # (run_delta_config) and would otherwise mask build regressions.
    fast = FrontierPlanner(params, use_matrix=True, use_delta=False)
    slow = FrontierPlanner(params, use_matrix=False)
    t_fast, key_fast = _time_plans(fast, wf, state, ready,
                                   min_reps, min_seconds)
    t_slow, key_slow = _time_plans(slow, wf, state, ready,
                                   max(2, min_reps // 2), min_seconds)
    return {
        "frontier_width": width,
        "n_devices": n_devices,
        "horizon": horizon,
        "n_stages": len(wf.stages),
        "fast_ms": t_fast * 1e3,
        "slow_ms": t_slow * 1e3,
        "speedup": t_slow / t_fast,
        "identical_placements": key_fast == key_slow,
        "n_placed": len(key_fast),
    }


# ---------------------------------------------------------------------------
# steady-state rolling-frontier delta benchmark
# ---------------------------------------------------------------------------


def _completion_events(n_events: int, n_devices: int,
                       seed: int = 0) -> list[tuple]:
    """Deterministic completion-like state mutations: each event frees a
    device at a later time, flips its residency, warms a prefix group,
    and advances the clock — exactly what one stage completion does to
    (ρ, κ, τ) between serving replans."""
    rng = random.Random(seed)
    return [(rng.randrange(n_devices), rng.choice(MODELS),
             f"g{rng.randrange(4)}", rng.randint(1, 16),
             rng.uniform(0.01, 0.1)) for _ in range(n_events)]


def _replay(wf: Workflow, cluster, ready: list[str], events: list[tuple],
            horizon: int, mode: str, check: bool = False) -> dict:
    """Replay the event trace replanning after every event.

    ``mode='full'`` rebuilds the score matrix from scratch each replan
    (PR 1's full-rescore path); ``mode='delta'`` rescored incrementally.
    With ``check=True`` both engines run in lockstep and every replan
    asserts bit-identical tables and identical solver placements.
    """
    width = len(ready)
    state = _warmed_state(wf, width, cluster)
    params = ScoreParams(horizon=horizon)
    sc = Scorer(state, CostModel(state), params)
    sc.set_frontier(wf, ready)
    prev = sc.score_matrix(wf, ready)
    identical = True
    elapsed = 0.0
    for d, m, g, q, dt in events:
        state.now += dt
        state.set_free_at(d, state.now + 0.08)
        state.set_resident(d, m)
        state.warm_prefix(d, g, m, q, state.now)
        t0 = time.perf_counter()
        sc.set_frontier(wf, ready)
        if mode == "delta":
            # no claimed dirty set: the safe snapshot-verified path,
            # exactly what the planner's cross-session wave runs
            prev = sc.rescore_matrix(wf, ready, prev)
        else:
            prev = sc.score_matrix(wf, ready)
        elapsed += time.perf_counter() - t0
        if check:
            sc2 = Scorer(state, CostModel(state), params)
            sc2.set_frontier(wf, ready)
            full = sc2.score_matrix(wf, ready)
            for name in ("raw", "eft", "base", "wait"):
                if not np.array_equal(getattr(prev, name),
                                      getattr(full, name)):
                    identical = False
            sol_a = solve_frontier_exact(FrontierProblem(
                [(s, 0) for s in ready], prev.devices, prev.raw.copy()))
            sol_b = solve_frontier_exact(FrontierProblem(
                [(s, 0) for s in ready], full.devices, full.raw.copy()))
            if sol_a.assignment != sol_b.assignment:
                identical = False
    return {"ms_per_replan": elapsed / max(len(events), 1) * 1e3,
            "identical": identical}


def run_delta_config(width: int = 32, n_devices: int = 16,
                     horizon: int = 4, *, n_events: int = 250,
                     n_check: int = 40) -> dict:
    """Steady-state replanning: delta rescoring vs full matrix rebuild
    on a rolling-frontier completion trace (the serving hot path)."""
    wf = bench_workflow(width)
    cluster = heterogeneous_cluster(n_devices)
    ready = [f"w{i}" for i in range(width)]
    events = _completion_events(n_events, n_devices)
    # correctness pass first (short, lockstep-verified)
    chk = _replay(wf, cluster, ready, events[:n_check], horizon,
                  "delta", check=True)
    full = _replay(wf, cluster, ready, events, horizon, "full")
    delta = _replay(wf, cluster, ready, events, horizon, "delta")
    return {
        "frontier_width": width,
        "n_devices": n_devices,
        "horizon": horizon,
        "n_events": n_events,
        "full_ms": full["ms_per_replan"],
        "delta_ms": delta["ms_per_replan"],
        "speedup": full["ms_per_replan"] / delta["ms_per_replan"],
        "identical": chk["identical"],
        "target": DELTA_TARGET,
        "guard": DELTA_GUARD,
    }


# ---------------------------------------------------------------------------
# per-phase profile + serving mode
# ---------------------------------------------------------------------------


def run_profile(width: int = 32, n_devices: int = 16,
                horizon: int = 4, reps: int = 20) -> dict:
    """Per-phase planner timing breakdown (matrix build vs delta
    rescore vs exact solve) over repeated plan() sessions."""
    wf = bench_workflow(width)
    cluster = heterogeneous_cluster(n_devices)
    state = _warmed_state(wf, width, cluster)
    ready = [f"w{i}" for i in range(width)]
    planner = FrontierPlanner(ScoreParams(horizon=horizon))
    planner.plan(wf, state, list(ready))        # warm caches
    planner.phase_ms = {k: 0.0 for k in planner.phase_ms}
    planner.solve_log.clear()
    t0 = time.perf_counter()
    for _ in range(reps):
        planner.plan(wf, state, list(ready))
    total_ms = (time.perf_counter() - t0) * 1e3
    phases = dict(planner.phase_ms)
    accounted = sum(phases.values())
    return {
        "reps": reps,
        "total_ms": total_ms,
        "phase_ms": phases,
        "other_ms": max(0.0, total_ms - accounted),
        "solves": len(planner.solve_log),
    }


def _run_from_config(trace, cluster, config, *, world_profiles=None,
                     world_cost_params=None, probe_corrector=None):
    """Run one serving trace through the event-driven Scheduler API:
    submit every arrival, drain, return ``(result, scheduler)``."""
    from repro.core.scheduler import Scheduler

    sched = Scheduler(cluster, config, world_profiles=world_profiles,
                      world_cost_params=world_cost_params,
                      probe_corrector=probe_corrector)
    for t, wf in trace:
        sched.submit(wf, at=t)
    res = sched.drain()
    return res, sched


def run_serve_slo(n_workflows: int = 18, rate: float = 14.0,
                  n_devices: int = 6, seed: int = 0,
                  config_out=None) -> dict:
    """SLO control-plane benchmark on an overloaded Poisson trace.

    Runs the same trace three ways under FATE — each leg expressed as
    a :class:`~repro.core.scheduler.SchedulerConfig` and driven
    through the event-driven ``Scheduler`` API: unconditional
    admission (deadlines tracked, control plane off), the SLO-aware
    control plane (admission + deferral + preemption + warm-started
    solves), and a cold-solve parity reference of the controlled run
    (``use_delta=False, warm_start=False``).  The controlled leg's
    config is serialized to ``config_out`` (CI uploads it next to
    ``BENCH_sched.json``), so the gated run is reproducible via
    ``sched_bench --config``.

    Gates (exit-code enforced when ``--serve-slo`` is passed):
      * controlled SLO attainment and SLO goodput STRICTLY better than
        unconditional admission;
      * nonzero rejections and preemptions (the mechanisms actually
        engage on this trace);
      * controlled placements/stats bit-identical to the cold-solve
        reference (warm starts and delta rescoring are pure speedups).
    """
    from repro.core.admission import SLOConfig
    from repro.core.scheduler import SchedulerConfig
    from repro.workflowbench.metrics import slo_summary
    from repro.workflowbench.suites import overloaded_serving_trace

    trace = overloaded_serving_trace(n_workflows=n_workflows, rate=rate,
                                     seed=seed, num_queries=8)
    cluster = homogeneous_cluster(n_devices)
    ctrl_cfg = SchedulerConfig(policy="FATE", slo=SLOConfig())
    if config_out is not None:
        ctrl_cfg.save(config_out)

    def _run(config):
        res, sched = _run_from_config(trace, cluster, config)
        return res, sched.runs

    uncond, _ = _run(SchedulerConfig(
        policy="FATE", slo=SLOConfig(admission=False, preemption=False)))
    ctrl, ctrl_runs = _run(ctrl_cfg)
    ref, ref_runs = _run(SchedulerConfig(
        policy="FATE", slo=SLOConfig(), use_delta=False,
        warm_start=False))

    identical = (set(ctrl.stats) == set(ref.stats)
                 and ctrl.rejected == ref.rejected
                 and ctrl.preemptions == ref.preemptions
                 and set(ctrl_runs) == set(ref_runs)
                 and all(ctrl_runs[k].placement.devices
                         == ref_runs[k].placement.devices
                         and ctrl_runs[k].placement.shard_sizes
                         == ref_runs[k].placement.shard_sizes
                         for k in ctrl_runs)
                 and all(ctrl.stats[w].makespan == ref.stats[w].makespan
                         for w in ctrl.stats))
    summary = slo_summary({"unconditional": uncond,
                           "controlled": ctrl})
    u, c = summary["unconditional"], summary["controlled"]
    ok = (c["slo_attainment"] > u["slo_attainment"]
          and c["goodput_slo_wps"] > u["goodput_slo_wps"]
          and c["n_rejected"] > 0
          and c["preemptions"] > 0
          and identical)
    return {
        "n_workflows": n_workflows,
        "rate": rate,
        "n_devices": n_devices,
        "policies": summary,
        "parity_identical": identical,
        "pass": ok,
    }


def run_chaos(n_workflows: int = 18, rate: float = 14.0,
              n_devices: int = 6, seed: int = 0) -> dict:
    """Chaos benchmark: fault-tolerant execution under a seeded fault
    script.

    Runs the overloaded n=18 serving trace four ways under FATE:
    fault-free (the baseline), under the
    :func:`~repro.workflowbench.suites.chaos_fault_plan` script (one
    device crash with recovery, a 3× slowdown episode, two targeted
    transient shard failures), the same chaos run replayed with the
    same seed, and with an EMPTY armed ``FaultPlan`` (machinery on,
    no faults).

    Gates (exit-code enforced when ``--chaos`` is passed):
      * completion: every admitted workflow completes under chaos
        (no ``gave_up`` degradations);
      * bounded degradation: chaos makespan <= 2x the fault-free
        horizon;
      * coverage: the script actually engaged — >=1 device down, >=2
        shard failures, >=1 straggler detection;
      * determinism: two same-seed chaos runs produce bit-identical
        event streams;
      * parity: the empty armed plan reproduces the fault-free run's
        placements and event stream bit-for-bit (the fault machinery
        is strictly additive).
    """
    import dataclasses

    from repro.core.faults import FaultPlan
    from repro.core.scheduler import SchedulerConfig
    from repro.workflowbench.metrics import chaos_summary
    from repro.workflowbench.suites import chaos_fault_plan, \
        overloaded_serving_trace

    trace = overloaded_serving_trace(n_workflows=n_workflows, rate=rate,
                                     seed=seed, num_queries=8)
    cluster = homogeneous_cluster(n_devices)

    def _events(sched):
        return [(type(e).__name__, dataclasses.astuple(e))
                for e in sched.events]

    def _placements(sched):
        return {f"{w}/{s}": [list(r.placement.devices),
                             list(r.placement.shard_sizes)]
                for (w, s), r in sched.runs.items()}

    base, s_base = _run_from_config(trace, cluster,
                                    SchedulerConfig(policy="FATE"))
    chaos_cfg = SchedulerConfig(policy="FATE",
                                faults=chaos_fault_plan(seed))
    chaos, s_chaos = _run_from_config(trace, cluster, chaos_cfg)
    replay, s_replay = _run_from_config(
        trace, cluster,
        SchedulerConfig.from_json(chaos_cfg.to_json()))
    empty, s_empty = _run_from_config(
        trace, cluster, SchedulerConfig(policy="FATE",
                                        faults=FaultPlan()))

    all_wids = {wf.wid for _, wf in trace}
    completed_all = (set(chaos.stats) == all_wids
                     and not chaos.failed)
    degradation = chaos.horizon / base.horizon if base.horizon else 1.0
    replay_identical = _events(s_chaos) == _events(s_replay)
    empty_parity = (_placements(s_base) == _placements(s_empty)
                    and _events(s_base) == _events(s_empty))
    engaged = (chaos.device_downs >= 1 and chaos.shard_failures >= 2
               and chaos.stragglers >= 1)
    ok = (completed_all and degradation <= 2.0 and engaged
          and replay_identical and empty_parity)
    return {
        "n_workflows": n_workflows,
        "rate": rate,
        "n_devices": n_devices,
        "seed": seed,
        "fault_plan": chaos_fault_plan(seed).to_dict(),
        "runs": chaos_summary({"fault-free": base, "chaos": chaos}),
        "completed_all": completed_all,
        "degradation": degradation,
        "replay_identical": replay_identical,
        "empty_plan_parity": empty_parity,
        "pass": ok,
    }


def run_recovery(n_workflows: int = 18, rate: float = 14.0,
                 n_devices: int = 6, seed: int = 0,
                 kill_fractions=(0.1, 0.3, 0.5, 0.7, 0.9),
                 snap_every: int = 20) -> dict:
    """Crash-recovery benchmark: durable control plane under chaos.

    Runs the overloaded n=18 chaos trace (same trace and fault script
    as ``--chaos``) once uninterrupted to fix a baseline fingerprint,
    then for each kill fraction: runs a journaled scheduler (periodic
    snapshots, 64 KiB journal segments so rotation is exercised),
    abandons it mid-run at the swept event index, reopens the journal
    directory cold, restores from the latest snapshot plus
    deterministic journal-tail replay, and drains to completion.  One
    kill point additionally gets a torn final journal line (a
    simulated mid-write crash) before reopening.

    Gates (exit-code enforced when ``--recovery`` is passed):
      * every recovered run completes all admitted workflows and its
        result fingerprint (per-workflow arrival/finish/per-query
        completion times, rejections, failures, horizon, every fault
        and control-plane counter, total event count) is bit-identical
        to the uninterrupted baseline, at EVERY kill point;
      * :func:`~repro.core.scheduler.audit_invariants` reports zero
        violations immediately after restore and again after drain;
      * the torn-tail kill point is detected
        (``recovered_torn_tail``) and still recovers bit-identically.
    """
    import tempfile

    from repro.core.admission import SLOConfig
    from repro.core.journal import EventJournal
    from repro.core.scheduler import (Scheduler, SchedulerConfig,
                                      audit_invariants)
    from repro.workflowbench.suites import chaos_fault_plan, \
        overloaded_serving_trace

    trace = overloaded_serving_trace(n_workflows=n_workflows, rate=rate,
                                     seed=seed, num_queries=8)
    cluster = homogeneous_cluster(n_devices)
    cfg = SchedulerConfig(policy="FATE", slo=SLOConfig(),
                          faults=chaos_fault_plan(seed))

    def _fingerprint(res, sched):
        return {
            "stats": {w: [s.arrival, s.finish,
                          list(s.query_completion), s.deadline]
                      for w, s in res.stats.items()},
            "rejected": list(res.rejected),
            "failed": list(res.failed),
            "horizon": res.horizon,
            "counters": [res.replans, res.preemptions, res.deferrals,
                         res.max_in_flight, res.device_downs,
                         res.shard_failures, res.retries,
                         res.stragglers, res.speculations],
            "n_events": sched.events.n_total,
        }

    base_res, base_sched = _run_from_config(trace, cluster, cfg)
    base_fp = _fingerprint(base_res, base_sched)
    total = base_sched.events.n_total
    kill_points = sorted({max(1, int(total * f))
                          for f in kill_fractions})
    torn_at = kill_points[len(kill_points) // 2]

    rows = []
    for k in kill_points:
        with tempfile.TemporaryDirectory() as tmp:
            journal = EventJournal(tmp, rotate_bytes=64 * 1024)
            sched = Scheduler(cluster,
                              SchedulerConfig.from_json(cfg.to_json()),
                              journal=journal)
            for t, wf in trace:
                sched.submit(wf, at=t)
            journal.write_snapshot(sched.snapshot())
            steps = 0
            while sched.events.n_total < k and sched.step():
                steps += 1
                if steps % snap_every == 0:
                    journal.write_snapshot(sched.snapshot())
            killed_at = sched.events.n_total
            del sched, journal                 # crash: abandon in place

            torn = k == torn_at
            if torn:
                segs = sorted(Path(tmp).glob("events-*.jsonl"))
                with segs[-1].open("a") as fh:   # simulated torn write
                    fh.write('{"event_version": 1, "type": "Sta')

            reopened = EventJournal(tmp)
            snap = reopened.latest_snapshot()
            snap_events = snap["events"]["n_total"]
            restored = Scheduler.restore(snap, reopened)
            audit_restored = audit_invariants(restored)
            res = restored.drain()
            audit_drained = audit_invariants(restored)
            identical = _fingerprint(res, restored) == base_fp
            rows.append({
                "kill_event_index": k,
                "killed_at": killed_at,
                "snapshot_event_index": snap_events,
                "replayed_tail": killed_at - snap_events,
                "torn_tail_injected": torn,
                "torn_tail_recovered": reopened.recovered_torn_tail,
                "audit_restored": audit_restored,
                "audit_drained": audit_drained,
                "identical": identical,
                "pass": (identical and not audit_restored
                         and not audit_drained
                         and (reopened.recovered_torn_tail == torn)),
            })

    ok = bool(rows) and all(r["pass"] for r in rows)
    return {
        "n_workflows": n_workflows,
        "rate": rate,
        "n_devices": n_devices,
        "seed": seed,
        "baseline_events": total,
        "baseline_completed": len(base_res.stats),
        "baseline_rejected": len(base_res.rejected),
        "kill_points": rows,
        "pass": ok,
    }


def run_classes(n_workflows: int = 18, rate: float = 14.0,
                n_devices: int = 6, seed: int = 0,
                kill_fractions=(0.15, 0.5, 0.85),
                snap_every: int = 20) -> dict:
    """Multi-class priority benchmark: weighted SLOs, aging, and true
    preemption of running shards.

    Three legs, all on the overloaded n=18 Poisson burst:

    1. **Default-class parity** — a config whose only class is
       ``"default"`` (``classes={"default": ClassSpec()}``) must
       reproduce the class-free ``SLOConfig()`` run bit-identically:
       same event log (field-for-field), same placements, same
       per-workflow stats.  The multi-class machinery is strictly
       additive.
    2. **Multi-class gates** — the same arrivals tagged
       platinum/batch/batch (:func:`multiclass_overloaded_trace`)
       under a weighted config with aging and running-shard
       preemption.  Gates: platinum SLO attainment >= the
       single-class controlled run's overall attainment; the batch
       (bottom) class completes 100% of its arrivals with max wait
       bounded by the aging starvation bound plus twice the
       single-class horizon; running-shard preemptions actually
       fire; zero invariant violations.
    3. **Journaled preemption recovery** — the multi-class config
       plus the ``--chaos`` fault script runs journaled, is killed at
       swept event indices (always including one just past the first
       ``ShardPreemptionEvent``), restored cold from snapshot +
       journal-tail replay, and drained.  Gates: the baseline emits
       at least one ``ShardPreemptionEvent``; every kill point
       recovers bit-identically (stats, rejections, failures,
       horizon, preemption counters, class map, event count) with
       clean audits at restore and after drain.

    All gates are exit-code enforced when ``--classes`` is passed;
    the report is written to ``BENCH_classes.json``.
    """
    import dataclasses
    import tempfile

    from repro.core.admission import ClassSpec, SLOConfig
    from repro.core.journal import EventJournal
    from repro.core.scheduler import (Scheduler, SchedulerConfig,
                                      audit_invariants)
    from repro.workflowbench.metrics import class_summary, slo_summary
    from repro.workflowbench.suites import (chaos_fault_plan,
                                            multiclass_overloaded_trace,
                                            overloaded_serving_trace)

    cluster = homogeneous_cluster(n_devices)
    trace = overloaded_serving_trace(n_workflows=n_workflows, rate=rate,
                                     seed=seed, num_queries=8)
    mc_trace = multiclass_overloaded_trace(
        n_workflows=n_workflows, rate=rate, seed=seed, num_queries=8)
    mc_slo = SLOConfig(
        classes={"platinum": ClassSpec(weight=4.0, latency_scale=8.0),
                 "batch": ClassSpec(weight=1.0, latency_scale=40.0,
                                    backlog_limit=18)},
        aging_rate=0.5, preempt_running=True, preempt_running_max=6,
        preempt_kill_cap=3)
    # aging closes the weight gap at aging_rate per second of queue
    # wait, so the bottom class outranks a fresh top-class arrival
    # after at most this many seconds (the anti-starvation guarantee)
    starvation_bound = ((mc_slo.class_weight("platinum")
                         - mc_slo.class_weight("batch"))
                        / mc_slo.aging_rate)

    def _events(sched):
        return [(type(e).__name__, dataclasses.astuple(e))
                for e in sched.events]

    def _placements(sched):
        return {f"{w}/{s}": [list(r.placement.devices),
                             list(r.placement.shard_sizes)]
                for (w, s), r in sched.runs.items()}

    def _stats(res):
        return {w: dataclasses.astuple(s)
                for w, s in sorted(res.stats.items())}

    def _run_mc(cfg, journal=None):
        sched = Scheduler(cluster, cfg, journal=journal)
        for t, wf, klass in mc_trace:
            sched.submit(wf, at=t, klass=klass)
        return sched

    # ---- leg 1: default-only class config is bit-identical --------
    plain, s_plain = _run_from_config(
        trace, cluster, SchedulerConfig(policy="FATE", slo=SLOConfig()))
    defaulted, s_defaulted = _run_from_config(
        trace, cluster,
        SchedulerConfig(policy="FATE", slo=SLOConfig(
            classes={"default": ClassSpec()})))
    parity = (_events(s_plain) == _events(s_defaulted)
              and _placements(s_plain) == _placements(s_defaulted)
              and _stats(plain) == _stats(defaulted)
              and plain.rejected == defaulted.rejected
              and plain.horizon == defaulted.horizon)

    # ---- leg 2: weighted classes, aging, running-shard preemption -
    single = slo_summary({"controlled": plain})["controlled"]
    mc_sched = _run_mc(SchedulerConfig(policy="FATE", slo=mc_slo))
    mc_res = mc_sched.drain()
    mc_audit = audit_invariants(mc_sched)
    per_class = class_summary(mc_res)
    plat = per_class.get("platinum", {})
    batch = per_class.get("batch", {})
    wait_bound = starvation_bound + 2.0 * plain.horizon
    gates = {
        "platinum_attainment_ge_single": (
            plat.get("slo_attainment", 0.0)
            >= single["slo_attainment"]),
        "batch_completes_everything": (
            batch.get("completion_rate", 0.0) == 1.0),
        "batch_wait_bounded": (
            batch.get("max_wait", float("inf")) <= wait_bound),
        "shard_preemptions_fired": mc_res.shard_preemptions > 0,
        "audit_clean": not mc_audit,
    }

    # ---- leg 3: journaled chaos + preemption crash recovery -------
    rec_cfg = SchedulerConfig(policy="FATE", slo=mc_slo,
                              faults=chaos_fault_plan(seed))

    def _fingerprint(res, sched):
        return {
            "stats": {w: [s.arrival, s.finish,
                          list(s.query_completion), s.deadline]
                      for w, s in sorted(res.stats.items())},
            "rejected": sorted(res.rejected),
            "failed": sorted(res.failed),
            "horizon": res.horizon,
            "counters": [res.replans, res.preemptions,
                         res.shard_preemptions, res.deferrals,
                         res.device_downs, res.shard_failures,
                         res.retries],
            "classes": dict(sorted(res.classes.items())),
            "n_events": sched.events.n_total,
        }

    base_sched = _run_mc(SchedulerConfig.from_json(rec_cfg.to_json()))
    base_res = base_sched.drain()
    base_fp = _fingerprint(base_res, base_sched)
    total = base_sched.events.n_total
    preempt_idxs = [i for i, e in enumerate(base_sched.events)
                    if type(e).__name__ == "ShardPreemptionEvent"]
    kill_points = sorted({max(1, int(total * f))
                          for f in kill_fractions}
                         | ({preempt_idxs[0] + 1} if preempt_idxs
                            else set()))

    rows = []
    for k in kill_points:
        with tempfile.TemporaryDirectory() as tmp:
            journal = EventJournal(tmp, rotate_bytes=64 * 1024)
            sched = _run_mc(SchedulerConfig.from_json(rec_cfg.to_json()),
                            journal=journal)
            journal.write_snapshot(sched.snapshot())
            steps = 0
            while sched.events.n_total < k and sched.step():
                steps += 1
                if steps % snap_every == 0:
                    journal.write_snapshot(sched.snapshot())
            killed_at = sched.events.n_total
            del sched, journal                 # crash: abandon in place

            reopened = EventJournal(tmp)
            restored = Scheduler.restore(reopened.latest_snapshot(),
                                         reopened)
            audit_restored = audit_invariants(restored)
            res = restored.drain()
            audit_drained = audit_invariants(restored)
            identical = _fingerprint(res, restored) == base_fp
            rows.append({
                "kill_event_index": k,
                "killed_at": killed_at,
                "past_first_preemption": bool(
                    preempt_idxs and k > preempt_idxs[0]),
                "audit_restored": audit_restored,
                "audit_drained": audit_drained,
                "identical": identical,
                "pass": (identical and not audit_restored
                         and not audit_drained),
            })

    recovery_ok = (bool(preempt_idxs) and bool(rows)
                   and all(r["pass"] for r in rows))
    ok = parity and all(gates.values()) and recovery_ok
    return {
        "n_workflows": n_workflows,
        "rate": rate,
        "n_devices": n_devices,
        "seed": seed,
        "default_class_parity": parity,
        "single_class": single,
        "per_class": per_class,
        "starvation_bound_s": starvation_bound,
        "batch_wait_bound_s": wait_bound,
        "shard_preemptions": mc_res.shard_preemptions,
        "revoke_preemptions": mc_res.preemptions,
        "gates": gates,
        "recovery": {
            "baseline_events": total,
            "preemption_event_indices": preempt_idxs,
            "kill_points": rows,
            "pass": recovery_ok,
        },
        "pass": ok,
    }


def _profile_parity(profile, width: int = 16, n_devices: int = 8,
                    horizon: int = 3) -> bool:
    """Bit-identical placements under a FIXED calibration profile.

    A loaded profile only changes constants (per-model switch/prefill/
    decode via the state's profiles, global scales via CostParams), so
    the matrix, scalar, and delta score paths must still agree exactly.
    Plans the warmed wide frontier twice per configuration (the second
    call exercises the cross-session delta-rescore path).
    """
    from repro.core.calibration import CalibrationProfile
    assert isinstance(profile, CalibrationProfile)
    wf = bench_workflow(width)
    cluster = heterogeneous_cluster(n_devices)
    profiles = profile.model_profiles()
    cparams = profile.cost_params()
    ready = [f"w{i}" for i in range(width)]
    params = ScoreParams(horizon=horizon)
    keys = []
    for kwargs in ({"use_matrix": True, "use_delta": True},
                   {"use_matrix": True, "use_delta": False},
                   {"use_matrix": False}):
        state = _warmed_state(wf, width, cluster, profiles=profiles)
        planner = FrontierPlanner(params, cost_params=cparams, **kwargs)
        key = []
        for _ in range(2):
            ps = planner.plan(wf, state, list(ready))
            key.append([(p.sid, p.devices, p.shard_sizes) for p in ps])
        keys.append(key)
    return all(k == keys[0] for k in keys)


def run_calibrate(n_workflows: int = 18, rate: float = 14.0,
                  n_devices: int = 6, seed: int = 0,
                  profile_out=None) -> dict:
    """End-to-end calibration gate: measure → fit → profile → probe.

    1. **Fit round-trip** — a synthetic instrumented trace (the
       format :meth:`repro.serving.engine.ServingEngine.observations`
       emits) is generated from a known TRUE profile whose constants
       diverge from the hand-set ones the way the real engine's do
       (tiny models switch far faster than the 7–14B proxies;
       token coefficients drift both ways); ``fit_profile`` must
       recover every identifiable non-base coefficient within 15%.
       The fitted profile is written to ``profile_out`` (CI uploads it
       next to ``BENCH_sched.json``).
    2. **Probe accuracy** — the overloaded n=18 Poisson trace runs in
       a world that follows the TRUE constants
       (``ServingExecutor(world_profiles=...)``) while the scheduler
       believes (a) the hand-set constants with the static
       ``probe_margin`` vs (b) the fitted profile with the online
       EWMA-corrected margin (one calibration pass warm-starts the
       corrector, which keeps updating online).  Gate: the calibrated
       configuration cuts the median absolute probe error
       (|margin·predicted − observed| over completed workflows) by
       ≥ ``CALIBRATE_TARGET``×.
    3. **Parity** — placements under the fitted profile are
       bit-identical across matrix/scalar and delta/full score paths
       (:func:`_profile_parity`).
    """
    from repro.core import calibration as C
    from repro.core.admission import SLOConfig
    from repro.core.scheduler import SchedulerConfig
    from repro.workflowbench.metrics import probe_error_summary
    from repro.workflowbench.suites import overloaded_serving_trace

    # 1. fit round-trip against a synthetic engine-style trace
    truth = C.CalibrationProfile.hand_set().perturbed(
        switch_mul=0.45, prefill_mul=1.3, decode_mul=0.8,
        transfer_mul=1.4, prefix_saving=0.75, base=0.001)
    trace_obs = C.synthetic_trace(truth, 600, seed=seed + 1,
                                  noise=0.01, time_scale=0.05)
    fitted = C.fit_profile(trace_obs, time_scale=0.05,
                           source="fit:synthetic-engine-trace")
    errs = {k: v for k, v in C.coefficient_errors(fitted, truth).items()
            if not k.endswith(".base")}   # base is µs-scale: noise-bound
    fit_err = max(errs.values()) if errs else float("inf")
    if profile_out is not None:
        fitted.save(profile_out)

    # 2. probe error, mis-believed vs calibrated constants
    trace = overloaded_serving_trace(n_workflows=n_workflows, rate=rate,
                                     seed=seed, num_queries=8)
    cluster = homogeneous_cluster(n_devices)
    world_profiles = truth.model_profiles()
    world_params = truth.cost_params()

    def _leg(belief_calibration, slo, corrector):
        # the scheduler's BELIEF is one SchedulerConfig (profiles +
        # cost params lowered from the embedded calibration profile);
        # the emulated hardware follows the TRUE constants
        config = SchedulerConfig(policy="FATE", slo=slo,
                                 calibration=belief_calibration)
        res, sched = _run_from_config(
            trace, cluster, config, world_profiles=world_profiles,
            world_cost_params=world_params, probe_corrector=corrector)
        return res, sched.admission

    res_hand, adm_hand = _leg(None, SLOConfig(), None)
    corrector = C.ProbeCorrector(prior=SLOConfig().probe_margin)
    for _ in range(2):    # pass 1 warm-starts the corrector, pass 2 is
        res_cal, adm_cal = _leg(           # the gated evaluation run
            fitted, SLOConfig(online_margin=True), corrector)
    hand = probe_error_summary(adm_hand.probe_log)
    cal = probe_error_summary(adm_cal.probe_log)
    if hand["n"] == 0 or cal["n"] == 0:
        # an empty probe log is a regression, not a win: without
        # completed evidence on BOTH legs the comparison is vacuous
        # (NaN medians must fail the gate, never sail through it)
        reduction = 0.0
    elif cal["median_abs_err"] == 0.0:
        reduction = float("inf")
    else:
        reduction = hand["median_abs_err"] / cal["median_abs_err"]

    # 3. score-path parity under the fitted profile
    parity = _profile_parity(fitted)

    ok = (fit_err <= CALIBRATE_FIT_TOL
          and reduction >= CALIBRATE_TARGET
          and parity)
    return {
        "n_workflows": n_workflows,
        "rate": rate,
        "n_devices": n_devices,
        "fit_max_rel_err": float(fit_err),
        "fit_tol": CALIBRATE_FIT_TOL,
        "probe_handset": {k: float(v) for k, v in hand.items()},
        "probe_calibrated": {k: float(v) for k, v in cal.items()},
        "error_reduction": float(reduction),
        "target_reduction": CALIBRATE_TARGET,
        "margins": {k: float(v) for k, v in corrector.margins.items()},
        "slo_attainment": {"handset": res_hand.slo_attainment,
                           "calibrated": res_cal.slo_attainment},
        "profile_parity": parity,
        "pass": ok,
    }


SCALE_N = 1000                  # --scale gate: workflows
SCALE_DEVICES = 64              # --scale gate: cluster size
SCALE_CEILING_MS = 5.0          # --scale gate: mean ms per event
SCALE_AUDIT_EVERY = 100         # --scale: invariant audit cadence


def _scale_pool_parity(width: int = 32, n_devices: int = 16,
                       horizon: int = 4) -> bool:
    """Single-pool hierarchical solve vs monolithic: bit-identical.

    Forces the hierarchical path with ONE pool holding every device
    (``_forced_partition``) on the wide 32×16 H=4 merged frontier
    (``plan_shared`` — the only path that partitions) and checks the
    placements match the monolithic merged solve exactly — twice, so
    the second plan exercises the delta-rescore path under the
    partitioned solve too.  The column-sliced score tables make this
    an identity by construction; the gate keeps it that way.
    """
    wf = bench_workflow(width)
    cluster = heterogeneous_cluster(n_devices)
    ready = [(wf.wid, f"w{i}") for i in range(width)]
    params = ScoreParams(horizon=horizon)
    keys = []
    for forced in (None, [list(cluster.ids())]):
        state = _warmed_state(wf, width, cluster)
        planner = FrontierPlanner(params)
        planner._forced_partition = forced
        key = []
        for _ in range(2):
            ps = planner.plan_shared({wf.wid: wf}, state, list(ready))
            key.append([(p.sid, p.devices, p.shard_sizes) for p in ps])
        keys.append(key)
    return bool(keys[0][0]) and keys[0] == keys[1]


def run_scale(n_workflows: int = SCALE_N,
              n_devices: int = SCALE_DEVICES, burst: int = 8,
              gap: float = 2.0, pools: int = 4,
              audit_every: int = SCALE_AUDIT_EVERY,
              ceiling_ms: float = SCALE_CEILING_MS) -> dict:
    """1k-workflow scale gate: hierarchical pooled solve + batched
    admission probing + indexed event-loop structures at fleet size.

    Drives the bursty :func:`~repro.workflowbench.suites.
    scale_serving_trace` (arrivals land ``burst`` at a time on the
    same timestamp, so every burst shares one batched admission
    overlay) through the event-driven ``Scheduler`` on a
    ``n_devices``-device cluster with the ``pools``-way hierarchical
    frontier solve, a bounded event ring (the 4096-slot buffer slides
    thousands of times at this scale), and a generous SLO so the
    admission plane probes every arrival without shedding load.

    Gates (exit-code enforced when ``--scale`` is passed):
      * completion: all ``n_workflows`` workflows complete — nothing
        rejected, failed, or stranded;
      * invariants: :func:`~repro.core.scheduler.audit_invariants`
        reports ZERO violations, checked every ``audit_every`` steps
        mid-run and once more after drain (audit time is excluded
        from the timed window);
      * overhead ceiling: mean scheduler wall-time per emitted event
        stays under ``ceiling_ms`` — the end-to-end guard on the hot
        loop (partitioned solves, batched probes, indexed scans);
      * parity: the single-pool hierarchical solve is bit-identical
        to the monolithic merged solve on the wide 32×16 H=4
        frontier (:func:`_scale_pool_parity`).

    The per-phase planner breakdown of the scale run is always
    recorded in the report (``phase_ms``) — ``docs/SCALE.md`` explains
    how to read it.
    """
    from repro.core.admission import SLOConfig
    from repro.core.scheduler import (Scheduler, SchedulerConfig,
                                      audit_invariants)
    from repro.workflowbench.suites import scale_serving_trace

    trace = scale_serving_trace(n_workflows, burst=burst, gap=gap,
                                num_queries=1)
    cluster = homogeneous_cluster(n_devices)
    config = SchedulerConfig(policy="FATE",
                             slo=SLOConfig(latency_scale=100.0),
                             pools=pools, batch_probes=True,
                             event_buffer=4096)
    sched = Scheduler(cluster, config)
    for t, wf in trace:
        sched.submit(wf, at=t)

    violations: list[str] = []
    steps = 0
    audit_s = 0.0
    t0 = time.perf_counter()
    while True:
        if not sched.step():
            break
        steps += 1
        if steps % audit_every == 0:
            a0 = time.perf_counter()
            violations += audit_invariants(sched)
            audit_s += time.perf_counter() - a0
    wall_s = time.perf_counter() - t0 - audit_s
    res = sched.drain()
    violations += audit_invariants(sched)

    n_events = sched.events.n_total
    mean_ms = wall_s * 1e3 / max(n_events, 1)
    completed_all = (len(res.stats) == n_workflows
                     and not res.rejected and not res.failed)
    parity = _scale_pool_parity()
    ok = (completed_all and not violations
          and mean_ms <= ceiling_ms and parity)
    return {
        "n_workflows": n_workflows,
        "n_devices": n_devices,
        "burst": burst,
        "pools": pools,
        "n_completed": len(res.stats),
        "n_rejected": len(res.rejected),
        "n_failed": len(res.failed),
        "completed_all": completed_all,
        "n_events": n_events,
        "events_dropped_from_ring": sched.events.n_dropped,
        "max_in_flight": res.max_in_flight,
        "replans": res.replans,
        "n_probes": sched.admission.n_probes,
        "horizon_s": res.horizon,
        "wall_s": wall_s,
        "audit_s": audit_s,
        "n_audits": steps // audit_every + 1,
        "violations": violations,
        "mean_event_ms": mean_ms,
        "ceiling_ms": ceiling_ms,
        "phase_ms": {k: float(v)
                     for k, v in sched.policy.phase_ms.items()},
        "single_pool_parity": parity,
        "pass": ok,
    }


def run_serve(n_workflows: int = 12, rate: float = 6.0,
              n_devices: int = 8, seed: int = 0) -> dict:
    """Poisson multi-workflow serving smoke: shared-frontier FATE vs
    round-robin, normalized makespan/P95/goodput."""
    from repro.workflowbench.metrics import serving_summary
    from repro.workflowbench.runner import run_serving
    from repro.workflowbench.suites import poisson_serving_trace

    trace = poisson_serving_trace(n_workflows=n_workflows, rate=rate,
                                  seed=seed, num_queries=8)
    results = run_serving(trace, ["RoundRobin", "FATE"],
                          homogeneous_cluster(n_devices))
    summary = serving_summary(results)
    return {
        "n_workflows": n_workflows,
        "rate": rate,
        "n_devices": n_devices,
        "max_in_flight": max(r.max_in_flight for r in results.values()),
        "policies": summary,
    }


def _gateway_events(sched) -> list:
    """Versioned event documents in emission order (parity compares)."""
    return [ev.to_dict() for ev in sched.events]


def _gateway_placements(sched) -> dict:
    """Issued-run placement records keyed by stage (parity compares)."""
    return {k: (r.placement.devices, r.placement.shard_sizes,
                r.placement.model, r.start, r.finish)
            for k, r in sched.runs.items()}


def _busy_device_seconds(sched) -> float:
    """Total device-seconds of issued execution: the routed-vs-fixed
    cost objective (each run occupies every device in its placement
    for its full duration)."""
    return sum((r.finish - r.start) * len(r.placement.devices)
               for r in sched.runs.values())


def _routed_quality(sched, trace) -> dict:
    """Chosen-family quality audit over the issued runs.

    Per run, quality is 1.0 when the stage ran its default family,
    else the declared candidate quality for the chosen alias.  Returns
    the minimum / mean chosen quality and how many runs were routed
    off their default — the quality-floor side of the gate.
    """
    by_wid = {wf.wid: wf for _, wf in trace}
    qualities, n_routed = [], 0
    for (wid, sid), r in sched.runs.items():
        st = by_wid[wid].stages[sid]
        model = r.placement.model or st.model
        if model == st.model:
            qualities.append(1.0)
        else:
            n_routed += 1
            qualities.append(dict(st.candidates)[model])
    return {"min_quality": min(qualities) if qualities else 1.0,
            "mean_quality": (sum(qualities) / len(qualities)
                             if qualities else 1.0),
            "n_runs": len(qualities), "n_routed": n_routed}


def run_gateway(n_devices: int = 6, seed: int = 0) -> dict:
    """HTTP serving-gateway gate (``--gateway``): the event-driven
    scheduler behind ``serving/gateway.py``, plus cost/quality routing.

    Four legs, all exit-code enforced:

    1. **Single-replica parity** — the overloaded n=18 trace submitted
       over live HTTP (explicit arrival times) then drained must be
       bit-identical to a direct ``Scheduler`` run: same events, same
       placements, same ``scheduler_fingerprint``.  The gateway adds
       transport, never scheduling decisions.
    2. **Poisson HTTP load** — wall-clock-paced Poisson submissions
       against the live gateway (no ``at``); gates 100%% completion
       and reports end-to-end P95 (gateway ingress wall-stamp to
       completion — transport + scheduling overhead included) and
       per-request submit latency.
    3. **Routing disabled == today** — a config with
       ``routing=RoutingConfig()`` on candidate-free workloads (the
       overloaded n=18 serving trace AND the 32x16 H=4 batch frontier)
       must match ``routing=None`` bit-for-bit: enabling the router
       without ``Stage.candidates`` is a provable no-op.
    4. **Routed vs fixed family** — on the routed trace (large default
       family with cheaper admissible alternates), routing must
       complete everything at chosen quality >= the floor while
       spending strictly fewer busy device-seconds than the
       fixed-family run, and must actually route (>0 off-default
       runs).
    """
    import http.client

    from repro.core.routing import RoutingConfig
    from repro.core.scheduler import Scheduler, SchedulerConfig
    from repro.core.scoring import ScoreParams
    from repro.serving.gateway import (Gateway, GatewayServer,
                                       scheduler_fingerprint)
    from repro.workflowbench.metrics import slo_summary
    from repro.workflowbench.suites import (overloaded_serving_trace,
                                            poisson_serving_trace,
                                            routed_serving_trace)

    cluster = homogeneous_cluster(n_devices)
    cfg = SchedulerConfig(policy="FATE")

    def _post(conn, path, doc=None):
        body = json.dumps(doc).encode() if doc is not None else b""
        conn.request("POST", path, body=body,
                     headers={"Content-Type": "application/json"})
        resp = conn.getresponse()
        return resp.status, json.loads(resp.read())

    # -- leg 1: single-replica parity over live HTTP -------------------
    trace = overloaded_serving_trace(seed=seed, num_queries=8)
    direct_res, direct_sched = _run_from_config(trace, cluster, cfg)
    gw = Gateway(lambda: Scheduler(cluster, cfg), replicas=1)
    with GatewayServer(gw) as srv:
        for t, wf in trace:
            conn = http.client.HTTPConnection(srv.host, srv.port,
                                              timeout=60)
            status, sub = _post(conn, "/v1/workflows",
                                {"workflow": wf.to_dict(), "at": t})
            conn.close()
            assert status == 202, (status, sub)
        conn = http.client.HTTPConnection(srv.host, srv.port,
                                          timeout=600)
        _, drain_doc = _post(conn, "/v1/drain")
        conn.close()
    gw_sched = gw.replicas[0].sched
    parity = {
        "events_identical": (_gateway_events(direct_sched)
                             == _gateway_events(gw_sched)),
        "placements_identical": (_gateway_placements(direct_sched)
                                 == _gateway_placements(gw_sched)),
        "fingerprint_direct": scheduler_fingerprint(direct_sched),
        "fingerprint_gateway": drain_doc["replicas"][0]["fingerprint"],
        "n_completed": len(gw_sched.stats),
        "n_offered": direct_res.n_offered,
    }
    parity["fingerprint_identical"] = (parity["fingerprint_direct"]
                                       == parity["fingerprint_gateway"])
    parity_ok = (parity["events_identical"]
                 and parity["placements_identical"]
                 and parity["fingerprint_identical"])

    # -- leg 2: wall-clock Poisson load over live HTTP -----------------
    load_trace = poisson_serving_trace(n_workflows=12, rate=6.0,
                                       seed=seed, num_queries=8)
    gw2 = Gateway(lambda: Scheduler(homogeneous_cluster(8), cfg),
                  replicas=1)
    submit_ms = []
    wall0 = time.perf_counter()
    with GatewayServer(gw2) as srv:
        prev_t = 0.0
        for t, wf in load_trace:
            # pace submissions at the trace's Poisson gaps (compressed
            # 4x so the leg stays quick; relative order preserved)
            time.sleep(max(0.0, (t - prev_t) / 4.0))
            prev_t = t
            conn = http.client.HTTPConnection(srv.host, srv.port,
                                              timeout=60)
            t0 = time.perf_counter()
            status, sub = _post(conn, "/v1/workflows",
                                {"workflow": wf.to_dict()})
            submit_ms.append((time.perf_counter() - t0) * 1e3)
            conn.close()
            assert status == 202, (status, sub)
        conn = http.client.HTTPConnection(srv.host, srv.port,
                                          timeout=600)
        _, metrics_live = (conn.request("GET", "/v1/metrics"),
                           json.loads(conn.getresponse().read()))
        conn.close()
        conn = http.client.HTTPConnection(srv.host, srv.port,
                                          timeout=600)
        _, drain2 = _post(conn, "/v1/drain")
        conn.close()
    wall_s = time.perf_counter() - wall0
    slo_row = drain2["metrics"]["slo"]
    load = {
        "n_offered": len(load_trace),
        "n_completed": slo_row["n_completed"],
        "completion": (slo_row["n_completed"] / len(load_trace)),
        "p95_e2e_s": slo_row["p95_latency"],
        "mean_e2e_s": slo_row["mean_latency"],
        "submit_mean_ms": sum(submit_ms) / len(submit_ms),
        "submit_max_ms": max(submit_ms),
        "wall_s": wall_s,
        "live_metrics_replicas": len(metrics_live["replicas"]),
    }
    load_ok = (load["completion"] == 1.0
               and load["p95_e2e_s"] is not None)

    # -- leg 3: routing disabled is bit-identical ----------------------
    cfg_route_off = SchedulerConfig(policy="FATE")
    cfg_route_noop = SchedulerConfig(policy="FATE",
                                     routing=RoutingConfig())
    _, s_off = _run_from_config(trace, cluster, cfg_route_off)
    _, s_noop = _run_from_config(trace, cluster, cfg_route_noop)
    serving_noop = (_gateway_events(s_off) == _gateway_events(s_noop)
                    and _gateway_placements(s_off)
                    == _gateway_placements(s_noop)
                    and scheduler_fingerprint(s_off)
                    == scheduler_fingerprint(s_noop))
    # batch frontier: the 32x16 H=4 wide config, planner-level
    wf = bench_workflow(32)
    hcluster = heterogeneous_cluster(16)
    state_a = _warmed_state(wf, 32, hcluster)
    state_b = _warmed_state(wf, 32, hcluster)
    ready = [f"w{i}" for i in range(32)]
    params = ScoreParams(horizon=4)
    plain = FrontierPlanner(params).plan(wf, state_a, list(ready))
    routed = FrontierPlanner(params, routing=RoutingConfig()).plan(
        wf, state_b, list(ready))
    batch_noop = ([(p.sid, p.devices, p.shard_sizes, p.model)
                   for p in plain]
                  == [(p.sid, p.devices, p.shard_sizes, p.model)
                      for p in routed])
    noop = {"serving_identical": serving_noop,
            "batch_identical": batch_noop}
    noop_ok = serving_noop and batch_noop

    # -- leg 4: routed vs fixed family cost/quality --------------------
    rtrace = routed_serving_trace(n_workflows=10, rate=4.0, seed=seed)
    fixed_res, fixed_sched = _run_from_config(
        rtrace, cluster, SchedulerConfig(policy="FATE"))
    routed_res, routed_sched = _run_from_config(
        rtrace, cluster,
        SchedulerConfig(policy="FATE", routing=RoutingConfig()))
    quality = _routed_quality(routed_sched, rtrace)
    floor = RoutingConfig().quality_floor
    routed_row = {
        "n_offered": routed_res.n_offered,
        "fixed_completed": len(fixed_res.stats),
        "routed_completed": len(routed_res.stats),
        "fixed_cost_device_s": _busy_device_seconds(fixed_sched),
        "routed_cost_device_s": _busy_device_seconds(routed_sched),
        "quality_floor": floor,
        **quality,
        "fixed_p95": slo_summary(
            {"fixed": fixed_res})["fixed"]["p95_latency"],
        "routed_p95": slo_summary(
            {"routed": routed_res})["routed"]["p95_latency"],
    }
    routed_row["cost_ratio"] = (routed_row["routed_cost_device_s"]
                                / routed_row["fixed_cost_device_s"])
    routed_ok = (routed_row["routed_completed"]
                 == routed_row["n_offered"]
                 and quality["n_routed"] > 0
                 and quality["min_quality"] >= floor
                 and routed_row["routed_cost_device_s"]
                 < routed_row["fixed_cost_device_s"])

    return {
        "n_devices": n_devices,
        "parity": parity,
        "load": load,
        "routing_noop": noop,
        "routed_vs_fixed": routed_row,
        "legs": {"parity": parity_ok, "load": load_ok,
                 "routing_noop": noop_ok, "routed": routed_ok},
        "pass": parity_ok and load_ok and noop_ok and routed_ok,
    }


def run_from_config_file(config_path: str, out: Path,
                         n_workflows: int = 18, rate: float = 14.0,
                         n_devices: int = 6, seed: int = 0) -> dict:
    """Replay the overloaded serving gate from a serialized
    :class:`~repro.core.scheduler.SchedulerConfig` artifact.

    Loads the config (``sched_bench --config``), drives the
    event-driven ``Scheduler`` over the standard overloaded n=18
    trace, prints the serving outcome, and appends a
    ``config_run`` section to the report JSON — so any gated run CI
    archived (``SCHED_config.json``) reproduces bit-identically from
    its artifact alone.
    """
    from repro.core.scheduler import SchedulerConfig, SchedulerEvent
    from repro.workflowbench.suites import overloaded_serving_trace

    config = SchedulerConfig.load(config_path)
    trace = overloaded_serving_trace(n_workflows=n_workflows, rate=rate,
                                     seed=seed, num_queries=8)
    res, sched = _run_from_config(trace, homogeneous_cluster(n_devices),
                                  config)
    by_type: dict[str, int] = {}
    for ev in sched.events:
        by_type[type(ev).__name__] = by_type.get(type(ev).__name__, 0) + 1
    row = {
        "config": str(config_path),
        "policy": config.policy,
        "n_offered": res.n_offered,
        "n_completed": len(res.stats),
        "n_rejected": len(res.rejected),
        "deferrals": res.deferrals,
        "preemptions": res.preemptions,
        "slo_attainment": res.slo_attainment,
        "goodput_slo_wps": res.goodput_slo_wps,
        "events": by_type,
    }
    report = {"benchmark": "sched_bench", "unix_time": time.time(),
              "config_run": row, "pass": True}
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"config run [{config_path}]: policy={config.policy} "
          f"completed={row['n_completed']}/{row['n_offered']} "
          f"rejected={row['n_rejected']} "
          f"attainment={row['slo_attainment']:.3f} "
          f"slo-goodput={row['goodput_slo_wps']:.3f} wf/s")
    print("config run events: " + "  ".join(
        f"{k}={v}" for k, v in sorted(by_type.items())))
    return row


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="wide-frontier config only, short timing windows")
    ap.add_argument("--profile", action="store_true",
                    help="emit per-phase planner timing breakdown")
    ap.add_argument("--serve", action="store_true",
                    help="run the Poisson multi-workflow serving smoke")
    ap.add_argument("--serve-slo", action="store_true",
                    help="run the overloaded-trace SLO control-plane "
                         "benchmark (gates on attainment/goodput gains "
                         "and warm-start/cold-solve parity)")
    ap.add_argument("--calibrate", action="store_true",
                    help="run the cost-model calibration gate (fit "
                         "round-trip, >=2x probe-error reduction vs "
                         "hand-set constants, fixed-profile parity); "
                         "writes CALIBRATION_profile.json")
    ap.add_argument("--chaos", action="store_true",
                    help="run the chaos fault-tolerance gate (100%% "
                         "completion under a seeded fault script, <=2x "
                         "makespan degradation, bit-identical replay, "
                         "empty-plan parity); writes BENCH_chaos.json")
    ap.add_argument("--scale", action="store_true",
                    help="run the 1k-workflow scale gate (hierarchical "
                         "pooled solve + batched admission probing on a "
                         "64-device cluster; 100%% completion, zero "
                         "invariant violations, mean per-event overhead "
                         "ceiling, single-pool/monolithic parity); "
                         "writes BENCH_scale.json")
    ap.add_argument("--classes", action="store_true",
                    help="run the multi-class priority gate (default-"
                         "class bit-parity, weighted platinum/batch "
                         "SLOs with aging and running-shard "
                         "preemption, journaled preemption crash "
                         "recovery); writes BENCH_classes.json")
    ap.add_argument("--gateway", action="store_true",
                    help="run the HTTP serving-gateway gate (100%% "
                         "completion under wall-clock Poisson HTTP "
                         "load with e2e P95, single-replica gateway "
                         "bit-identical to a direct Scheduler run, "
                         "routing disabled bit-identical on serving "
                         "and batch traces, routed cheaper than "
                         "fixed-family at quality >= floor); writes "
                         "BENCH_gateway.json")
    ap.add_argument("--recovery", action="store_true",
                    help="run the crash-recovery gate (journaled chaos "
                         "run killed at swept event indices, restored "
                         "from snapshot + journal replay; bit-identical "
                         "results and zero invariant violations "
                         "required); writes BENCH_recovery.json")
    ap.add_argument("--config", default=None, metavar="PATH",
                    help="run the overloaded serving trace from a "
                         "serialized SchedulerConfig JSON (e.g. the "
                         "SCHED_config.json artifact of a gated run) "
                         "and report its serving metrics")
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_sched.json"))
    args = ap.parse_args()

    if args.config:
        # a replay must not clobber the tracked full-gate trajectory:
        # unless --out was given explicitly, write the stub report to
        # its own file next to BENCH_sched.json
        out = Path(args.out)
        if args.out == ap.get_default("out"):
            out = out.parent / "BENCH_config_run.json"
        run_from_config_file(args.config, out)
        return

    if args.quick:
        grid = [WIDE]
        min_reps, min_seconds = 3, 0.1
    else:
        grid = [(w, d, h)
                for w in (8, 16, 32, 48)
                for d in (8, 16)
                for h in (2, 4)]
        if WIDE not in grid:
            grid.append(WIDE)
        min_reps, min_seconds = 5, 0.3

    rows = []
    for width, n_dev, horizon in grid:
        row = run_config(width, n_dev, horizon,
                         min_reps=min_reps, min_seconds=min_seconds)
        rows.append(row)
        print(f"width={width:3d} devices={n_dev:3d} horizon={horizon} | "
              f"fast {row['fast_ms']:7.2f} ms  slow {row['slow_ms']:7.2f} ms"
              f"  speedup {row['speedup']:5.1f}x  "
              f"identical={row['identical_placements']}")

    wide = next(r for r in rows
                if (r["frontier_width"], r["n_devices"], r["horizon"])
                == WIDE)

    delta = run_delta_config(
        *WIDE, n_events=120 if args.quick else 300,
        n_check=20 if args.quick else 60)
    print(f"delta rescore (32x16, H=4 rolling trace) | "
          f"full {delta['full_ms']:6.3f} ms  "
          f"delta {delta['delta_ms']:6.3f} ms  "
          f"speedup {delta['speedup']:4.1f}x  "
          f"identical={delta['identical']}")

    ok = (wide["speedup"] >= TARGET_SPEEDUP
          and all(r["identical_placements"] for r in rows)
          and delta["speedup"] >= DELTA_GUARD
          and delta["identical"])
    report = {
        "benchmark": "sched_bench",
        "unix_time": time.time(),
        "target_speedup": TARGET_SPEEDUP,
        "wide_frontier": wide,
        "configs": rows,
        "delta_rescore": delta,
        "pass": ok,
    }
    if args.profile:
        report["profile"] = run_profile(*WIDE)
        pm = report["profile"]["phase_ms"]
        print("profile: " + "  ".join(
            f"{k}={v:.1f}ms" for k, v in pm.items())
            + f"  other={report['profile']['other_ms']:.1f}ms"
            + f"  ({report['profile']['reps']} plans)")
    if args.serve:
        report["serving"] = run_serve(
            n_workflows=8 if args.quick else 12)
        for pol, row in report["serving"]["policies"].items():
            print(f"serve: {pol:10s} norm_ms={row['norm_ms']:.3f} "
                  f"norm_p95={row['norm_p95']:.3f} "
                  f"goodput={row['goodput_wps']:.2f} wf/s")
    if args.serve_slo:
        # fixed trace size: the preemption-engagement gate needs the
        # n=18 burst (the n=12 prefix never gets SLO-tight enough);
        # the controlled leg's SchedulerConfig is archived next to the
        # report so the gated run is reproducible via --config
        config_path = Path(args.out).parent / "SCHED_config.json"
        slo = run_serve_slo(config_out=config_path)
        report["scheduler_config"] = str(config_path)
        report["serving_slo"] = slo
        for mode, row in slo["policies"].items():
            print(f"serve-slo: {mode:14s} "
                  f"attainment={row['slo_attainment']:.3f} "
                  f"slo-goodput={row['goodput_slo_wps']:.3f} wf/s "
                  f"reject={row['rejection_rate']:.2f} "
                  f"preempt={row['preemptions']} "
                  f"p95={row['p95_latency']:.1f}s")
        print(f"serve-slo: warm-start/delta placements identical to "
              f"cold solve: {slo['parity_identical']}  ->  "
              f"{'PASS' if slo['pass'] else 'FAIL'}")
        ok = ok and slo["pass"]
        report["pass"] = ok
    if args.calibrate:
        # fixed trace size as in --serve-slo: the gate is defined on
        # the overloaded n=18 burst
        profile_path = Path(args.out).parent / "CALIBRATION_profile.json"
        cal = run_calibrate(profile_out=profile_path)
        report["calibration"] = cal
        print(f"calibrate: fit max rel err "
              f"{cal['fit_max_rel_err']:.4f} (tol {cal['fit_tol']}); "
              f"probe median abs err hand-set "
              f"{cal['probe_handset']['median_abs_err']:.2f}s vs "
              f"calibrated "
              f"{cal['probe_calibrated']['median_abs_err']:.2f}s  ->  "
              f"{cal['error_reduction']:.2f}x reduction "
              f"(target >= {cal['target_reduction']:.0f}x)")
        print(f"calibrate: fixed-profile placements bit-identical "
              f"across score paths: {cal['profile_parity']}  ->  "
              f"{'PASS' if cal['pass'] else 'FAIL'}  [{profile_path}]")
        ok = ok and cal["pass"]
        report["pass"] = ok
    if args.chaos:
        # fixed trace size as in --serve-slo: the chaos gate is
        # defined on the overloaded n=18 burst; the full chaos report
        # goes to its own artifact next to BENCH_sched.json
        chaos = run_chaos()
        chaos_path = Path(args.out).parent / "BENCH_chaos.json"
        chaos_path.write_text(json.dumps(chaos, indent=2) + "\n")
        report["chaos"] = chaos
        for label, row in chaos["runs"].items():
            print(f"chaos: {label:10s} "
                  f"completed={row['n_completed']}/{row['n_completed'] + row['n_failed']} "
                  f"horizon={row['horizon']:.1f}s "
                  f"downs={row['device_downs']} "
                  f"failures={row['shard_failures']} "
                  f"retries={row['retries']} "
                  f"stragglers={row['stragglers']} "
                  f"spec={row['speculations']}")
        print(f"chaos: degradation {chaos['degradation']:.2f}x "
              f"(<= 2x); replay identical: "
              f"{chaos['replay_identical']}; empty-plan parity: "
              f"{chaos['empty_plan_parity']}  ->  "
              f"{'PASS' if chaos['pass'] else 'FAIL'}  [{chaos_path}]")
        ok = ok and chaos["pass"]
        report["pass"] = ok
    if args.scale:
        # fixed gate size: the scale contract is defined at 1000
        # workflows on 64 devices; the full report goes to its own
        # artifact next to BENCH_sched.json
        scale = run_scale()
        scale_path = Path(args.out).parent / "BENCH_scale.json"
        scale_path.write_text(json.dumps(scale, indent=2) + "\n")
        report["scale"] = scale
        print(f"scale: {scale['n_completed']}/{scale['n_workflows']} "
              f"workflows on {scale['n_devices']} devices "
              f"(pools={scale['pools']}, burst={scale['burst']}) | "
              f"{scale['n_events']} events in {scale['wall_s']:.1f}s, "
              f"mean {scale['mean_event_ms']:.3f} ms/event "
              f"(ceiling {scale['ceiling_ms']:.1f}), "
              f"in-flight<= {scale['max_in_flight']}, "
              f"probes={scale['n_probes']}")
        print("scale: phase " + "  ".join(
            f"{k}={v:.1f}ms" for k, v in scale["phase_ms"].items())
            + f"  audits={scale['n_audits']} "
            f"({scale['audit_s']:.2f}s, excluded) "
            f"violations={len(scale['violations'])}")
        print(f"scale: single-pool hierarchical == monolithic: "
              f"{scale['single_pool_parity']}  ->  "
              f"{'PASS' if scale['pass'] else 'FAIL'}  [{scale_path}]")
        ok = ok and scale["pass"]
        report["pass"] = ok
    if args.classes:
        # fixed trace size as in --serve-slo: the class gates are
        # defined on the overloaded n=18 burst; the full report goes
        # to its own artifact next to BENCH_sched.json
        cls = run_classes()
        cls_path = Path(args.out).parent / "BENCH_classes.json"
        cls_path.write_text(json.dumps(cls, indent=2) + "\n")
        report["classes"] = cls
        print(f"classes: default-class parity (events/placements/"
              f"stats bit-identical): {cls['default_class_parity']}")
        for klass, row in cls["per_class"].items():
            print(f"classes: {klass:9s} "
                  f"attainment={row['slo_attainment']:.3f} "
                  f"completed={row['n_completed']}/{row['n_offered']} "
                  f"max_wait={row['max_wait']:.1f}s "
                  f"p95={row['p95_latency']:.1f}s")
        print(f"classes: single-class attainment "
              f"{cls['single_class']['slo_attainment']:.3f}; "
              f"batch wait bound {cls['batch_wait_bound_s']:.1f}s "
              f"(starvation bound {cls['starvation_bound_s']:.1f}s); "
              f"shard preemptions {cls['shard_preemptions']}")
        rec = cls["recovery"]
        for row in rec["kill_points"]:
            print(f"classes: kill@{row['kill_event_index']:5d} "
                  f"past-preempt={'y' if row['past_first_preemption'] else 'n'} "
                  f"audit={len(row['audit_restored']) + len(row['audit_drained'])} "
                  f"identical={row['identical']}")
        print(f"classes: {len(rec['kill_points'])} journaled kill "
              f"points, {len(rec['preemption_event_indices'])} "
              f"preemption events in baseline  ->  "
              f"{'PASS' if cls['pass'] else 'FAIL'}  [{cls_path}]")
        ok = ok and cls["pass"]
        report["pass"] = ok
    if args.gateway:
        # fixed trace sizes: parity is defined on the overloaded n=18
        # burst and the 32x16 H=4 wide frontier; the full report goes
        # to its own artifact next to BENCH_sched.json
        gwy = run_gateway()
        gwy_path = Path(args.out).parent / "BENCH_gateway.json"
        gwy_path.write_text(json.dumps(gwy, indent=2) + "\n")
        report["gateway"] = gwy
        par, load = gwy["parity"], gwy["load"]
        print(f"gateway: single-replica parity events="
              f"{par['events_identical']} placements="
              f"{par['placements_identical']} fingerprint="
              f"{par['fingerprint_identical']} "
              f"({par['n_completed']}/{par['n_offered']} workflows)")
        print(f"gateway: HTTP load {load['n_completed']}/"
              f"{load['n_offered']} completed "
              f"(completion={load['completion']:.2f}) "
              f"e2e p95={load['p95_e2e_s']:.2f}s "
              f"submit mean={load['submit_mean_ms']:.1f}ms "
              f"max={load['submit_max_ms']:.1f}ms "
              f"wall={load['wall_s']:.1f}s")
        rv = gwy["routed_vs_fixed"]
        print(f"gateway: routing-noop serving="
              f"{gwy['routing_noop']['serving_identical']} batch="
              f"{gwy['routing_noop']['batch_identical']}; routed "
              f"cost {rv['routed_cost_device_s']:.1f} vs fixed "
              f"{rv['fixed_cost_device_s']:.1f} device-s "
              f"(ratio {rv['cost_ratio']:.2f}), "
              f"{rv['n_routed']}/{rv['n_runs']} runs routed, "
              f"min quality {rv['min_quality']:.2f} "
              f"(floor {rv['quality_floor']:.2f})  ->  "
              f"{'PASS' if gwy['pass'] else 'FAIL'}  [{gwy_path}]")
        ok = ok and gwy["pass"]
        report["pass"] = ok
    if args.recovery:
        # fixed trace size as in --chaos: the recovery gate is defined
        # on the overloaded n=18 chaos burst; the full report goes to
        # its own artifact next to BENCH_sched.json
        rec = run_recovery()
        rec_path = Path(args.out).parent / "BENCH_recovery.json"
        rec_path.write_text(json.dumps(rec, indent=2) + "\n")
        report["recovery"] = rec
        for row in rec["kill_points"]:
            print(f"recovery: kill@{row['kill_event_index']:5d} "
                  f"snap@{row['snapshot_event_index']:5d} "
                  f"replayed={row['replayed_tail']:3d} "
                  f"torn={'y' if row['torn_tail_injected'] else 'n'} "
                  f"audit={len(row['audit_restored']) + len(row['audit_drained'])} "
                  f"identical={row['identical']}")
        print(f"recovery: {len(rec['kill_points'])} kill points over "
              f"{rec['baseline_events']} baseline events, all "
              f"bit-identical: {all(r['identical'] for r in rec['kill_points'])}"
              f"  ->  {'PASS' if rec['pass'] else 'FAIL'}  [{rec_path}]")
        ok = ok and rec["pass"]
        report["pass"] = ok
    out = Path(args.out)
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"\nwide frontier (32x16, H=4): {wide['speedup']:.1f}x "
          f"(target >= {TARGET_SPEEDUP:.0f}x); "
          f"delta rescore {delta['speedup']:.1f}x "
          f"(target >= {DELTA_TARGET:.0f}x, guard >= "
          f"{DELTA_GUARD:.0f}x)  ->  "
          f"{'PASS' if ok else 'FAIL'}  [{out}]")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
