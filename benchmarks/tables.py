"""One benchmark per paper table.  Each function returns a list of CSV
rows (name, us_per_call, derived) where ``derived`` carries the metric
the table reports, and prints a human-readable summary."""
from __future__ import annotations

import statistics
import time

from repro.core.scoring import ScoreParams
from repro.workflowbench.families import FAMILIES
from repro.workflowbench.lift import build_benchmark, build_instance
from repro.workflowbench.metrics import geomean
from repro.workflowbench.runner import (run_one, run_suite,
                                        rows_to_tables, export_csv)
from repro.workflowbench.suites import (RATIOS, conflict_suite,
                                        prefix_suite)

POLICIES = ["RoundRobin", "FATE", "KVFlow", "Helix", "Halo", "HEFT"]
PAPER_T1 = {"FATE": 0.675, "KVFlow": 0.748, "Helix": 0.741,
            "Halo": 0.902, "HEFT": 0.791, "RoundRobin": 1.0}


def _suite_slice(n_per_family: int = 3, nq: int = 16):
    return [build_instance(fam, i, nq)
            for fam in FAMILIES for i in range(n_per_family)]


def table1_main(full: bool = True) -> list[str]:
    """Table 1: overall workflow-DAG benchmark."""
    wfs = build_benchmark() if full else _suite_slice()
    t0 = time.perf_counter()
    rows = run_suite(wfs, POLICIES, csv_name="table1_main.csv")
    dt_us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    tab = rows_to_tables(rows)
    out = []
    print("\n# Table 1 — workflow-DAG benchmark (paper values in []):")
    print(f"{'policy':12s} {'normMS':>7s} {'normP95':>8s} {'xdev':>6s} "
          f"{'cache':>6s} {'cont':>6s}")
    for pol in ["FATE", "KVFlow", "Helix", "Halo", "HEFT", "RoundRobin"]:
        t = tab[pol]
        print(f"{pol:12s} {t['norm_ms']:7.3f} {t['norm_p95']:8.3f} "
              f"{t['xdev_edge']:6.3f} {t['cache_score']:6.3f} "
              f"{t['model_cont']:6.3f}   [paper MS {PAPER_T1[pol]:.3f}]")
        out.append(f"table1/{pol}/norm_ms,{dt_us:.1f},{t['norm_ms']:.4f}")
        out.append(
            f"table1/{pol}/norm_p95,{dt_us:.1f},{t['norm_p95']:.4f}")
    return out


def table2_prefix() -> list[str]:
    """Table 2: controlled prefix reuse, normalized by Halo at ratio 0."""
    out = []
    halo0 = {w.wid.rsplit("-", 1)[1]: run_one(
        w, "Halo", _cluster()).makespan for w in prefix_suite(0.0)}
    print("\n# Table 2 — controlled prefix reuse (vs Halo@0):")
    print(f"{'policy':8s} " + " ".join(f"{r:>7.2f}" for r in RATIOS))
    for pol in ["Halo", "KVFlow", "FATE"]:
        vals = []
        for r in RATIOS:
            ms = []
            for w in prefix_suite(r):
                idx = w.wid.rsplit("-", 1)[1]
                res = run_one(w, pol, _cluster())
                ms.append(res.makespan / halo0[idx])
            vals.append(geomean(ms))
        print(f"{pol:8s} " + " ".join(f"{v:>7.3f}" for v in vals))
        for r, v in zip(RATIOS, vals):
            out.append(f"table2/{pol}/ratio{r},0,{v:.4f}")
    return out


def table3_ablation() -> list[str]:
    """Table 3: component ablations on the lifted workflow DAGs
    (full manifest — slice-level ablations are noise-dominated)."""
    wfs = build_benchmark()
    variants = {
        "Full FATE": ScoreParams(),
        "w/o future planning": ScoreParams(enable_future=False),
        "w/o locality terms": ScoreParams(enable_locality=False),
        "w/o same-model bonus": ScoreParams(enable_same_model=False),
        "w/o prefix terms": ScoreParams(enable_prefix=False),
        "w/o shard parallelism": ScoreParams(enable_shard=False),
    }
    out = []
    base_ms = None
    print("\n# Table 3 — ablations:")
    for name, sp in variants.items():
        rows = run_suite(wfs, ["RoundRobin", "FATE"], score_params=sp)
        v = rows_to_tables(rows)["FATE"]["norm_ms"]
        if base_ms is None:
            base_ms = v
        deg = (v / base_ms - 1) * 100
        print(f"{name:24s} normMS={v:.3f}  deg={deg:+.2f}%")
        out.append(f"table3/{name.replace(' ', '_')},0,{v:.4f}")
    return out


def table8_families() -> list[str]:
    """Table 8: per-family breakdown (FATE vs best non-FATE)."""
    out = []
    print("\n# Table 8 — per-family normalized makespan:")
    for fam, (_, count) in FAMILIES.items():
        wfs = [build_instance(fam, i, 16) for i in range(count)]
        rows = run_suite(wfs, POLICIES)
        tab = rows_to_tables(rows)
        fate = tab["FATE"]["norm_ms"]
        best_pol, best = min(
            ((p, tab[p]["norm_ms"]) for p in POLICIES
             if p not in ("FATE", "RoundRobin")), key=lambda kv: kv[1])
        print(f"{fam:26s} DAGs={count:3d} FATE={fate:.3f} "
              f"best-non-FATE={best:.3f} ({best_pol})")
        out.append(f"table8/{fam}/FATE,0,{fate:.4f}")
        out.append(f"table8/{fam}/best_other,0,{best:.4f}")
    return out


def table9_conflict() -> list[str]:
    """Table 9: conflict stress test, normalized by Halo per ratio."""
    out = []
    print("\n# Table 9 — controlled conflict stress test (vs Halo):")
    print(f"{'policy':8s} " + " ".join(f"{r:>7.2f}" for r in RATIOS))
    halo = {}
    for r in RATIOS:
        for w in conflict_suite(r):
            halo[w.wid] = run_one(w, "Halo", _cluster()).makespan
    for pol in ["Halo", "KVFlow", "FATE"]:
        vals = []
        for r in RATIOS:
            ms = [run_one(w, pol, _cluster()).makespan / halo[w.wid]
                  for w in conflict_suite(r)]
            vals.append(geomean(ms))
        print(f"{pol:8s} " + " ".join(f"{v:>7.3f}" for v in vals))
        for r, v in zip(RATIOS, vals):
            out.append(f"table9/{pol}/ratio{r},0,{v:.4f}")
    return out


def table10_sensitivity() -> list[str]:
    """Table 10: horizon + weight-scale sensitivity on 30 DAGs."""
    wfs = _suite_slice(3)
    settings = {
        "H=0 (no future planning)": ScoreParams(enable_future=False),
        "H=1": ScoreParams(horizon=1),
        "H=2": ScoreParams(horizon=2),
        "H=3": ScoreParams(horizon=3),
        "H=4 (default)": ScoreParams(horizon=4),
        "state x0.5": ScoreParams().scaled(state_mul=0.5),
        "state x1.5": ScoreParams().scaled(state_mul=1.5),
        "locality x0.5": ScoreParams().scaled(locality_mul=0.5),
        "locality x1.5": ScoreParams().scaled(locality_mul=1.5),
        "prefix x0.5": ScoreParams().scaled(prefix_mul=0.5),
        "prefix x1.5": ScoreParams().scaled(prefix_mul=1.5),
    }
    out = []
    ref = None
    print("\n# Table 10 — hyperparameter sensitivity:")
    for name, sp in settings.items():
        rows = run_suite(wfs, ["RoundRobin", "FATE"], score_params=sp)
        v = rows_to_tables(rows)["FATE"]["norm_ms"]
        if "default" in name:
            ref = v
        print(f"{name:28s} normMS={v:.3f}")
        out.append(f"table10/{name.split()[0]},0,{v:.4f}")
    if ref:
        spread = max(float(r.split(',')[-1]) for r in out) - \
            min(float(r.split(',')[-1]) for r in out)
        print(f"spread across settings: {spread:.3f}")
    return out


def table11_perturbation() -> list[str]:
    """Table 11: proxy-cost perturbation (switch/transfer/prefix ×0.5/×2)."""
    from repro.core.costs import CostParams
    wfs = _suite_slice(3)
    conds = {
        "default": CostParams(),
        "switch x0.5": CostParams(switch_scale=0.5),
        "switch x2.0": CostParams(switch_scale=2.0),
        "transfer x0.5": CostParams(transfer_scale=0.5),
        "transfer x2.0": CostParams(transfer_scale=2.0),
        "prefix x0.5": CostParams(prefix_scale=0.5),
        "prefix x2.0": CostParams(prefix_scale=2.0),
    }
    out = []
    print("\n# Table 11 — proxy-cost perturbation (normMS vs RR):")
    print(f"{'condition':16s} {'FATE':>7s} {'KVFlow':>7s} {'Helix':>7s}")
    for name, cp in conds.items():
        rows = run_suite(wfs, ["RoundRobin", "FATE", "KVFlow", "Helix"],
                         cost_params=cp)
        tab = rows_to_tables(rows)
        f, k, h = (tab[p]["norm_ms"] for p in ("FATE", "KVFlow", "Helix"))
        print(f"{name:16s} {f:7.3f} {k:7.3f} {h:7.3f}")
        out.append(f"table11/{name.replace(' ', '_')}/FATE,0,{f:.4f}")
    return out


def table12_solver() -> list[str]:
    """Table 12: CP-SAT frontier-solver overhead across the benchmark."""
    from repro.core.executor import WorkflowExecutor, fresh_state
    from repro.core.policies import make_policy
    wfs = _suite_slice(2)
    times, nodes = [], []
    optimal = total = 0
    for wf in wfs:
        pol = make_policy("FATE")
        WorkflowExecutor(fresh_state(_cluster())).run(wf, pol)
        for rec in pol.solve_log:
            times.append(rec.wall_time)
            nodes.append(rec.nodes)
            total += 1
            optimal += rec.status == "OPTIMAL"
    times.sort()
    mean = sum(times) / len(times)
    med = times[len(times) // 2]
    p95 = times[int(0.95 * (len(times) - 1))]
    mx = times[-1]
    print("\n# Table 12 — frontier-solver overhead:")
    print(f"solves={total} optimal={optimal} mean={mean*1e3:.2f}ms "
          f"median={med*1e3:.2f}ms p95={p95*1e3:.2f}ms max={mx*1e3:.2f}ms")
    assert optimal == total
    return [
        f"table12/solves,{mean*1e6:.1f},{total}",
        f"table12/p95_ms,{p95*1e3:.3f},{p95*1e3:.3f}",
        f"table12/max_ms,{mx*1e3:.3f},{mx*1e3:.3f}",
        f"table12/all_optimal,0,{int(optimal == total)}",
    ]


def fig2_ecdf() -> list[str]:
    """Figure 2: ECDF of per-workflow normalized makespan."""
    wfs = _suite_slice(3)
    rows = run_suite(wfs, POLICIES)
    per = {}
    base = {r.wid: r.makespan for r in rows if r.policy == "RoundRobin"}
    for r in rows:
        if r.policy == "RoundRobin":
            continue
        per.setdefault(r.policy, []).append(r.makespan / base[r.wid])
    out = []
    print("\n# Figure 2 — ECDF quantiles of per-workflow normMS:")
    for pol, vals in per.items():
        vals.sort()
        qs = [vals[int(q * (len(vals) - 1))] for q in (0.25, 0.5, 0.75)]
        print(f"{pol:10s} q25={qs[0]:.3f} q50={qs[1]:.3f} q75={qs[2]:.3f}")
        out.append(f"fig2/{pol}/median,0,{qs[1]:.4f}")
    return out


def _cluster():
    from repro.core.devices import homogeneous_cluster
    return homogeneous_cluster(8)


def _csv_note(out, t0):
    pass
