"""Kernel micro-benchmarks (CPU wall time is NOT the metric — these run
in interpret mode; the derived column reports validated max-abs error vs
the pure-jnp oracle, plus analytic FLOPs of the TPU-target shape)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels import ref as R


def _timed(fn, *args, reps=2, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / reps * 1e6


def run() -> list[str]:
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    rows = []

    b, s, h, kv, d = 1, 512, 8, 4, 64
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, s, kv, d), jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, s, kv, d), jnp.bfloat16)
    out, us = _timed(ops.flash_attention, q, k, v, interpret=True)
    err = float(jnp.max(jnp.abs(
        out.astype(jnp.float32)
        - R.flash_attention_ref(q, k, v).astype(jnp.float32))))
    flops = 4 * b * h * s * s * d
    rows.append(f"kernel/flash_attention,{us:.1f},err={err:.1e};"
                f"flops={flops}")

    q1 = jax.random.normal(ks[0], (4, 1, h, d), jnp.bfloat16)
    kc = jax.random.normal(ks[1], (4, 2048, kv, d), jnp.bfloat16)
    vc = jax.random.normal(ks[2], (4, 2048, kv, d), jnp.bfloat16)
    out, us = _timed(ops.decode_attention, q1, kc, vc, jnp.int32(2048),
                     interpret=True)
    err = float(jnp.max(jnp.abs(
        out.astype(jnp.float32)
        - R.decode_attention_ref(q1, kc, vc, 2048).astype(jnp.float32))))
    rows.append(f"kernel/decode_attention,{us:.1f},err={err:.1e}")

    x = jax.random.normal(ks[3], (8, 128, 256), jnp.bfloat16)
    w = jax.random.normal(ks[4], (8, 256, 512), jnp.bfloat16)
    out, us = _timed(ops.moe_gemm, x, w, interpret=True)
    ref = R.moe_gemm_ref(x, w)
    rel = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32)))
                / jnp.max(jnp.abs(ref.astype(jnp.float32))))
    rows.append(f"kernel/moe_gemm,{us:.1f},relerr={rel:.1e}")

    bsz, s2, hh, p, n = 1, 256, 4, 32, 16
    xh = jax.random.normal(ks[0], (bsz, s2, hh, p))
    bb = jax.random.normal(ks[1], (bsz, s2, n))
    cc = jax.random.normal(ks[2], (bsz, s2, n))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (bsz, s2, hh)))
    (y, fin), us = _timed(ops.mamba2_scan, xh, bb, cc, dt,
                          jnp.zeros(hh), chunk=64, interpret=True)
    yr, _ = R.mamba2_scan_ref(xh, bb, cc, dt, jnp.zeros(hh))
    rows.append(f"kernel/mamba2_scan,{us:.1f},"
                f"err={float(jnp.max(jnp.abs(y - yr))):.1e}")

    r = jax.random.normal(ks[0], (1, 128, 2, 32)) * 0.5
    kk = jax.random.normal(ks[1], (1, 128, 2, 32)) * 0.5
    vv = jax.random.normal(ks[2], (1, 128, 2, 32))
    w6 = jax.nn.sigmoid(jax.random.normal(ks[3], (1, 128, 2, 32)))
    bonus = jax.random.normal(ks[4], (2, 32)) * 0.1
    (out, fin), us = _timed(ops.rwkv6_scan, r, kk, vv, w6, bonus,
                            chunk=32, interpret=True)
    outr, _ = R.rwkv6_scan_ref(r, kk, vv, w6, bonus)
    rows.append(f"kernel/rwkv6_scan,{us:.1f},"
                f"err={float(jnp.max(jnp.abs(out - outr))):.1e}")
    for row in rows:
        print(row)
    return rows
