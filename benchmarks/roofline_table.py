"""Generate results/roofline_table.md — the full §Roofline per-cell
table (baseline vs optimized) from the dry-run artifacts."""
from __future__ import annotations

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def load(d: Path) -> dict:
    out = {}
    for f in sorted(d.glob("*__single.json")):
        r = json.loads(f.read_text())
        if "error" not in r:
            out[(r["arch"], r["shape"])] = r
    return out


def main() -> None:
    base = load(ROOT / "results" / "dryrun_baseline")
    opt = load(ROOT / "results" / "dryrun")
    lines = [
        "# Roofline table — single-pod (16,16), 256 chips, per chip",
        "",
        "Terms in seconds (v5e: 197 TF bf16, 819 GB/s HBM, 50 GB/s "
        "link); `useful` = MODEL_FLOPS / HLO-dot-FLOPs; baseline = "
        "paper-faithful, opt = after §Perf iterations.",
        "",
        "| arch / shape | comp (base→opt) | mem (base→opt) | "
        "coll (base→opt) | dominant | useful (base→opt) | bound speedup |",
        "|---|---|---|---|---|---|---|",
    ]
    for key in sorted(set(base) | set(opt)):
        b, o = base.get(key), opt.get(key)
        if b is None or o is None:
            continue
        sp = (b["roofline_bound_s"] / o["roofline_bound_s"]
              if o["roofline_bound_s"] else float("nan"))
        lines.append(
            f"| {key[0]}/{key[1]} "
            f"| {b['compute_s']:.3f}→{o['compute_s']:.3f} "
            f"| {b['memory_s']:.2f}→{o['memory_s']:.2f} "
            f"| {b['collective_s']:.2f}→{o['collective_s']:.2f} "
            f"| {o['dominant'].replace('_s','')} "
            f"| {b['useful_flop_ratio']:.3f}→{o['useful_flop_ratio']:.3f} "
            f"| {sp:.2f}× |")
    # multi-pod pass/fail summary
    multi = sorted((ROOT / "results" / "dryrun").glob("*__multi.json"))
    ok = sum(1 for f in multi
             if "error" not in json.loads(f.read_text()))
    lines += ["", f"Multi-pod (2,16,16) compiles: {ok}/{len(multi)} OK."]
    out_path = ROOT / "results" / "roofline_table.md"
    out_path.write_text("\n".join(lines) + "\n")
    print("\n".join(lines))
    print(f"\nwritten to {out_path}")


if __name__ == "__main__":
    main()
