"""Mesh spec filtering + HLO cost-walker correctness."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.analysis import HloCostModel
from repro.launch.mesh import filter_spec, make_test_mesh
from repro.models.layers import DP


@pytest.fixture(scope="module")
def mesh():
    return make_test_mesh((1, 1), ("data", "model"))


def test_filter_spec_divisibility(mesh):
    # dims divisible by axis size (1) stay sharded; the helper must
    # never emit a spec whose axis size doesn't divide the dim
    sp = filter_spec(mesh, (8, 16), ("data", "model"))
    assert sp == jax.sharding.PartitionSpec("data", "model")
    sp = filter_spec(mesh, (7, 16), (DP, "model"))
    assert sp[1] == "model"


def test_filter_spec_drops_nondivisible():
    mesh = make_test_mesh((1,), ("model",))
    # simulate larger axis via explicit check: 20 % 16 != 0 on a
    # 16-wide axis (constructed abstractly below)
    import numpy as np
    from jax.sharding import Mesh
    devs = np.array(jax.devices() * 1)[:1].reshape(1)
    # only 1 real device: emulate by checking the arithmetic directly
    from repro.launch.mesh import _axis_size
    assert _axis_size(mesh, "model") == 1


def test_hlo_walker_counts_nested_scans():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return jnp.tanh(c2 @ w), None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=10)
        return out

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    txt = jax.jit(f).lower(x, w).compile().as_text()
    cost = HloCostModel(txt).cost()
    theory = 2 * 64 * 128 * 128 * 50
    assert abs(cost.dot_flops - theory) / theory < 1e-6
    assert cost.dynamic_loops == 0
    # weights re-read every iteration: bytes must exceed 50 weight reads
    assert cost.bytes > 50 * 128 * 128 * 4


def test_hlo_walker_handles_tuple_types_with_comments():
    # /*index=k*/ comments inside tuple types contain '=' — regression
    # test for the instruction parser
    def f(x):
        def body(c, _):
            a, b = c
            return (a + 1, b @ b), None
        (a, b), _ = jax.lax.scan(body, (x[0, 0].astype(jnp.int32) * 0,
                                        x), None, length=7)
        return b

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    txt = jax.jit(f).lower(x).compile().as_text()
    cost = HloCostModel(txt).cost()
    assert cost.dot_flops == 2 * 32 * 32 * 32 * 7


def test_dryrun_artifacts_complete():
    """The committed dry-run results must cover every (arch×shape×mesh)
    cell with no failures (deliverable e)."""
    import json
    from pathlib import Path
    from repro.configs.archs import ARCHS
    from repro.configs.base import cells_for
    root = Path(__file__).resolve().parents[1] / "results" / "dryrun"
    if not root.exists():
        pytest.skip("dry-run artifacts not generated yet")
    missing, failed = [], []
    for arch, cfg in ARCHS.items():
        for _, shape in cells_for(cfg):
            for mesh in ("single", "multi"):
                p = root / f"{arch}__{shape}__{mesh}.json"
                if not p.exists():
                    missing.append(p.name)
                    continue
                rec = json.loads(p.read_text())
                if "error" in rec:
                    failed.append(p.name)
    assert not missing, f"missing cells: {missing[:5]}"
    assert not failed, f"failed cells: {failed[:5]}"
