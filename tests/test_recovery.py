"""Durable control plane: event serialization, write-ahead journal,
snapshot/restore, and deterministic crash recovery.

The contract under test, in order of importance:

* every :class:`~repro.core.scheduler.SchedulerEvent` subclass
  round-trips through ``to_dict``/``from_dict`` (via JSON) exactly;
  unknown types and foreign schema versions are REJECTED, extra keys
  (the journal's ``"i"`` tag) are ignored;
* the :class:`~repro.core.journal.EventJournal` is a contiguous prefix
  of the event stream: gap appends raise, rotation preserves read
  order, a torn final line (mid-append crash) is detected and
  truncated while corruption anywhere else raises
  :class:`~repro.core.journal.JournalError`;
* ``Scheduler.snapshot()`` + ``Scheduler.restore()`` resume a mid-run
  scheduler whose drained result is BIT-IDENTICAL to the uninterrupted
  run — with or without a journal tail to replay — and
  ``audit_invariants`` stays clean throughout;
* lifecycle: submissions are refused after ``drain()`` and on restored
  schedulers; snapshots are refused for dependency-injected runs.
"""
import dataclasses
import json

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # offline container
    from _fallback_hypothesis import given, settings, strategies as st

from repro.core.admission import SLOConfig
from repro.core.devices import homogeneous_cluster
from repro.core.journal import EventJournal, JournalError
from repro.core.scheduler import (EVENT_REGISTRY, EVENT_SCHEMA_VERSION,
                                  EVENT_TYPES, CompletionEvent,
                                  EventLog, IssueEvent, Scheduler,
                                  SchedulerConfig, SchedulerEvent,
                                  audit_invariants)
from repro.core.workflow import DEFAULT_PROFILES
from repro.workflowbench.suites import (chaos_fault_plan,
                                        overloaded_serving_trace)


# ---------------------------------------------------------------------------
# event serialization
# ---------------------------------------------------------------------------

_FIELD_VALUES = {
    "float": st.floats(min_value=0.0, max_value=1e5),
    "int": st.integers(min_value=0, max_value=64),
    "str": st.sampled_from(["w0", "w1", "stage-2", "crash", ""]),
    "bool": st.booleans(),
    "tuple": st.lists(st.integers(min_value=0, max_value=15),
                      min_size=0, max_size=4),
}


def _field_strategy(annotation: str):
    if annotation.startswith("Optional["):
        return st.one_of(st.none(), _field_strategy(annotation[9:-1]))
    return _FIELD_VALUES[annotation]


def _draw_event(data, cls):
    kwargs = {}
    for f in dataclasses.fields(cls):
        v = data.draw(_field_strategy(f.type), label=f.name)
        kwargs[f.name] = tuple(v) if isinstance(v, list) else v
    return cls(**kwargs)


@settings(max_examples=30)
@given(st.data())
def test_every_event_type_round_trips_through_json(data):
    """Property: for EVERY registered event type, random field values
    survive to_dict -> json -> from_dict exactly (including tuple
    coercion and None optionals)."""
    for cls in EVENT_TYPES:
        ev = _draw_event(data, cls)
        doc = json.loads(json.dumps(ev.to_dict(), sort_keys=True))
        back = SchedulerEvent.from_dict(doc)
        assert type(back) is cls
        assert back == ev


def test_registry_covers_every_event_type():
    assert set(EVENT_REGISTRY.values()) == set(EVENT_TYPES)
    assert all(EVENT_REGISTRY[c.__name__] is c for c in EVENT_TYPES)


def test_from_dict_rejects_unknown_type():
    doc = {"event_version": EVENT_SCHEMA_VERSION,
           "type": "NotARealEvent", "t": 0.0}
    with pytest.raises(ValueError, match="unknown event type"):
        SchedulerEvent.from_dict(doc)


def test_from_dict_rejects_future_schema_version():
    doc = CompletionEvent(t=1.0, wid="w", sid="s").to_dict()
    doc["event_version"] = EVENT_SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="schema version"):
        SchedulerEvent.from_dict(doc)
    doc.pop("event_version")            # missing version is also foreign
    with pytest.raises(ValueError, match="schema version"):
        SchedulerEvent.from_dict(doc)


def test_from_dict_ignores_extra_keys():
    doc = IssueEvent(t=2.0, wid="w", sid="s", devices=(0, 1),
                     start=2.0, finish=3.5).to_dict()
    doc["i"] = 17                        # the journal's index tag
    doc["unknown_future_field"] = "x"
    ev = SchedulerEvent.from_dict(doc)
    assert ev == IssueEvent(t=2.0, wid="w", sid="s", devices=(0, 1),
                            start=2.0, finish=3.5)
    assert ev.devices == (0, 1)          # list -> tuple coercion


# ---------------------------------------------------------------------------
# event journal
# ---------------------------------------------------------------------------

def _events(n, start_t=0.0):
    return [CompletionEvent(t=start_t + i, wid=f"w{i}", sid="s")
            for i in range(n)]


def test_journal_append_read_round_trip(tmp_path):
    j = EventJournal(tmp_path)
    evs = _events(5)
    j.append_batch(evs[:3], 0)
    j.append_batch(evs[3:], 3)
    assert j.next_index == 5
    got = j.entries()
    assert [i for i, _ in got] == [0, 1, 2, 3, 4]
    assert [e for _, e in got] == evs
    assert [e for _, e in j.entries(3)] == evs[3:]


def test_journal_rejects_gap_appends(tmp_path):
    j = EventJournal(tmp_path)
    j.append_batch(_events(2), 0)
    with pytest.raises(JournalError, match="non-contiguous"):
        j.append_batch(_events(1), 5)
    with pytest.raises(JournalError, match="non-contiguous"):
        j.append_batch(_events(1), 1)    # replays are refused too


def test_journal_rotation_preserves_order(tmp_path):
    j = EventJournal(tmp_path, rotate_bytes=200)
    for k in range(10):
        j.append_batch(_events(1, start_t=float(k)), k)
    segs = sorted(tmp_path.glob("events-*.jsonl"))
    assert len(segs) > 1                 # rotation actually engaged
    j2 = EventJournal(tmp_path)          # cold reopen walks all segments
    assert j2.next_index == 10
    assert [i for i, _ in j2.entries()] == list(range(10))


def test_journal_torn_tail_is_truncated_on_reopen(tmp_path):
    j = EventJournal(tmp_path)
    j.append_batch(_events(4), 0)
    seg = sorted(tmp_path.glob("events-*.jsonl"))[-1]
    with seg.open("a") as fh:            # simulated mid-append crash
        fh.write('{"event_version": 1, "type": "Comple')
    j2 = EventJournal(tmp_path)
    assert j2.recovered_torn_tail
    assert j2.next_index == 4            # the 4 good events survive
    assert len(j2.entries()) == 4
    j2.append_batch(_events(1), 4)       # appends resume cleanly
    assert not EventJournal(tmp_path).recovered_torn_tail


def test_journal_mid_file_corruption_raises(tmp_path):
    j = EventJournal(tmp_path, rotate_bytes=200)
    for k in range(10):
        j.append_batch(_events(1, start_t=float(k)), k)
    first = sorted(tmp_path.glob("events-*.jsonl"))[0]
    lines = first.read_text().splitlines()
    lines[0] = '{"garbage": true}'       # NOT a torn tail: mid-journal
    first.write_text("\n".join(lines) + "\n")
    with pytest.raises(JournalError, match="corrupt journal entry"):
        EventJournal(tmp_path)


def test_snapshot_store_prunes_and_returns_latest(tmp_path):
    j = EventJournal(tmp_path)
    assert j.latest_snapshot() is None
    for n in (3, 7, 12):
        j.write_snapshot({"snapshot_version": 1, "mark": n,
                          "events": {"n_total": n}})
    snaps = sorted(tmp_path.glob("snapshot-*.json"))
    assert len(snaps) == 2               # keep=2 pruned the oldest
    assert j.latest_snapshot()["mark"] == 12


# ---------------------------------------------------------------------------
# EventLog.since hardening
# ---------------------------------------------------------------------------

def test_event_log_since_rejects_out_of_range_cursors():
    log = EventLog(maxlen=4)
    for ev in _events(6):
        log.append(ev)
    assert log.n_total == 6 and log.n_dropped == 2
    assert log.since(6) == []            # exactly-at-the-end is legal
    assert len(log.since(4)) == 2
    assert log.since(0) == list(log)     # evicted prefix: silent window
    with pytest.raises(ValueError, match="must be >= 0"):
        log.since(-1)
    with pytest.raises(ValueError, match="past the end"):
        log.since(7)


# ---------------------------------------------------------------------------
# snapshot / restore / lifecycle
# ---------------------------------------------------------------------------

def _trace():
    return overloaded_serving_trace(n_workflows=8, rate=14.0, seed=0,
                                    num_queries=4)


def _config():
    return SchedulerConfig(policy="FATE", slo=SLOConfig(),
                           faults=chaos_fault_plan(0))


def _fingerprint(res, sched):
    return {
        "stats": {w: (s.arrival, s.finish, tuple(s.query_completion),
                      s.deadline) for w, s in res.stats.items()},
        "rejected": tuple(res.rejected),
        "failed": tuple(res.failed),
        "horizon": res.horizon,
        "counters": (res.replans, res.preemptions, res.deferrals,
                     res.max_in_flight, res.device_downs,
                     res.shard_failures, res.retries, res.stragglers,
                     res.speculations),
        "n_events": sched.events.n_total,
    }


def _baseline():
    sched = Scheduler(homogeneous_cluster(4), _config())
    for t, wf in _trace():
        sched.submit(wf, at=t)
    res = sched.drain()
    return _fingerprint(res, sched), sched


def _run_until(sched, n_events):
    while sched.events.n_total < n_events and sched.step():
        pass


def test_submit_after_drain_raises():
    _, sched = _baseline()
    t, wf = _trace()[0]
    with pytest.raises(RuntimeError, match="lifecycle"):
        sched.submit(wf, at=t)


def test_snapshot_refused_for_injected_dependencies():
    sched = Scheduler(homogeneous_cluster(4), _config(),
                      world_profiles=dict(DEFAULT_PROFILES))
    with pytest.raises(ValueError, match="injected"):
        sched.snapshot()


def test_snapshot_restore_without_journal_is_bit_identical():
    base_fp, _ = _baseline()
    sched = Scheduler(homogeneous_cluster(4), _config())
    for t, wf in _trace():
        sched.submit(wf, at=t)
    _run_until(sched, base_fp["n_events"] // 2)
    snap = json.loads(json.dumps(sched.snapshot()))   # force plain JSON
    restored = Scheduler.restore(snap)
    assert audit_invariants(restored) == []
    res = restored.drain()
    assert audit_invariants(restored) == []
    assert _fingerprint(res, restored) == base_fp


def test_snapshot_document_round_trips_through_restore():
    sched = Scheduler(homogeneous_cluster(4), _config())
    for t, wf in _trace():
        sched.submit(wf, at=t)
    _run_until(sched, 120)
    snap = sched.snapshot()
    restored = Scheduler.restore(json.loads(json.dumps(snap)))
    again = restored.snapshot()
    snap.pop("lifecycle"), again.pop("lifecycle")
    assert json.loads(json.dumps(again)) == json.loads(json.dumps(snap))


def test_restore_rejects_foreign_snapshot_version():
    sched = Scheduler(homogeneous_cluster(4), _config())
    for t, wf in _trace():
        sched.submit(wf, at=t)
    snap = sched.snapshot()
    snap["snapshot_version"] = 99
    with pytest.raises(ValueError, match="snapshot version"):
        Scheduler.restore(snap)


def test_crash_restore_with_journal_replay_is_bit_identical(tmp_path):
    base_fp, _ = _baseline()
    journal = EventJournal(tmp_path, rotate_bytes=16 * 1024)
    sched = Scheduler(homogeneous_cluster(4), _config(),
                      journal=journal)
    for t, wf in _trace():
        sched.submit(wf, at=t)
    journal.write_snapshot(sched.snapshot())
    steps = 0
    while sched.events.n_total < int(base_fp["n_events"] * 0.4):
        if not sched.step():
            break
        steps += 1
        if steps % 15 == 0:
            journal.write_snapshot(sched.snapshot())
    killed_at = sched.events.n_total
    del sched, journal                   # crash: abandon in place

    reopened = EventJournal(tmp_path)
    snap = reopened.latest_snapshot()
    assert snap["events"]["n_total"] < killed_at   # a real tail to replay
    restored = Scheduler.restore(snap, reopened)
    assert restored.events.n_total == killed_at    # replay caught up
    assert audit_invariants(restored) == []
    t, wf = _trace()[0]
    with pytest.raises(RuntimeError, match="lifecycle"):
        restored.submit(wf, at=t)        # restored runs take no arrivals
    res = restored.drain()
    assert audit_invariants(restored) == []
    assert _fingerprint(res, restored) == base_fp
    # the journal kept recording through the post-restore drain
    assert reopened.next_index == base_fp["n_events"]


def test_attach_journal_rejects_misaligned_cursor(tmp_path):
    journal = EventJournal(tmp_path)
    journal.append_batch(_events(3), 0)  # journal already holds 3 events
    sched = Scheduler(homogeneous_cluster(4), _config())
    with pytest.raises(JournalError):
        sched.attach_journal(journal)


# ---------------------------------------------------------------------------
# invariant auditor
# ---------------------------------------------------------------------------

def test_audit_clean_on_live_and_drained_schedulers():
    sched = Scheduler(homogeneous_cluster(4), _config())
    for t, wf in _trace():
        sched.submit(wf, at=t)
    _run_until(sched, 100)
    assert audit_invariants(sched) == []
    sched.drain()
    assert audit_invariants(sched) == []


def test_audit_detects_lost_inflight_work():
    sched = Scheduler(homogeneous_cluster(4), _config())
    for t, wf in _trace():
        sched.submit(wf, at=t)
    _run_until(sched, 100)
    sched.issued.add(("ghost", "s0"))    # issued with no run/heap event
    violations = audit_invariants(sched)
    assert any("ghost" in v for v in violations)


def test_audit_every_hook_runs_during_step():
    sched = Scheduler(homogeneous_cluster(4), _config(), audit_every=1)
    for t, wf in _trace():
        sched.submit(wf, at=t)
    res = sched.drain()                  # every step audited in-line
    assert res.stats or res.rejected
