"""Serving engine end-to-end: real tiny models, FATE-driven placement,
residency switches and prefix-cache behaviour on virtual devices."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.archs import SMOKE
from repro.core.devices import homogeneous_cluster
from repro.core.executor import fresh_state
from repro.core.policies import make_policy
from repro.core.workflow import Stage, Workflow
from repro.serving.engine import ModelBundle, ServingEngine


@pytest.fixture(scope="module")
def bundles():
    cfg_a = SMOKE["qwen3-1.7b"]
    cfg_b = dataclasses.replace(SMOKE["glm4-9b"],
                                vocab_size=cfg_a.vocab_size)
    return {
        "qwen-7b": ModelBundle.create("qwen-7b", cfg_a, seed=0),
        "llama-8b": ModelBundle.create("llama-8b", cfg_b, seed=1),
    }


def _workflow(nq=4):
    stages = {
        "retrieve": Stage("retrieve", "qwen-7b", base_cost={-1: 0.01},
                          prefix_group="ctx", max_shards=2),
        "work_a": Stage("work_a", "llama-8b", base_cost={-1: 0.02},
                        parents=("retrieve",)),
        "work_b": Stage("work_b", "qwen-7b", base_cost={-1: 0.02},
                        prefix_group="ctx", parents=("retrieve",)),
        "merge": Stage("merge", "qwen-7b", base_cost={-1: 0.015},
                       prefix_group="ctx",
                       parents=("work_a", "work_b")),
    }
    return Workflow(wid="serve-test", stages=stages, num_queries=nq)


def test_serving_end_to_end(bundles):
    wf = _workflow()
    engine = ServingEngine(bundles, n_devices=2, gen_len=4,
                           prompt_len=8)
    state = fresh_state(homogeneous_cluster(2))
    prompts = jax.random.randint(jax.random.PRNGKey(0), (4, 8), 0, 256)
    results = engine.run_workflow(wf, make_policy("FATE"), state,
                                  prompts)
    assert set(results) == set(wf.stages)
    for sid, res in results.items():
        assert res.tokens_out.shape == (4, 4)
        assert bool(jnp.all(res.tokens_out >= 0))
    # residency: devices ended up hosting the models used
    hosted = {d.resident for d in engine.devices}
    assert hosted <= {"qwen-7b", "llama-8b", None}


def test_serving_residency_switch_counted(bundles):
    wf = _workflow()
    engine = ServingEngine(bundles, n_devices=1, gen_len=2,
                           prompt_len=8)
    state = fresh_state(homogeneous_cluster(1))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 256)
    engine.run_workflow(wf, make_policy("RoundRobin"), state, prompts)
    # single device + two models => at least 2 switches happened
    switched = sum(1 for r in engine.log if r.switched)
    assert switched >= 2


def test_serving_emits_calibration_observations(bundles):
    wf = _workflow()
    engine = ServingEngine(bundles, n_devices=2, gen_len=4,
                           prompt_len=8)
    state = fresh_state(homogeneous_cluster(2))
    prompts = jax.random.randint(jax.random.PRNGKey(3), (4, 8), 0, 256)
    engine.run_workflow(wf, make_policy("FATE"), state, prompts)
    obs = engine.observations()
    assert len(obs) == len(engine.log) == len(wf.stages)
    for o in obs:
        assert o.queries == 4
        assert o.prompt_tokens == 8 and o.output_tokens == 4
        assert o.wall_s > 0.0
        assert o.family in {"qwen", "llama"}
        assert o.transfer_ktokens == 0.0
    # the single-model prefix chain re-runs on a warm group at least
    # once, so some observation carries a nonzero hit fraction
    assert sum(o.switches for o in obs) >= 1


def test_serving_engine_asserts_profile_consistency(bundles):
    from repro.core.calibration import CalibrationProfile

    profile = CalibrationProfile.hand_set().perturbed(switch_mul=0.5)
    wf = _workflow()
    engine = ServingEngine(bundles, n_devices=2, gen_len=2,
                           prompt_len=8, calibration=profile)
    prompts = jax.random.randint(jax.random.PRNGKey(4), (4, 8), 0, 256)
    # state still carries the hand-set constants -> load-time error
    state = fresh_state(homogeneous_cluster(2))
    with pytest.raises(ValueError, match="calibration mismatch"):
        engine.run_workflow(wf, make_policy("FATE"), state, prompts)
    # loading the SAME profile into the state reconciles them
    state = fresh_state(homogeneous_cluster(2),
                        profiles=profile.model_profiles())
    results = engine.run_workflow(wf, make_policy("FATE"), state,
                                  prompts)
    assert set(results) == set(wf.stages)


def test_serving_deterministic_outputs(bundles):
    wf = _workflow()
    prompts = jax.random.randint(jax.random.PRNGKey(2), (4, 8), 0, 256)
    outs = []
    for _ in range(2):
        engine = ServingEngine(bundles, n_devices=2, gen_len=3,
                               prompt_len=8)
        state = fresh_state(homogeneous_cluster(2))
        res = engine.run_workflow(wf, make_policy("FATE"), state,
                                  prompts)
        outs.append({k: v.tokens_out for k, v in res.items()})
    for k in outs[0]:
        assert bool(jnp.all(outs[0][k] == outs[1][k]))
