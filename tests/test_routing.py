"""Cost/quality model-family routing (core/routing.py).

Pins the routing contract: candidates below the quality floor are
filtered before the solve; variant stages are pure cost-scaled twins;
the (stage, family, device) solve respects family exclusivity; routing
disabled — or enabled over candidate-free workloads — is bit-identical
to the pre-routing planner; and the routed trace is served strictly
cheaper than the fixed-family run at chosen quality >= the floor.
"""
import dataclasses
import json

import numpy as np

from repro.core.devices import heterogeneous_cluster, \
    homogeneous_cluster
from repro.core.frontier_solver import FrontierProblem, \
    solve_frontier_exact
from repro.core.planner import FrontierPlanner
from repro.core.routing import (RoutingConfig, StageRouter,
                                admissible_candidates,
                                family_cost_ratio, variant_stage)
from repro.core.scheduler import Scheduler, SchedulerConfig
from repro.core.scoring import ScoreParams
from repro.core.workflow import DEFAULT_PROFILES, Stage, Workflow
from repro.workflowbench.suites import (poisson_serving_trace,
                                        routed_serving_trace,
                                        routed_workflow_instance)


def _routed_stage():
    return Stage("w", "qwen-14b", base_cost={-1: 0.2},
                 candidates=(("qwen-7b", 0.92), ("llama-3b", 0.84)))


def _run(trace, config, n_devices=6):
    sched = Scheduler(homogeneous_cluster(n_devices), config)
    for t, wf in trace:
        sched.submit(wf, at=t)
    res = sched.drain()
    return res, sched


def _events(sched):
    return [(type(e).__name__, dataclasses.astuple(e))
            for e in sched.events]


def _placements(sched):
    return {k: (r.placement.devices, r.placement.shard_sizes,
                r.placement.model, r.start, r.finish)
            for k, r in sched.runs.items()}


# -- candidate admissibility / variant purity ---------------------------


def test_quality_floor_filters_candidates():
    st = _routed_stage()
    cfg = RoutingConfig(quality_floor=0.9)
    assert [m for m, _ in
            admissible_candidates(st, cfg, DEFAULT_PROFILES)] \
        == ["qwen-7b"]
    # a lower floor admits both; a floor above every candidate -> none
    low = RoutingConfig(quality_floor=0.8)
    assert [m for m, _ in
            admissible_candidates(st, low, DEFAULT_PROFILES)] \
        == ["qwen-7b", "llama-3b"]
    high = RoutingConfig(quality_floor=0.99)
    assert admissible_candidates(st, high, DEFAULT_PROFILES) == []


def test_max_candidates_caps_alternates():
    st = Stage("w", "qwen-14b", base_cost={-1: 0.2},
               candidates=(("qwen-7b", 0.95), ("llama-8b", 0.94),
                           ("deepseek-7b", 0.93)))
    cfg = RoutingConfig(quality_floor=0.9, max_candidates=2)
    assert len(admissible_candidates(st, cfg, DEFAULT_PROFILES)) == 2


def test_variant_stage_is_pure_cost_scaled_twin():
    st = _routed_stage()
    v = variant_stage(st, "qwen-7b", DEFAULT_PROFILES)
    assert v.sid == st.sid and v.parents == st.parents
    assert v.model == "qwen-7b"
    ratio = family_cost_ratio(DEFAULT_PROFILES, "qwen-14b", "qwen-7b",
                              st.prefill_fraction)
    assert v.base_cost[-1] == st.base_cost[-1] * ratio
    # the 7b family is genuinely cheaper than 14b
    assert 0.0 < ratio < 1.0
    # purity: same inputs, same output; the original is untouched
    v2 = variant_stage(st, "qwen-7b", DEFAULT_PROFILES)
    assert v2.base_cost == v.base_cost
    assert st.model == "qwen-14b" and st.base_cost[-1] == 0.2


def test_router_variant_cached_per_workflow():
    router = StageRouter(RoutingConfig())
    st = _routed_stage()
    a = router.variant("w1", st, "qwen-7b", DEFAULT_PROFILES)
    b = router.variant("w1", st, "qwen-7b", DEFAULT_PROFILES)
    assert a is b
    router.forget_workflow("w1")
    c = router.variant("w1", st, "qwen-7b", DEFAULT_PROFILES)
    assert c is not a and c.base_cost == a.base_cost


# -- solver exclusivity -------------------------------------------------


def test_solver_exclusive_groups_pick_one_family():
    """With default and variant rows for the same stage in one
    exclusive group, the exact solve assigns at most one of the two
    keys — and picks the higher-weight family."""
    rows = [("s", 0), ("s", 1),                     # default family
            (("s", "alt"), 0), (("s", "alt"), 1)]   # variant block
    weights = np.array([[1.0, 0.8], [0.0, 0.0],
                        [3.0, 2.5], [0.0, 0.0]])
    prob = FrontierProblem(rows, [0, 1], weights,
                           exclusive=[["s", ("s", "alt")]])
    sol = solve_frontier_exact(prob)
    placed = {key for (key, _slot) in sol.assignment}
    assert placed == {("s", "alt")}


def test_solver_exclusive_respects_better_default():
    rows = [("s", 0), (("s", "alt"), 0)]
    weights = np.array([[5.0], [1.0]])
    prob = FrontierProblem(rows, [0], weights,
                           exclusive=[["s", ("s", "alt")]])
    sol = solve_frontier_exact(prob)
    placed = {key for (key, _slot) in sol.assignment}
    assert placed == {"s"}


# -- disabled / candidate-free bit-identity -----------------------------


def test_routing_none_vs_enabled_on_candidate_free_serving():
    """Enabling routing over workflows with no candidates must be a
    provable no-op: identical events and placements."""
    trace = poisson_serving_trace(n_workflows=8, rate=6.0, seed=0,
                                  num_queries=4)
    off, s_off = _run(trace, SchedulerConfig(policy="FATE"))
    on, s_on = _run(trace, SchedulerConfig(policy="FATE",
                                           routing=RoutingConfig()))
    assert _events(s_off) == _events(s_on)
    assert _placements(s_off) == _placements(s_on)
    assert {w: s.makespan for w, s in off.stats.items()} \
        == {w: s.makespan for w, s in on.stats.items()}


def test_routing_enabled_batch_frontier_candidate_free_parity():
    """32x16 H=4 wide batch frontier: the routed planner over a
    candidate-free workflow produces the exact placements of the
    plain planner."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]
                           / "benchmarks"))
    from sched_bench import _warmed_state, bench_workflow

    wf = bench_workflow(32)
    cluster = heterogeneous_cluster(16)
    ready = [f"w{i}" for i in range(32)]
    params = ScoreParams(horizon=4)
    plain = FrontierPlanner(params).plan(
        wf, _warmed_state(wf, 32, cluster), list(ready))
    routed = FrontierPlanner(params, routing=RoutingConfig()).plan(
        wf, _warmed_state(wf, 32, cluster), list(ready))
    assert [(p.sid, p.devices, p.shard_sizes, p.model)
            for p in plain] \
        == [(p.sid, p.devices, p.shard_sizes, p.model)
            for p in routed]
    assert all(p.model is None for p in plain)


# -- routed end-to-end --------------------------------------------------


def test_routed_trace_cheaper_at_quality_floor():
    trace = routed_serving_trace(n_workflows=6, rate=4.0, seed=0,
                                 num_queries=4)
    fixed, s_fixed = _run(trace, SchedulerConfig(policy="FATE"))
    routed, s_routed = _run(trace, SchedulerConfig(
        policy="FATE", routing=RoutingConfig()))
    assert len(routed.stats) == len(trace)          # all complete
    by_wid = {wf.wid: wf for _, wf in trace}
    floor = RoutingConfig().quality_floor
    n_routed = 0
    for (wid, sid), r in s_routed.runs.items():
        st = by_wid[wid].stages[sid]
        if r.placement.model and r.placement.model != st.model:
            n_routed += 1
            assert dict(st.candidates)[r.placement.model] >= floor
            # the below-floor llama-3b candidate is never chosen
            assert r.placement.model != "llama-3b"

    def cost(s):
        return sum((r.finish - r.start) * len(r.placement.devices)
                   for r in s.runs.values())

    assert n_routed > 0
    assert cost(s_routed) < cost(s_fixed)


def test_routed_placement_model_survives_snapshot():
    """A routed run's snapshot round-trips Placement.model; an
    unrouted run's placement docs carry no 'model' key at all."""
    trace = routed_serving_trace(n_workflows=3, rate=4.0, seed=0,
                                 num_queries=4)
    cfg = SchedulerConfig(policy="FATE", routing=RoutingConfig())
    sched = Scheduler(homogeneous_cluster(4), cfg)
    for t, wf in trace:
        sched.submit(wf, at=t)
    # advance until something routed is in flight
    while not any(r.placement.model for r in sched.runs.values()):
        assert sched.step(), "no routed run ever issued"
    snap = sched.snapshot()
    doc = json.loads(json.dumps(snap))       # wire round-trip
    restored = Scheduler.restore(doc)
    assert {k: r.placement.model for k, r in sched.runs.items()} \
        == {k: r.placement.model for k, r in restored.runs.items()}


def test_unrouted_snapshot_has_no_model_keys():
    trace = poisson_serving_trace(n_workflows=4, rate=6.0, seed=0,
                                  num_queries=4)
    cfg = SchedulerConfig(policy="FATE")
    sched = Scheduler(homogeneous_cluster(4), cfg)
    for t, wf in trace:
        sched.submit(wf, at=t)
    while not sched.runs:
        assert sched.step()
    snap = json.loads(json.dumps(sched.snapshot()))
    docs = [run["placement"] for _w, _s, run in snap["runs"]] \
        + list(snap["committed"])
    assert docs
    assert all("model" not in d for d in docs)


# -- config surface -----------------------------------------------------


def test_config_round_trips_routing_gateway_and_auto_pools():
    cfg = SchedulerConfig(
        policy="FATE",
        routing=RoutingConfig(quality_floor=0.85, max_candidates=2),
        gateway={"replicas": 3}, pools="auto")
    back = SchedulerConfig.from_json(cfg.to_json())
    assert back.routing is not None
    assert back.routing.quality_floor == 0.85
    assert back.routing.max_candidates == 2
    assert back.gateway == {"replicas": 3}
    assert back.pools == "auto"


def test_legacy_config_docs_load_with_routing_disabled():
    """Pre-gateway JSON documents (no routing/gateway keys) must load
    unchanged, with both features disabled."""
    doc = json.loads(SchedulerConfig(policy="FATE").to_json())
    doc.pop("routing", None)
    doc.pop("gateway", None)
    cfg = SchedulerConfig.from_json(json.dumps(doc))
    assert cfg.routing is None
    assert cfg.gateway is None
    assert cfg.pools == 1


def test_stage_candidates_round_trip_and_legacy_load():
    st = _routed_stage()
    back = Stage.from_dict(st.to_dict())
    assert back.candidates == st.candidates
    legacy = st.to_dict()
    legacy.pop("candidates")
    assert Stage.from_dict(legacy).candidates == ()
    wf = routed_workflow_instance(0, num_queries=4)
    wf2 = Workflow.from_dict(wf.to_dict())
    assert {s.sid: s.candidates for s in wf.stages.values()} \
        == {s.sid: s.candidates for s in wf2.stages.values()}
