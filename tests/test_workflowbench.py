"""Benchmark construction invariants + end-to-end scheduling results
(the paper's qualitative claims as assertions)."""
import pytest

from repro.workflowbench.families import FAMILIES
from repro.workflowbench.lift import (MAX_STAGES, build_instance,
                                      build_benchmark)
from repro.workflowbench.runner import run_suite, rows_to_tables


def test_generator_deterministic():
    a = build_instance("Montage", 0, 16)
    b = build_instance("Montage", 0, 16)
    assert set(a.stages) == set(b.stages)
    for sid in a.stages:
        assert a.stages[sid].model == b.stages[sid].model
        assert a.stages[sid].base_cost == b.stages[sid].base_cost


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_lift_invariants(family):
    wf = build_instance(family, 1, 16)
    wf.validate()
    assert 1 <= len(wf.stages) <= MAX_STAGES
    # acyclic with complete levels
    assert len(wf.topo_order) == len(wf.stages)
    for st in wf.stages.values():
        assert st.model in {"qwen-7b", "deepseek-7b", "llama-8b",
                            "llama-3b", "qwen-14b"}
        assert st.cost_on(0) > 0
        assert st.max_shards in (1, 2)


def test_fixed_model_families_single_model():
    wf = build_instance("Srasearch", 0, 16)
    assert len({st.model for st in wf.stages.values()}) == 1


SLICE = [build_instance(fam, i, 16)
         for fam in FAMILIES for i in range(2)]


def test_fate_beats_roundrobin_and_baselines():
    """Table 1's qualitative claims: FATE < all baselines < RR."""
    rows = run_suite(SLICE, ["RoundRobin", "FATE", "KVFlow", "Helix",
                             "Halo", "HEFT"])
    tab = rows_to_tables(rows)
    assert tab["FATE"]["norm_ms"] < 0.85
    for pol in ["KVFlow", "Helix", "Halo", "HEFT"]:
        assert tab[pol]["norm_ms"] < 1.0          # beat RR
        assert tab["FATE"]["norm_ms"] <= tab[pol]["norm_ms"] + 0.02
    # mechanism: FATE preserves the most state
    assert tab["FATE"]["model_cont"] >= tab["Halo"]["model_cont"]
    assert tab["FATE"]["cache_score"] >= tab["Helix"]["cache_score"]


def test_ablation_future_planning_matters():
    """Table 3's headline: removing future planning degrades the most."""
    from repro.core.scoring import ScoreParams
    rows_full = run_suite(SLICE, ["RoundRobin", "FATE"])
    full = rows_to_tables(rows_full)["FATE"]["norm_ms"]
    rows_nf = run_suite(SLICE, ["RoundRobin", "FATE"],
                        score_params=ScoreParams(enable_future=False))
    nf = rows_to_tables(rows_nf)["FATE"]["norm_ms"]
    assert nf >= full - 1e-9, (full, nf)
